"""Observability artifact validation — schema + overhead gate for CI.

Validates the JSON artifacts the serving stack emits against the committed
shape contracts in ``tools/schemas/`` and enforces the tracing overhead
budget, without any third-party dependency (the validator implements the
JSON-Schema subset the contracts use: ``type`` (incl. union lists),
``required``, ``properties``, ``items``, ``enum``, ``minimum``).

    PYTHONPATH=src python tools/check_obs.py --trace trace.json
    PYTHONPATH=src python tools/check_obs.py --events events.json
    PYTHONPATH=src python tools/check_obs.py \
        --bench BENCH_serving.json --overhead-budget 0.03
    PYTHONPATH=src python tools/check_obs.py --pareto reports/dse/pareto.json
    PYTHONPATH=src python tools/check_obs.py --dse BENCH_dse.json

Beyond the schema, ``--trace`` also checks the phase-conditional fields the
schema subset cannot express (``X`` spans need ``ts``/``dur`` and a request
uid; ``C``/``i`` samples need ``ts``), and ``--events`` cross-checks the
reconstructed timelines against the raw events.
Exit code 0 = every artifact validates; 1 = any violation (printed).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SCHEMA_DIR = Path(__file__).resolve().parent / "schemas"

_TYPES = {
    "object": dict, "array": list, "string": str,
    "integer": int, "number": (int, float), "boolean": bool,
    "null": type(None),
}


def validate(instance, schema, path="$", errors=None):
    """Hand-rolled validator for the subset of JSON Schema the committed
    contracts use.  Appends human-readable violations to ``errors``."""
    if errors is None:
        errors = []
    t = schema.get("type")
    if t is not None:
        types = t if isinstance(t, list) else [t]
        py = tuple(_TYPES[x] for x in types)
        ok = isinstance(instance, py)
        # bool is an int subclass in Python; don't let it satisfy integer
        if ok and isinstance(instance, bool) and "boolean" not in types:
            ok = False
        # JSON integers must not be floats with fractional parts
        if not ok and "integer" in types and isinstance(instance, float) \
                and instance.is_integer():
            ok = True
        if not ok:
            errors.append(f"{path}: expected {t}, got "
                          f"{type(instance).__name__} ({instance!r})")
            return errors
    if "enum" in schema and instance not in schema["enum"]:
        errors.append(f"{path}: {instance!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(instance, (int, float)) \
            and not isinstance(instance, bool) \
            and instance < schema["minimum"]:
        errors.append(f"{path}: {instance!r} < minimum {schema['minimum']}")
    if isinstance(instance, dict):
        for req in schema.get("required", []):
            if req not in instance:
                errors.append(f"{path}: missing required key {req!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in instance:
                validate(instance[key], sub, f"{path}.{key}", errors)
    if isinstance(instance, list) and "items" in schema:
        for i, item in enumerate(instance):
            validate(item, schema["items"], f"{path}[{i}]", errors)
    return errors


def check_trace_semantics(doc) -> list:
    """Phase-conditional requirements the schema subset cannot express:
    ``X`` spans carry integer ``ts``/``dur`` and a request uid; ``C``/``i``
    samples carry an integer ``ts``.  (Event *file order* is close order,
    not ``ts`` order — spans are stamped with their open tick — so there is
    deliberately no monotonicity requirement here.)"""
    errors = []
    for i, ev in enumerate(doc.get("traceEvents", [])):
        ph = ev.get("ph")
        where = f"$.traceEvents[{i}] ({ph} {ev.get('name')!r})"
        if ph == "X":
            for k in ("ts", "dur"):
                if not isinstance(ev.get(k), int):
                    errors.append(f"{where}: X span needs integer {k!r}")
            if "uid" not in ev.get("args", {}):
                errors.append(f"{where}: request span missing args.uid")
        elif ph in ("C", "i"):
            if not isinstance(ev.get("ts"), int):
                errors.append(f"{where}: {ph} event needs integer 'ts'")
    return errors


def check_events_semantics(doc) -> list:
    """Chain-consistency: every detected timeline has a detection event,
    recovery latencies are never negative."""
    errors = []
    kinds = [e["kind"] for e in doc.get("events", [])]
    n_detections = kinds.count("detection")
    for i, tl in enumerate(doc.get("timelines", [])):
        where = f"$.timelines[{i}]"
        if tl["detected"] and n_detections == 0:
            errors.append(f"{where}: detected=true but no detection events")
        lat = tl.get("detection_latency_ticks")
        if tl["detected"] and (lat is None or lat < 0):
            errors.append(f"{where}: detected=true with bad latency {lat!r}")
        rlat = tl.get("recovery_latency_ticks")
        if tl["recovered"] and (rlat is None or rlat < 0):
            errors.append(f"{where}: recovered=true with bad latency {rlat!r}")
    return errors


def _dominates(a, b) -> bool:
    return all(x <= y for x, y in zip(a, b)) and \
        any(x < y for x, y in zip(a, b))


def check_pareto_semantics(doc) -> list:
    """Frontier invariants the schema cannot express: the committed front
    is mutually non-dominated, and the per-generation ``evaluated`` counter
    never decreases (the archive only grows)."""
    errors = []
    front = doc.get("front", [])
    for i, a in enumerate(front):
        for j, b in enumerate(front):
            if i != j and _dominates(a["objectives"], b["objectives"]):
                errors.append(f"$.front[{j}] ({b.get('digest')}) is "
                              f"dominated by $.front[{i}] "
                              f"({a.get('digest')}) — not a Pareto front")
    evaluated = [h["evaluated"] for h in doc.get("history", [])]
    if any(b < a for a, b in zip(evaluated, evaluated[1:])):
        errors.append(f"$.history: 'evaluated' not non-decreasing: "
                      f"{evaluated}")
    return errors


def check_dse_semantics(doc) -> list:
    """Certification cross-checks: the summary tallies must match the
    per-site campaign rows they summarize, and a mapped serving run must
    have decoded bit-identically to the unhardened stream."""
    errors = []
    cert = doc.get("certify", {})
    rows = cert.get("rows", {})
    if rows:
        sdc_max = max(r.get("sdc", 0) for r in rows.values())
        if cert.get("sdc_max") != sdc_max:
            errors.append(f"$.certify.sdc_max {cert.get('sdc_max')!r} != "
                          f"max of row sdc counts {sdc_max}")
        trials = sum(r.get("trials", 0) for r in rows.values())
        if cert.get("trials") != trials:
            errors.append(f"$.certify.trials {cert.get('trials')!r} != "
                          f"sum of row trials {trials}")
        for site, r in rows.items():
            tally = (r.get("masked", 0) + r.get("detected_corrected", 0)
                     + r.get("detected_uncorrected", 0) + r.get("sdc", 0))
            if tally != r.get("trials"):
                errors.append(f"$.certify.rows.{site}: outcome tally "
                              f"{tally} != trials {r.get('trials')!r}")
    serving = doc.get("serving")
    if serving is not None and serving.get("bit_identical") is not True:
        errors.append("$.serving.bit_identical: mapped decode stream "
                      "diverged from the unhardened baseline")
    return errors


def _load(path):
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", action="append", default=[],
                    help="Chrome trace_event JSON file(s) to validate")
    ap.add_argument("--events", action="append", default=[],
                    help="dependability event-log JSON file(s) to validate")
    ap.add_argument("--bench", default=None,
                    help="BENCH_serving.json with a trace_overhead_frac")
    ap.add_argument("--overhead-budget", type=float, default=0.03,
                    help="max tolerated tracing overhead fraction")
    ap.add_argument("--pareto", action="append", default=[],
                    help="DSE frontier report(s) (reports/dse/pareto.json)")
    ap.add_argument("--dse", action="append", default=[],
                    help="DSE certification summaries (BENCH_dse.json)")
    args = ap.parse_args(argv)
    if not (args.trace or args.events or args.bench or args.pareto
            or args.dse):
        ap.error("nothing to check: "
                 "pass --trace/--events/--bench/--pareto/--dse")

    failures = 0
    trace_schema = _load(SCHEMA_DIR / "trace.schema.json")
    events_schema = _load(SCHEMA_DIR / "events.schema.json")
    for path in args.trace:
        doc = _load(path)
        errs = validate(doc, trace_schema) + check_trace_semantics(doc)
        n = len(doc.get("traceEvents", []))
        print(f"{path}: {n} trace events, "
              f"{'ok' if not errs else f'{len(errs)} violation(s)'}")
        for e in errs[:20]:
            print(f"  {e}", file=sys.stderr)
        failures += bool(errs)
    for path in args.events:
        doc = _load(path)
        errs = validate(doc, events_schema) + check_events_semantics(doc)
        print(f"{path}: {len(doc.get('events', []))} events / "
              f"{len(doc.get('timelines', []))} timelines, "
              f"{'ok' if not errs else f'{len(errs)} violation(s)'}")
        for e in errs[:20]:
            print(f"  {e}", file=sys.stderr)
        failures += bool(errs)
    if args.pareto:
        pareto_schema = _load(SCHEMA_DIR / "pareto.schema.json")
        for path in args.pareto:
            doc = _load(path)
            errs = validate(doc, pareto_schema) + check_pareto_semantics(doc)
            print(f"{path}: {len(doc.get('front', []))} frontier designs / "
                  f"{doc.get('evaluations', 0)} evaluated, "
                  f"{'ok' if not errs else f'{len(errs)} violation(s)'}")
            for e in errs[:20]:
                print(f"  {e}", file=sys.stderr)
            failures += bool(errs)
    if args.dse:
        dse_schema = _load(SCHEMA_DIR / "dse.schema.json")
        for path in args.dse:
            doc = _load(path)
            errs = validate(doc, dse_schema) + check_dse_semantics(doc)
            cert = doc.get("certify", {})
            print(f"{path}: sdc_max={cert.get('sdc_max')} over "
                  f"{cert.get('trials')} certification trials, "
                  f"{'ok' if not errs else f'{len(errs)} violation(s)'}")
            for e in errs[:20]:
                print(f"  {e}", file=sys.stderr)
            failures += bool(errs)
    if args.bench:
        doc = _load(args.bench)
        frac = doc.get("trace_overhead_frac")
        if frac is None:
            print(f"{args.bench}: no trace_overhead_frac (run the bench "
                  "with --trace-out)", file=sys.stderr)
            failures += 1
        elif frac > args.overhead_budget:
            print(f"{args.bench}: tracing overhead {frac * 100:.1f}% exceeds "
                  f"budget {args.overhead_budget * 100:.1f}%",
                  file=sys.stderr)
            failures += 1
        else:
            print(f"{args.bench}: tracing overhead {frac * 100:.1f}% within "
                  f"{args.overhead_budget * 100:.1f}% budget")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
