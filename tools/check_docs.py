"""Docs-consistency check: execute the CLI commands documented in docs.

Scans ``README.md`` and ``docs/*.md`` for fenced ```bash blocks, extracts
every ``python`` invocation (continuation backslashes joined), rewrites it
to smoke scale — trial counts shrunk, report output redirected to a temp
dir — and runs it.  A documented command that no longer parses or exits
nonzero fails CI, so quickstart sections cannot rot ahead of the code.

    PYTHONPATH=src python tools/check_docs.py            # run everything
    PYTHONPATH=src python tools/check_docs.py --list     # show the plan
    PYTHONPATH=src python tools/check_docs.py --only fleet.md

Rewrites applied (smoke mode, default):
  --trials N      -> --trials 5
  --bit-trials N  -> --bit-trials 2
  --requests N    -> --requests 3
  --out PATH      -> --out <tmpdir>/PATH   (also appended when a repro.*
                                            CLI documents no --out)
Commands that are not ``python …`` (or that run pytest — tier-1 has its
own CI job) are skipped.
"""
from __future__ import annotations

import argparse
import os
import re
import shlex
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_GLOBS = ["README.md", "docs/*.md"]


def fenced_bash_blocks(text: str):
    """Yield the contents of ```bash fenced blocks."""
    for m in re.finditer(r"```bash\n(.*?)```", text, re.DOTALL):
        yield m.group(1)


def commands_in_block(block: str):
    """Join continuation lines and yield the shell commands."""
    logical, pending = [], ""
    for line in block.splitlines():
        line = line.rstrip()
        if not line or line.lstrip().startswith("#"):
            continue
        pending += line.rstrip("\\").rstrip() + " "
        if not line.endswith("\\"):
            logical.append(pending.strip())
            pending = ""
    if pending.strip():
        logical.append(pending.strip())
    return logical


def runnable(cmd: str) -> bool:
    return ("python" in cmd.split()[0] or cmd.startswith("PYTHONPATH")) \
        and "pytest" not in cmd


def smoke_rewrite(cmd: str, out_dir: Path, idx: int) -> str:
    cmd = re.sub(r"--trials\s+\d+", "--trials 5", cmd)
    cmd = re.sub(r"--max-trials\s+\d+", "--max-trials 8", cmd)
    cmd = re.sub(r"--bit-trials\s+\d+", "--bit-trials 2", cmd)
    cmd = re.sub(r"--requests\s+\d+", "--requests 3", cmd)
    cmd = re.sub(r"--workers\s+\d+", "--workers 2", cmd)
    cmd = re.sub(r"--generations\s+\d+", "--generations 2", cmd)
    cmd = re.sub(r"--population\s+\d+", "--population 6", cmd)
    cmd = re.sub(r"--reps\s+\d+", "--reps 2", cmd)
    if "--out" in cmd:
        cmd = re.sub(r"--out\s+(\S+)",
                     lambda m: f"--out {out_dir / Path(m.group(1)).name}", cmd)
    elif re.search(r"-m repro\.(campaign|fleet|dse)\.cli", cmd):
        cmd += f" --out {out_dir / f'cmd{idx:02d}'}"
    # observability artifacts: redirect documented paths into the tmpdir —
    # both the producing flags (--trace-out …) and tools/check_obs.py's
    # consuming flags (--trace …), so produce-then-validate doc sequences
    # line up on the same files
    # --resume is a directory a previous documented command wrote with
    # --out: both rewrite to the same tmpdir basename, so documented
    # run-then-resume sequences line up on the same journal
    # --bench-out is the only DSE flag redirected: the certify command's
    # consuming flags (--map/--cost-model/--pareto/--dse/--policy-map)
    # deliberately resolve against the *committed* artifacts in the repo
    for flag in ("--trace-out", "--metrics-out", "--events-out",
                 "--trace", "--events", "--bench", "--resume",
                 "--bench-out"):
        cmd = re.sub(
            rf"(?<!\S){flag}\s+(\S+)",
            lambda m, f=flag: f"{f} {out_dir / Path(m.group(1)).name}", cmd)
    return cmd


def collect(only: str | None):
    plan, seen = [], set()
    for g in DOC_GLOBS:
        for doc in sorted(REPO.glob(g)):
            if only and only not in doc.name:
                continue
            for block in fenced_bash_blocks(doc.read_text()):
                for cmd in commands_in_block(block):
                    # the same command documented in two places only needs
                    # to prove itself once (attributed to the first doc)
                    key = " ".join(cmd.split())
                    if runnable(cmd) and key not in seen:
                        seen.add(key)
                        plan.append((doc.relative_to(REPO), cmd))
    return plan


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="print the rewritten commands without running")
    ap.add_argument("--only", default=None,
                    help="substring filter on the doc filename")
    ap.add_argument("--timeout", type=int, default=900,
                    help="per-command timeout, seconds")
    args = ap.parse_args(argv)

    plan = collect(args.only)
    if not plan:
        print("no documented commands found", file=sys.stderr)
        return 2

    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = str(REPO / "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")

    failures = 0
    with tempfile.TemporaryDirectory(prefix="docs-check-") as td:
        for i, (doc, cmd) in enumerate(plan):
            # the docs spell the env assignment inline; we provide it via env
            bare = re.sub(r"^PYTHONPATH=\S+\s+", "", cmd)
            run = smoke_rewrite(bare, Path(td), i)
            print(f"[{i + 1}/{len(plan)}] {doc}: {run}", flush=True)
            if args.list:
                continue
            t0 = time.time()
            try:
                proc = subprocess.run(
                    shlex.split(run), cwd=REPO, env=env,
                    timeout=args.timeout, capture_output=True, text=True)
            except subprocess.TimeoutExpired:
                print(f"  TIMEOUT after {args.timeout}s", flush=True)
                failures += 1
                continue
            dt = time.time() - t0
            # fleet CLI uses exit 1 as the *documented* SDC verdict for
            # --policy none drills; that is correct behavior, not rot
            expected_fail = ("--policy none" in run and "repro.fleet.cli" in run
                            and ("--inject" in run or "--kill" in run))
            ok = proc.returncode == 0 or (expected_fail and proc.returncode == 1)
            print(f"  {'ok' if ok else 'FAIL rc=' + str(proc.returncode)} "
                  f"({dt:.1f}s)", flush=True)
            if not ok:
                sys.stdout.write(proc.stdout[-2000:])
                sys.stderr.write(proc.stderr[-2000:])
                failures += 1
    if failures:
        print(f"{failures} documented command(s) failed", file=sys.stderr)
        return 1
    print(f"all {len(plan)} documented commands "
          f"{'listed' if args.list else 'ran clean'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
