"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ArchConfig, SHAPES, ShapeConfig, reduced, valid_cells

from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.qwen3_0_6b import CONFIG as _qwen3
from repro.configs.command_r_plus_104b import CONFIG as _cmdr
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in [
        _kimi, _mixtral, _smollm, _qwen3, _cmdr, _llama3, _rwkv6,
        _musicgen, _llava, _rgemma,
    ]
}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Every runnable (arch, shape) pair — 33 cells (7 long_500k skips are
    documented in DESIGN.md §Arch-applicability)."""
    out = []
    for name, cfg in ARCHS.items():
        for shape in valid_cells(cfg):
            out.append((cfg, shape))
    return out


def names():
    return sorted(ARCHS)
