"""SmolLM-135M — llama-architecture small model. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="transformer",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    head_dim=64,
    tie_embeddings=True,
    optimizer="adamw",
    remat="save_dots",
)
