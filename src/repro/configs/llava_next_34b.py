"""LLaVA-NeXT 34B backbone — anyres patch frontend is a STUB (input_specs
provides precomputed patch embeddings). [hf:llava-hf family; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="transformer",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    input_mode="embeddings",
    fsdp_params=True,
    param_dtype="bfloat16",
    optimizer="adamw",
    remat="full",
)
