"""Qwen3-0.6B — GQA with qk-norm. [hf:Qwen/Qwen3-8B family]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="transformer",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=64,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    optimizer="adamw",
    remat="save_dots",
)
