"""Command R+ 104B — dense GQA, no biases, large vocab.
[hf:CohereForAI/c4ai-command-r-v01 family; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="transformer",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    use_bias=False,
    fsdp_params=True,
    param_dtype="bfloat16",
    optimizer="adamw",
    remat="full",
)
