"""MusicGen-large backbone — decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2306.05284; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="transformer",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,                       # MHA (kv == heads per assignment)
    d_ff=8192,
    vocab_size=2048,                     # EnCodec codebook
    head_dim=64,
    input_mode="embeddings",
    optimizer="adamw",
    remat="save_dots",
)
