"""Kimi K2 — trillion-parameter MoE (384 routed experts, top-8, 1 shared,
first layer dense).  [arXiv:2501.kimi2; paper-table]"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="transformer",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=112,                       # d_model / n_heads
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                  n_shared_experts=1, n_dense_layers=1),
    fsdp_params=True,
    param_dtype="bfloat16",
    optimizer="adafactor",              # Adam states would not fit 512×16 GB
    remat="full",
    notes="1T total / ~32B active; EP over model axis (24 experts/shard), "
          "expert d_expert FSDP over dp; full attention -> long_500k skipped",
)
