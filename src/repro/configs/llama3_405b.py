"""Llama 3 405B — dense GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="transformer",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    fsdp_params=True,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
)
