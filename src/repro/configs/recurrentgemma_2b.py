"""RecurrentGemma-2B — RG-LRU + local attention, 2:1 pattern, window 2048.
[arXiv:2402.19427; hf]"""
from repro.models.config import ArchConfig, RecurrentConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                        # MQA local attention
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    tie_embeddings=True,
    recurrent=RecurrentConfig(kind="rglru", lru_width=2560, d_conv=4,
                              attn_window=2048),
    sub_quadratic=True,                  # local attn + O(1) state
    optimizer="adamw",
    remat="save_dots",
)
