"""Mixtral 8x7B — 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="transformer",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    swa_window=4096,
    sub_quadratic=True,                 # SWA bounds the KV cache -> long_500k runs
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
    fsdp_params=True,
    param_dtype="bfloat16",
    optimizer="adamw",
    remat="save_dots",
)
