"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.models.config import ArchConfig, RecurrentConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,                          # d_model / head_dim
    n_kv_heads=1,
    d_ff=7168,
    vocab_size=65536,
    recurrent=RecurrentConfig(kind="rwkv6", head_dim=64),
    sub_quadratic=True,                  # O(1) state -> long_500k runs
    optimizer="adamw",
    remat="save_dots",
)
