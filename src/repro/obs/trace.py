"""Deterministic per-request span tracing — Chrome ``trace_event`` export.

Answers *where a request spent its time*: every request flowing through the
streaming executor gets one span per pipeline stage (admit → prefill →
decode → certify), plus counter tracks for queue depth and decode-slot
occupancy sampled once per pump cycle.  The file a trace dumps to is the
Chrome/Perfetto ``trace_event`` JSON format, so ``ui.perfetto.dev`` (or
``chrome://tracing``) renders the pipeline directly — one track per stage,
one slice per request-stage residency.

Determinism is the design constraint: spans are keyed on the executor's
**tick clock** (cooperative pump cycles), not the wall clock, so two runs
with the same seed produce *byte-identical* trace files — the property the
dependability campaigns rely on for replay debugging, asserted in
``tests/test_obs.py``.  ``wall_clock=True`` opt-in adds wall-time
annotations to span args (useful for real profiling, destroys
byte-identity; default off).

Cost model: tracing must be a pure observer —

  * disabled (``tracer=None`` on the executor) it is a handful of ``if x is
    None`` branches: zero allocations, nothing measurable;
  * enabled it is dict appends on host-side stage transitions only (never
    inside jitted code), budgeted at < 3 % tokens/s on the serving bench
    (asserted in CI).

Span model (Chrome ``ph`` phases):

  ``X`` complete events — one per (request uid, stage) residency, ``ts`` =
        entry tick, ``dur`` = ticks resident, ``args`` carry uid and
        stage-specific detail (prompt length, tokens decoded, …);
  ``C`` counter events — per-tick queue depths and slot occupancy;
  ``i`` instant events — point occurrences (release, rollback, strike);
  ``M`` metadata — process/thread naming so stage tracks sort correctly.

Ticks are exported as microseconds 1:1 (Perfetto needs a time unit; one
tick = 1 µs nominal).  In wall-clock mode spans additionally carry
``wall_ts``/``wall_dur`` (seconds) in their args.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

# canonical stage → trace-track (tid) assignment; release is an instant on
# the certify track's successor so it sorts last
STAGE_TIDS = {"admit": 1, "prefill": 2, "decode": 3, "certify": 4,
              "release": 5}


class SpanTracer:
    """Collects spans against a caller-advanced tick clock.

    The owner (``StreamingExecutor``) calls ``tick_to(t)`` as its clock
    advances, ``open_span``/``close_span`` at stage transitions, ``instant``
    for point events, and ``counter`` for per-tick level samples.  Nothing
    here reads a clock of its own in deterministic mode.
    """

    def __init__(self, wall_clock: bool = False, name: str = "engine",
                 pid: int = 0):
        self.wall_clock = wall_clock
        self.name = name
        self.pid = pid
        self.tick = 0
        self.events: List[dict] = []
        self._open: Dict[Tuple[int, str], dict] = {}   # (uid, stage) -> span
        self._t0 = time.perf_counter() if wall_clock else 0.0
        self._emit_metadata()

    # ------------------------------------------------------------ plumbing
    def _emit_metadata(self):
        self.events.append({"ph": "M", "pid": self.pid, "tid": 0,
                            "name": "process_name",
                            "args": {"name": self.name}})
        for stage, tid in STAGE_TIDS.items():
            self.events.append({"ph": "M", "pid": self.pid, "tid": tid,
                                "name": "thread_name",
                                "args": {"name": stage}})
            self.events.append({"ph": "M", "pid": self.pid, "tid": tid,
                                "name": "thread_sort_index",
                                "args": {"sort_index": tid}})

    def _wall(self) -> float:
        return time.perf_counter() - self._t0

    def tick_to(self, tick: int) -> None:
        self.tick = tick

    # -------------------------------------------------------------- spans
    def open_span(self, uid: int, stage: str, **args) -> None:
        """Begin a (uid, stage) residency at the current tick.  Re-opening
        an open span restarts it (rollback replays re-enter a stage)."""
        span = {"uid": uid, "stage": stage, "ts": self.tick, "args": args}
        if self.wall_clock:
            span["wall_ts"] = self._wall()
        self._open[(uid, stage)] = span

    def close_span(self, uid: int, stage: str, **args) -> None:
        """End a residency; silently ignores a span that is not open (e.g.
        a request cancelled out of a stage it never entered)."""
        span = self._open.pop((uid, stage), None)
        if span is None:
            return
        merged = dict(span["args"])
        merged.update(args)
        merged["uid"] = uid
        ev = {"ph": "X", "pid": self.pid, "tid": STAGE_TIDS.get(stage, 9),
              "name": stage, "cat": "request",
              "ts": span["ts"], "dur": self.tick - span["ts"],
              "args": merged}
        if self.wall_clock:
            ev["args"]["wall_ts"] = span["wall_ts"]
            ev["args"]["wall_dur"] = self._wall() - span["wall_ts"]
        self.events.append(ev)

    def cancel_span(self, uid: int, stage: str) -> None:
        """Drop an open span without emitting (request evicted/reset)."""
        self._open.pop((uid, stage), None)

    def instant(self, name: str, stage: str = "decode", **args) -> None:
        ev = {"ph": "i", "pid": self.pid,
              "tid": STAGE_TIDS.get(stage, 9), "name": name,
              "cat": "event", "ts": self.tick, "s": "t", "args": args}
        if self.wall_clock:
            ev["args"]["wall_ts"] = self._wall()
        self.events.append(ev)

    def counter(self, name: str, **series) -> None:
        """One ``C`` sample of a counter track at the current tick."""
        self.events.append({"ph": "C", "pid": self.pid, "tid": 0,
                            "name": name, "ts": self.tick, "args": series})

    # ------------------------------------------------------------- export
    def to_chrome_trace(self) -> dict:
        """The ``trace_event`` JSON object.  Open spans are flushed as
        zero-progress slices ending at the current tick (work still in
        flight when the trace was cut)."""
        events = list(self.events)
        for (uid, stage), span in sorted(self._open.items(),
                                         key=lambda kv: (kv[0][0],
                                                         kv[0][1])):
            args = dict(span["args"])
            args.update(uid=uid, unfinished=True)
            events.append({"ph": "X", "pid": self.pid,
                           "tid": STAGE_TIDS.get(stage, 9), "name": stage,
                           "cat": "request", "ts": span["ts"],
                           "dur": self.tick - span["ts"], "args": args})
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "metadata": {
                "clock": "ticks" if not self.wall_clock else "ticks+wall",
                "tracer": self.name,
            },
        }

    def to_bytes(self) -> bytes:
        """Canonical serialization: sorted keys, fixed separators — the
        byte-identity surface the determinism tests assert on."""
        return json.dumps(self.to_chrome_trace(), sort_keys=True,
                          separators=(",", ":")).encode() + b"\n"

    def dump(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_bytes(self.to_bytes())
        return path


def merge_traces(tracers) -> dict:
    """Combine several tracers (e.g. one per fleet replica, distinguished
    by ``pid``) into one ``trace_event`` object, in the order given —
    deterministic when each tracer is."""
    tracers = list(tracers)
    events: List[dict] = []
    for tr in tracers:
        events.extend(tr.to_chrome_trace()["traceEvents"])
    wall = any(tr.wall_clock for tr in tracers)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "clock": "ticks" if not wall else "ticks+wall",
            "tracer": "+".join(tr.name for tr in tracers),
        },
    }


def dump_merged(tracers, path) -> pathlib.Path:
    """Canonically serialize a merged trace (same byte-identity contract
    as ``SpanTracer.to_bytes``)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = json.dumps(merge_traces(tracers), sort_keys=True,
                      separators=(",", ":")).encode() + b"\n"
    path.write_bytes(data)
    return path
