"""Observability layer: metrics registry, deterministic span tracing, and
the structured dependability event log.

Three measured-event substrates, one design rule — *observation must not
perturb the system it observes*:

  * :mod:`repro.obs.metrics` — ``Counter``/``Gauge``/``Histogram`` in a
    ``Registry`` with JSON snapshot + Prometheus text exposition; fixed
    memory (streaming histograms), wall-clock-free export.
  * :mod:`repro.obs.trace` — per-request per-stage span tracing on the
    executor's deterministic tick clock, exported as Chrome
    ``trace_event`` JSON (Perfetto-viewable); byte-identical across
    same-seed runs, zero-cost when disabled.
  * :mod:`repro.obs.events` — typed dependability events (strike /
    detection / rollback / recovery / quarantine / failover) with fault
    provenance, plus injection→detection→recovery timeline reconstruction
    and per-policy latency distributions.

See docs/observability.md for the span model, event schema, and Perfetto
workflow.
"""
from repro.obs.events import Event, EventLog
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               exp_buckets)
from repro.obs.trace import SpanTracer, dump_merged, merge_traces

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "exp_buckets",
    "SpanTracer", "merge_traces", "dump_merged", "Event", "EventLog",
]
