"""Structured dependability event log — injection → detection → recovery.

The neutron-irradiation and DAVOS lines of work turn raw SDC counts into
hardening decisions by *attributing* every error: which site was struck,
when the policy noticed, what the recovery did, and how long each edge
took.  This module is that record for the reproduction: an append-only log
of typed events, each carrying fault provenance, that campaign reports
replay into per-policy detection- and recovery-latency distributions.

Event kinds (``EventLog.KINDS``):

  ``strike``      an SEU was injected (campaign hook or drill CLI):
                  site + fault model + the tick it landed on
  ``detection``   a policy's check flagged corruption (ABFT checksum,
                  storage scrub, decode-state scrub, DMR divergence)
  ``rollback``    in-place recovery: engine snapshot restore (steps
                  replayed, wall seconds)
  ``recovery``    out-of-place recovery: quarantine restore (incremental /
                  full), drain + replay, golden re-execution
  ``quarantine``  a replica was pulled from service pending recovery
  ``failover``    a request was replayed on another replica
  ``replica_dead``a replica left service permanently
  ``deploy_start``a rolling weight deploy began (fleet scope): target
                  checkpoint step + changed-leaf count
  ``replica_swapped`` one replica finished its swap and re-verified clean
                  against the *new* storage checksums (rejoins the router)
  ``backup_dispatch`` a straggler's in-flight request was speculatively
                  re-issued to a warm spare (first finisher wins)

Every event carries a ``tick`` on the emitting layer's deterministic clock
(engine steps for the executor, fleet ticks for the fleet) plus provenance
fields — ``site``, ``policy``, ``replica``, ``uid``, ``fault`` — that are
empty-defaulted so the log serializes uniformly.  Wall-clock durations of
measured recoveries ride in ``seconds``; they are *data about the recovery*
(not event timestamps), so they do not break tick determinism.

``timelines()`` reconstructs injection→detection→recovery chains: each
``strike`` claims every subsequent event until the next ``strike``, which
is exact for the one-strike-per-trial campaigns that drive this log and a
good approximation everywhere else.  ``latency_summary()`` reduces the
chains to per-policy distributions — the numbers the campaign report's
timeline columns print.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional


KINDS = ("strike", "detection", "rollback", "recovery", "quarantine",
         "failover", "replica_dead", "deploy_start", "replica_swapped",
         "backup_dispatch")


@dataclasses.dataclass
class Event:
    """One dependability occurrence with full fault provenance."""
    tick: int                 # deterministic clock of the emitting layer
    kind: str                 # one of KINDS
    site: str = ""            # fault site (kv_cache / weights / …)
    policy: str = ""          # dependability policy active at emission
    fault: str = ""           # fault-model name (single_bitflip, …)
    replica: int = -1         # replica id (-1: single-engine scope)
    uid: int = -1             # request uid (-1: not request-scoped)
    seconds: float = 0.0      # measured wall duration (recoveries)
    detail: Dict = dataclasses.field(default_factory=dict)

    def to_dict(self, wall: bool = True) -> dict:
        d = {"tick": self.tick, "kind": self.kind, "site": self.site,
             "policy": self.policy, "fault": self.fault,
             "replica": self.replica, "uid": self.uid,
             "detail": dict(self.detail)}
        if wall:
            d["seconds"] = self.seconds
        return d


class EventLog:
    """Append-only dependability event log with a shared default context.

    ``ctx`` fields (e.g. ``replica=2``, ``policy="ckpt"``) are merged into
    every emitted event unless the emit call overrides them — so an engine
    embedded in a fleet replica stamps its replica id without every call
    site threading it through.
    """

    KINDS = KINDS

    def __init__(self, **ctx):
        self.events: List[Event] = []
        self.ctx = ctx

    def emit(self, kind: str, tick: int, **fields) -> Event:
        if kind not in KINDS:
            raise ValueError(f"unknown event kind {kind!r}; known: {KINDS}")
        merged = dict(self.ctx)
        merged.update(fields)
        ev = Event(tick=int(tick), kind=kind, **merged)
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def clear(self) -> None:
        self.events = []

    def drain(self) -> List[Event]:
        ev, self.events = self.events, []
        return ev

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self.events if e.kind == kind]

    # ----------------------------------------------------------- analysis
    def timelines(self) -> List[dict]:
        """Injection→detection→recovery chains: every ``strike`` claims the
        events that follow it (up to the next strike).  Latencies are tick
        deltas on the emitting layer's clock; ``recovery_seconds`` is the
        summed measured wall time of the chain's recovery actions."""
        chains: List[dict] = []
        current: Optional[dict] = None
        for ev in self.events:
            if ev.kind == "strike":
                if current is not None:
                    chains.append(current)
                current = {"strike": ev, "detection": None,
                           "recoveries": [], "events": []}
                continue
            if current is None:
                continue                       # pre-strike noise (scrub ok …)
            current["events"].append(ev)
            if ev.kind == "detection" and current["detection"] is None:
                current["detection"] = ev
            elif ev.kind in ("rollback", "recovery"):
                current["recoveries"].append(ev)
        if current is not None:
            chains.append(current)
        out = []
        for ch in chains:
            strike, det = ch["strike"], ch["detection"]
            rec = ch["recoveries"]
            out.append({
                "site": strike.site,
                "policy": strike.policy,
                "fault": strike.fault,
                "strike_tick": strike.tick,
                "detected": det is not None,
                "detection_tick": det.tick if det else None,
                "detection_latency_ticks":
                    (det.tick - strike.tick) if det else None,
                "recovered": bool(rec),
                "recovery_latency_ticks":
                    (rec[-1].tick - strike.tick) if rec else None,
                "recovery_seconds": sum(e.seconds for e in rec),
                "n_events": len(ch["events"]),
            })
        return out

    def latency_summary(self) -> Dict[str, dict]:
        """Per-policy detection/recovery latency distributions from the
        reconstructed timelines — mean/max over tick deltas plus summed
        measured recovery seconds."""
        per: Dict[str, dict] = {}
        for tl in self.timelines():
            s = per.setdefault(tl["policy"] or "?", {
                "strikes": 0, "detected": 0, "recovered": 0,
                "detection_ticks": [], "recovery_ticks": [],
                "recovery_seconds": 0.0})
            s["strikes"] += 1
            if tl["detected"]:
                s["detected"] += 1
                s["detection_ticks"].append(tl["detection_latency_ticks"])
            if tl["recovered"]:
                s["recovered"] += 1
                s["recovery_ticks"].append(tl["recovery_latency_ticks"])
                s["recovery_seconds"] += tl["recovery_seconds"]
        out = {}
        for policy, s in per.items():
            dt, rt = s["detection_ticks"], s["recovery_ticks"]
            out[policy] = {
                "strikes": s["strikes"],
                "detected": s["detected"],
                "recovered": s["recovered"],
                "detection_ticks_mean":
                    (sum(dt) / len(dt)) if dt else 0.0,
                "detection_ticks_max": max(dt) if dt else 0,
                "recovery_ticks_mean":
                    (sum(rt) / len(rt)) if rt else 0.0,
                "recovery_ticks_max": max(rt) if rt else 0,
                "recovery_seconds": s["recovery_seconds"],
            }
        return out

    # ------------------------------------------------------------- export
    def to_json(self, wall: bool = True) -> dict:
        """The event-log document: raw events + reconstructed timelines.
        ``wall=False`` strips measured wall-clock seconds so deterministic
        runs export byte-identically (report-diffing mode)."""
        doc = {"events": [e.to_dict(wall=wall) for e in self.events],
               "timelines": self.timelines(),
               "latency_summary": self.latency_summary()}
        if not wall:
            for tl in doc["timelines"]:
                tl.pop("recovery_seconds", None)
            for s in doc["latency_summary"].values():
                s.pop("recovery_seconds", None)
        return doc

    def dump(self, path, wall: bool = True) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(wall=wall), indent=2,
                                   sort_keys=True) + "\n")
        return path
