"""Metrics registry — bounded-memory counters, gauges, and histograms.

The paper's dependability claims are *measured* claims (tokens/s, detection
latency, recovery time), so the reproduction needs a measurement substrate
that is itself dependable:

  * **bounded memory** — a `Histogram` is a fixed set of bucket counters
    plus (count, sum, min, max); observing ten million request latencies
    costs the same bytes as observing ten.  This is what replaces the
    unbounded ``FleetMetrics.latencies`` / ``recovery_seconds`` lists that
    used to grow per request for the lifetime of a fleet.
  * **deterministic export** — ``Registry.snapshot()`` is a plain dict of
    plain numbers in registration order, and ``render_prometheus()`` is the
    standard text exposition; neither touches the wall clock, so two
    same-seed runs export byte-identical metrics.
  * **cheap** — instruments are attribute-access + integer adds; nothing
    allocates on the hot path.

Instruments live in a ``Registry`` so one process-wide (or one
fleet/engine-scoped) namespace can be snapshotted atomically.  Names follow
Prometheus conventions (``snake_case``, unit suffix: ``_ticks``,
``_seconds``, ``_tokens``).
"""
from __future__ import annotations

import json
import math
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple


class Counter:
    """Monotonic event count."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-observed level (queue depth, slot occupancy, replica count)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n

    def to_dict(self) -> dict:
        return {"type": self.kind, "value": self.value}


def exp_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """Exponential bucket upper bounds: start, start·f, …  (count edges)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


# default edges: wide dynamic range for both tick-valued (1..~4k) and
# seconds-valued (1e-4..~26) observations, 16 buckets + overflow
DEFAULT_BUCKETS = exp_buckets(0.0001, 4.0, 16)


class Histogram:
    """Fixed-bucket streaming histogram: O(len(buckets)) memory forever.

    ``buckets`` are inclusive upper bounds; one overflow bucket catches
    everything above the last edge.  Exact ``count``/``sum``/``min``/``max``
    ride along, so means and extrema stay exact while percentiles are
    bucket-resolution estimates (`percentile` interpolates within the
    winning bucket).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        edges = tuple(sorted(float(b) for b in buckets))
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        self.name = name
        self.help = help
        self.buckets = edges
        self.bucket_counts: List[int] = [0] * (len(edges) + 1)  # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def __len__(self) -> int:
        return self.count

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (q in [0, 100]): linear
        interpolation inside the bucket where the rank lands, clamped to
        the exact observed [min, max]."""
        if not self.count:
            return 0.0
        rank = q / 100.0 * self.count
        cum = 0
        lo = 0.0
        for i, edge in enumerate(self.buckets):
            n = self.bucket_counts[i]
            if n and cum + n >= rank:
                frac = (rank - cum) / n
                est = lo + frac * (edge - lo)
                return min(max(est, self.min), self.max)
            cum += n
            lo = edge
        return self.max

    def to_dict(self) -> dict:
        return {
            "type": self.kind,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": self.mean(),
            "buckets": [
                {"le": edge, "count": c}
                for edge, c in zip(self.buckets, self.bucket_counts)
            ] + [{"le": "+Inf", "count": self.bucket_counts[-1]}],
        }


class Registry:
    """One namespace of instruments; get-or-create semantics so layers can
    share a registry without coordinating construction order."""

    def __init__(self):
        self._instruments: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{inst.kind}, not {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def __iter__(self):
        return iter(self._instruments.values())

    # -------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """JSON-ready dict in registration order — wall-clock-free, so two
        deterministic runs snapshot byte-identically."""
        return {name: inst.to_dict()
                for name, inst in self._instruments.items()}

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (one scrape page)."""
        lines: List[str] = []
        for name, inst in self._instruments.items():
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            if isinstance(inst, Histogram):
                cum = 0
                for edge, c in zip(inst.buckets, inst.bucket_counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{edge:g}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {inst.count}')
                lines.append(f"{name}_sum {inst.sum:g}")
                lines.append(f"{name}_count {inst.count}")
            else:
                lines.append(f"{name} {inst.value:g}")
        return "\n".join(lines) + "\n"

    def dump(self, path, fmt: Optional[str] = None) -> pathlib.Path:
        """Write the snapshot: JSON by default, Prometheus text when the
        path ends in ``.prom`` (or fmt='prom')."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        if fmt == "prom" or (fmt is None and path.suffix == ".prom"):
            path.write_text(self.render_prometheus())
        else:
            path.write_text(json.dumps(self.snapshot(), indent=2,
                                       sort_keys=False) + "\n")
        return path
