"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Layer = time-mix (WKV6 recurrence) + channel-mix, both with token-shift and
Finch's low-rank data-dependent interpolation (ddlerp).

WKV6 per head (state S ∈ R^{hd×hd}):
    o_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ ,   w_t = exp(-exp(ŵ(x_t)))  ∈ (0,1)

Two implementations:
  * ``wkv_scan``    — token-recurrent `lax.scan` (oracle; also THE decode path,
                      O(1) state ⇒ the long_500k cell is runnable).
  * ``wkv_chunked`` — chunk-parallel form (training/prefill): within a chunk
                      the decay products are materialized as an attention-like
                      C×C score matrix whose entries are products of w ∈ (0,1)
                      (computed as exp of cumsum differences with a mid-chunk
                      offset for f32 range), so each chunk is dense MXU work;
                      chunks are chained by carrying S.  This is the TPU
                      adaptation of the CUDA wkv kernel: instead of a
                      per-token warp loop, reshape the recurrence into
                      matmul-sized blocks the MXU can stream — same insight
                      as the paper's "reshape conv into the XPP dataflow".
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ArchConfig
from repro.models.transformer import ForwardOut, ShardCtx, _cdt, _pdt, _w

LORA_R = 16          # ddlerp low-rank dim
DECAY_LORA_R = 32


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    d, L, V, ff = cfg.d_model, cfg.n_layers, cfg.vocab_size, cfg.d_ff
    hd = cfg.recurrent.head_dim
    H = d // hd
    pdt = _pdt(cfg)
    keys = iter(jax.random.split(key, 40))

    def stack(shape):
        return common.dense_init(next(keys), (L,) + shape, in_axis=1, dtype=pdt)

    # decay init: moderate decay so both scan and chunked paths are in a
    # healthy numeric range (trained RWKV decays live here too)
    w0 = jnp.tile(jnp.linspace(-6.0, -0.5, d)[None, :], (L, 1)).astype(pdt)

    return {
        "embed": common.embed_init(next(keys), (V, d), dtype=pdt),
        "final_norm": jnp.zeros((d,), pdt),
        "lm_head": common.dense_init(next(keys), (d, V), dtype=pdt),
        "blocks": {
            "ln1": jnp.zeros((L, d), pdt),
            "ln2": jnp.zeros((L, d), pdt),
            # ddlerp
            "mu_x": jnp.zeros((L, d), pdt),
            "mu": jnp.zeros((L, 5, d), pdt),            # per {w,k,v,r,g}
            "ddl_A": stack((d, 5 * LORA_R)),
            "ddl_B": stack((5, LORA_R, d)) * 0.0,
            # time-mix projections
            "wr": stack((d, d)),
            "wk": stack((d, d)),
            "wv": stack((d, d)),
            "wg": stack((d, d)),
            "wo": stack((d, d)),
            # decay
            "w0": w0,
            "dec_A": stack((d, DECAY_LORA_R)),
            "dec_B": stack((DECAY_LORA_R, d)) * 0.0,
            "u": jnp.zeros((L, H, hd), pdt),
            "ln_x": jnp.zeros((L, d), pdt),             # per-head group norm scale
            # channel-mix
            "cm_mu_k": jnp.zeros((L, d), pdt),
            "cm_mu_r": jnp.zeros((L, d), pdt),
            "cm_wk": stack((d, ff)),
            "cm_wv": stack((ff, d)),
            "cm_wr": stack((d, d)),
        },
    }


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------


def wkv_scan(r, k, v, w, u, s0=None):
    """Token-recurrent oracle. r,k,v,w: (B, T, H, hd) f32; u: (H, hd).

    Returns (o (B,T,H,hd), s_final (B,H,hd,hd))."""
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (B, H, hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B, H, hd, hd)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), s


def wkv_chunked(r, k, v, w, u, s0=None, chunk: int = 32):
    """Chunk-parallel WKV6. Same contract as wkv_scan (f32 inputs)."""
    B, T, H, hd = r.shape
    C = min(chunk, T)
    n = -(-T // C)
    Tp = n * C
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, pad) for t in (r, k, v))
        w = jnp.pad(w, pad, constant_values=1.0)        # pad decay = identity
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    # (n, B, C, H, hd)
    rc, kc, vc, wc = (t.reshape(B, n, C, H, hd).transpose(1, 0, 2, 3, 4)
                      for t in (r, k, v, w))

    def chunk_body(s, inp):
        rr, kk, vv, ww = inp                            # (B, C, H, hd)
        lw = jnp.log(jnp.maximum(ww, 1e-24))            # ≤ 0
        L = jnp.cumsum(lw, axis=1)                      # inclusive
        E = L - lw                                      # exclusive
        mid = L[:, -1:, :, :] * 0.5                     # per-channel offset
        r_s = rr * jnp.exp(E - mid)                     # bounded by exp(|Lc|/2)
        k_s = kk * jnp.exp(mid - L)
        # intra-chunk scores s[t, i] = Σ_c r_s[t, c] k_s[i, c]  (strict lower tri)
        scores = jnp.einsum("bthc,bihc->bhti", r_s, k_s)
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        o = jnp.einsum("bhti,bihv->bthv", scores, vv)
        # current-token bonus
        o += jnp.einsum("bthc,bthc,bthv->bthv", rr * u[None, None], kk, vv)
        # inter-chunk: o_t += (r ⊙ Π_{j<t} w) · S0
        o += jnp.einsum("bthk,bhkv->bthv", rr * jnp.exp(E), s)
        # state to next chunk: S = diag(ΠW) S0 + Σ_i (Π_{j>i} w ⊙ k_i) v_iᵀ
        decay_all = jnp.exp(L[:, -1])                   # (B, H, hd)
        k_tail = kk * jnp.exp(L[:, -1:, :, :] - L)      # Π_{j>i} w  ≤ 1
        s = decay_all[..., :, None] * s + jnp.einsum("bihk,bihv->bhkv", k_tail, vv)
        return s, o

    s, o = jax.lax.scan(chunk_body, s0, (rc, kc, vc, wc))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, hd)
    return o[:, :T], s


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _ddlerp(bp, x, xx):
    """Finch data-dependent interpolation → 5 mixed inputs (w,k,v,r,g)."""
    diff = xx - x
    x_mix = x + diff * bp["mu_x"]
    lo = jnp.tanh(x_mix @ bp["ddl_A"])                  # (B,T,5R)
    B_, T_, _ = lo.shape
    lo = lo.reshape(B_, T_, 5, LORA_R)
    delta = jnp.einsum("btfr,frd->btfd", lo, bp["ddl_B"])
    mixed = x[:, :, None] + diff[:, :, None] * (bp["mu"][None, None] + delta)
    return [mixed[:, :, i] for i in range(5)]           # w,k,v,r,g


def _time_mix(cfg, bp, x, use_chunked: bool, state=None):
    """x: (B, T, d). state: (x_prev (B,d), S (B,H,hd,hd)) for decode chaining."""
    B, T, d = x.shape
    hd = cfg.recurrent.head_dim
    H = d // hd
    h = common.rms_norm(x, bp["ln1"], cfg.norm_eps)
    x_prev = state[0] if state is not None else jnp.zeros((B, d), h.dtype)
    xx = jnp.concatenate([x_prev[:, None], h[:, :-1]], axis=1)   # token shift
    xw, xk, xv, xr, xg = _ddlerp(bp, h, xx)

    r = (xr @ bp["wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ bp["wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ bp["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ bp["wg"])

    logw = bp["w0"][None, None] + jnp.tanh(xw @ bp["dec_A"]) @ bp["dec_B"]
    w = jnp.exp(-jnp.exp(logw.astype(jnp.float32))).reshape(B, T, H, hd)
    u = bp["u"].astype(jnp.float32)

    s0 = state[1] if state is not None else None
    if use_chunked and T > 1:
        o, s = wkv_chunked(r, k, v, w, u, s0)
    else:
        o, s = wkv_scan(r, k, v, w, u, s0)

    # per-head group norm
    o = o.reshape(B, T, H, hd)
    o = common.rms_norm(o, bp["ln_x"].reshape(H, hd), cfg.norm_eps)
    o = o.reshape(B, T, d).astype(x.dtype) * g
    out = x + o @ bp["wo"]
    return out, (h[:, -1], s)


def _channel_mix(cfg, bp, x, state=None):
    B, T, d = x.shape
    h = common.rms_norm(x, bp["ln2"], cfg.norm_eps)
    x_prev = state if state is not None else jnp.zeros((B, d), h.dtype)
    xx = jnp.concatenate([x_prev[:, None], h[:, :-1]], axis=1)
    xk = h + (xx - h) * bp["cm_mu_k"]
    xr = h + (xx - h) * bp["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ bp["cm_wk"]))
    out = jax.nn.sigmoid(xr @ bp["cm_wr"]) * (kk @ bp["cm_wv"])
    return x + out, h[:, -1]


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _cast_block(cfg, bp):
    return jax.tree_util.tree_map(lambda w: w.astype(_cdt(cfg)), bp)


def forward(cfg: ArchConfig, params, tokens: jax.Array,
            ctx: Optional[ShardCtx] = None,
            embeds: Optional[jax.Array] = None) -> ForwardOut:
    x = (embeds if embeds is not None else params["embed"][tokens]).astype(_cdt(cfg))

    def body(x, bp):
        bp = _cast_block(cfg, bp)
        x, _ = _time_mix(cfg, bp, x, use_chunked=True)
        x, _ = _channel_mix(cfg, bp, x)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    z = jnp.zeros((), jnp.float32)
    return ForwardOut(logits, z, z)


def loss_fn(cfg, params, batch, ctx=None):
    out = forward(cfg, params, batch["tokens"], ctx, embeds=batch.get("embeds"))
    loss = common.cross_entropy_loss(out.logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss}


class RwkvCache(NamedTuple):
    tm_x: jax.Array       # (L, B, d)   time-mix shift state
    tm_s: jax.Array       # (L, B, H, hd, hd) wkv state
    cm_x: jax.Array       # (L, B, d)   channel-mix shift state
    length: jax.Array


def init_cache(cfg: ArchConfig, B: int, max_len: int, dtype=None) -> RwkvCache:
    dtype = dtype or _cdt(cfg)
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.recurrent.head_dim
    H = d // hd
    return RwkvCache(jnp.zeros((L, B, d), dtype),
                     jnp.zeros((L, B, H, hd, hd), jnp.float32),
                     jnp.zeros((L, B, d), dtype),
                     jnp.zeros((), jnp.int32))


def decode_step(cfg, params, token, cache: RwkvCache,
                ctx: Optional[ShardCtx] = None,
                embed: Optional[jax.Array] = None):
    x = (embed if embed is not None else params["embed"][token])
    x = x[:, None, :].astype(_cdt(cfg))

    def body(x, layer):
        bp, tmx, tms, cmx = layer
        bp = _cast_block(cfg, bp)
        x, (tmx, tms) = _time_mix(cfg, bp, x, use_chunked=False, state=(tmx, tms))
        x, cmx = _channel_mix(cfg, bp, x, state=cmx)
        return x, (tmx, tms, cmx)

    x, (tmx, tms, cmx) = jax.lax.scan(
        body, x, (params["blocks"], cache.tm_x, cache.tm_s, cache.cm_x))
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"].astype(x.dtype))[:, 0]
    return logits, RwkvCache(tmx, tms, cmx, cache.length + 1)


def prefill(cfg, params, tokens, max_len: int, ctx=None, embeds=None):
    """Chunked forward that also returns the recurrent state as the cache."""
    x = (embeds if embeds is not None else params["embed"][tokens]).astype(_cdt(cfg))
    B, S = x.shape[:2]

    def body(x, bp):
        bp = _cast_block(cfg, bp)
        x, (tmx, tms) = _time_mix(cfg, bp, x, use_chunked=True)
        x, cmx = _channel_mix(cfg, bp, x)
        return x, (tmx, tms, cmx)

    x, (tmx, tms, cmx) = jax.lax.scan(body, x, params["blocks"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, RwkvCache(tmx, tms, cmx, jnp.asarray(S, jnp.int32))
