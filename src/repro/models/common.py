"""Shared model building blocks: norms, RoPE, attention (chunked-causal,
GQA, sliding-window), losses, initializers.

Everything is functional: params are plain pytrees (dicts of arrays), modules
are pure functions.  Compute dtype is bf16 by default with f32 for norms,
softmax and the loss — the MaxText-style mixed-precision recipe.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return y.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — chunked causal (flash-style online softmax in pure JAX)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_causal_attention(
    q: jax.Array,             # (B, S, H, hd)
    k: jax.Array,             # (B, S, KV, hd)
    v: jax.Array,             # (B, S, KV, hd)
    *,
    window: Optional[int] = None,   # sliding-window size (None = full causal)
    chunk: int = 512,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Memory-bounded causal attention with GQA and optional sliding window.

    Never materializes the (S, S) score matrix: iterates KV chunks per Q
    chunk with an online-softmax carry — the pure-JAX rendition of flash
    attention (the Pallas TPU kernel in kernels/flashattn specializes this).
    Peak live memory is O(S·chunk) per head instead of O(S²).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    chunk = min(chunk, S)
    n = -(-S // chunk)
    Sp = n * chunk
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    scale = 1.0 / math.sqrt(hd)
    # (n, B, C, KV, G, hd) queries / (n, B, C, KV, hd) keys
    qc = q.reshape(B, n, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, n, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    idx = jnp.arange(chunk)

    # NOTE: chunk indices (qi, kj) are threaded as loop CARRIES, not scan
    # inputs.  If they were scan inputs, XLA hoists the per-pair masks out of
    # both loops and materializes a (n, n, B, C, KV, C) boolean tensor —
    # tens of GB at production shapes.  Carry-derived values cannot be
    # hoisted, so the mask stays a (C, C) transient inside the loop body.
    def q_chunk_body(qi, q_i):
        def kv_body(carry, inputs):
            kv_idx, m_prev, l_prev, acc = carry
            kj, vj = inputs
            # scores: (B, C_q, KV, G, C_k).  Operands stay in the compute
            # dtype (bf16 on the MXU fast path — half the HBM traffic per
            # materialized chunk); accumulation is always f32 via
            # preferred_element_type, so the online softmax is stable.
            s = jnp.einsum("bqkgh,bckh->bqkgc", q_i, kj,
                           preferred_element_type=jnp.float32) * scale
            q_pos = qi * chunk + idx                       # (C_q,)
            k_pos = kv_idx * chunk + idx                   # (C_k,)
            mask = q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            # p is post-max-subtraction (≤ 1), safe to carry at the compute
            # dtype into the PV matmul (the flash-kernel convention)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p.astype(q_i.dtype), vj,
                preferred_element_type=jnp.float32)
            return (kv_idx + 1, m_new, l_new, acc), None

        m0 = jnp.full((B, chunk, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, chunk, KV, G), jnp.float32)
        a0 = jnp.zeros((B, chunk, KV, G, hd), jnp.float32)
        (_, m, l, acc), _ = jax.lax.scan(
            kv_body, (jnp.zeros((), jnp.int32), m0, l0, a0), (kc, vc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    # remat per q-chunk: backward recomputes the kv sweep for one chunk at a
    # time instead of stacking all (nq × nk) score residuals — O(S·C) peak
    # attention memory, the flash-attention recipe expressed through remat.
    q_chunk_body = jax.checkpoint(
        q_chunk_body, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False)

    def q_scan_body(qi, q_i):
        return qi + 1, q_chunk_body(qi, q_i)

    _, out = jax.lax.scan(q_scan_body, jnp.zeros((), jnp.int32), qc)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, H, hd)
    return out[:, :S]


def decode_attention(
    q: jax.Array,             # (B, 1, H, hd)
    k_cache: jax.Array,       # (B, T, KV, hd) — compute dtype or int8
    v_cache: jax.Array,       # (B, T, KV, hd)
    cur_len: jax.Array,       # (B,) or scalar — number of valid cache slots
    k_scale: Optional[jax.Array] = None,   # (B, T, KV) int8-KV scales
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention over a (ring-buffered) KV cache."""
    B, T, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    if k_scale is not None:
        # int8 KV: quantize q per (b, kv, g) row, int8×int8→int32 on the MXU,
        # rescale by q-scale × per-row k-scale.  V dequantizes at page level
        # (probabilities carry per-T structure that can't fold into the dot).
        q_s = jnp.max(jnp.abs(qg.astype(jnp.float32)), axis=-1)
        q_s = jnp.maximum(q_s, 1e-8) / 127.0
        q_q = jnp.clip(jnp.round(qg.astype(jnp.float32) / q_s[..., None]),
                       -127, 127).astype(jnp.int8)
        s32 = jnp.einsum("bkgh,btkh->bkgt", q_q, k_cache,
                         preferred_element_type=jnp.int32)
        ks_t = jnp.moveaxis(k_scale, 1, 2)[:, :, None, :]       # (B, KV, 1, T)
        s = s32.astype(jnp.float32) * q_s[..., None] * ks_t * scale
        v_cache = (v_cache.astype(jnp.float32)
                   * v_scale[..., None]).astype(q.dtype)
    else:
        # operands stay in the cache dtype (bf16): no f32 copy of the (T,·)
        # cache pages — accumulation is f32 via preferred_element_type
        # (decode is cache-read bound; an astype would double the traffic)
        s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(T)[None, :] < jnp.broadcast_to(jnp.atleast_1d(cur_len), (B,))[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Slot-wise cache plumbing (continuous batching)
# ---------------------------------------------------------------------------


def cache_write_slot(batch_cache, one_cache, slot: int, n: int):
    """Copy a single-request prefill cache into row ``slot`` of a batch
    cache — the splice that lets a request join a live decode batch without
    re-padding or draining its neighbors (runtime/dataflow DecodeStage).

    Works on any family's cache pytree: leaves are (L, B, T, ...) for KV or
    (L, B, ...) for recurrent state (batch at dim 1); per-row length vectors
    are (B,) int (batch at dim 0, set to ``n``); scalar counters are maxed.
    """
    def write(bc, oc):
        if bc.ndim == 0:
            return jnp.maximum(bc, oc)
        if bc.ndim == 1 and jnp.issubdtype(bc.dtype, jnp.integer):
            return bc.at[slot].set(n)          # per-row length vector
        # one_cache leaf has batch=1 at dim 1
        row = jax.lax.dynamic_slice_in_dim(oc, 0, 1, axis=1)
        if bc.ndim >= 3 and bc.shape[2] != row.shape[2]:
            # time-indexed leaf with different max_len: copy the prefix
            pad = [(0, 0)] * row.ndim
            pad[2] = (0, bc.shape[2] - row.shape[2])
            row = jnp.pad(row, pad)
        return jax.lax.dynamic_update_slice_in_dim(bc, row.astype(bc.dtype),
                                                   slot, axis=1)

    return jax.tree_util.tree_map(write, batch_cache, one_cache)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (B, S, V) any float dtype, labels (B, S) i32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
