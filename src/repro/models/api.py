"""Family-dispatching model API.

Every launcher / test / benchmark talks to models through these five
functions; the family field of the ArchConfig picks the implementation.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.models.config import ArchConfig
from repro.models import transformer, rwkv6, griffin


def _mod(cfg: ArchConfig):
    if cfg.family == "transformer":
        return transformer
    if cfg.family == "rwkv":
        return rwkv6
    if cfg.family == "hybrid":
        return griffin
    raise ValueError(f"unknown family {cfg.family!r} (cnn goes through models/shipdet.py)")


def init_params(cfg, key):
    return _mod(cfg).init_params(cfg, key)


def forward(cfg, params, tokens, ctx=None, embeds=None):
    return _mod(cfg).forward(cfg, params, tokens, ctx, embeds=embeds)


def loss_fn(cfg, params, batch, ctx=None):
    return _mod(cfg).loss_fn(cfg, params, batch, ctx)


def init_cache(cfg, B, max_len, dtype=None):
    return _mod(cfg).init_cache(cfg, B, max_len, dtype)


def decode_step(cfg, params, token, cache, ctx=None, embed=None):
    return _mod(cfg).decode_step(cfg, params, token, cache, ctx, embed=embed)


def prefill(cfg, params, tokens, max_len, ctx=None, embeds=None):
    return _mod(cfg).prefill(cfg, params, tokens, max_len, ctx, embeds=embeds)
