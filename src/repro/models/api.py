"""Family-dispatching model API.

Every launcher / test / benchmark talks to models through these five
functions; the family field of the ArchConfig picks the implementation.

The quantized hot paths inside every family (W8A8 FFN matmuls, the CNN's
qconv layers) are built on the pluggable execution-backend registry
(core/backend.py): ``cfg.backend`` is the per-layer selection rung, so an
engine or fleet swaps the whole model zoo between the jnp path and the
Pallas kernel path with ``with_backend(cfg, "pallas")`` — no model code
changes, exactly the paper's "no hardware-specific coding" property.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.models.config import ArchConfig
from repro.models import transformer, rwkv6, griffin


def with_backend(cfg: ArchConfig, backend: Optional[str]) -> ArchConfig:
    """The config with its quantized-primitive execution backend pinned
    (validated against the registry); None leaves the config untouched —
    an unpinned config (cfg.backend is None) follows the global default."""
    if backend is None or backend == cfg.backend:
        return cfg
    from repro.core import backend as backend_mod
    backend_mod.get_backend(backend)
    return dataclasses.replace(cfg, backend=backend)


def with_policy_map(cfg: ArchConfig, policy_map) -> ArchConfig:
    """The config with a per-site dependability policy map baked in
    (core/policy_map.py): the quantized FFN matmuls resolve ``ffn.<name>``
    through it in-graph.  Accepts a PolicyMap, a JSON doc/text/path
    (``as_policy_map`` coercions), or None (config untouched).  Every
    backend the map names is validated against the registry up front, so a
    typo fails at configuration time rather than inside a jit trace."""
    from repro.core.policy_map import as_policy_map
    pm = as_policy_map(policy_map)
    if pm is None or pm == cfg.policy_map:
        return cfg
    from repro.core import backend as backend_mod
    for name in pm.backends():
        backend_mod.get_backend(name)
    return dataclasses.replace(cfg, policy_map=pm)


def _mod(cfg: ArchConfig):
    if cfg.family == "transformer":
        return transformer
    if cfg.family == "rwkv":
        return rwkv6
    if cfg.family == "hybrid":
        return griffin
    raise ValueError(f"unknown family {cfg.family!r} (cnn goes through models/shipdet.py)")


def init_params(cfg, key):
    return _mod(cfg).init_params(cfg, key)


def forward(cfg, params, tokens, ctx=None, embeds=None):
    return _mod(cfg).forward(cfg, params, tokens, ctx, embeds=embeds)


def loss_fn(cfg, params, batch, ctx=None):
    return _mod(cfg).loss_fn(cfg, params, batch, ctx)


def init_cache(cfg, B, max_len, dtype=None):
    return _mod(cfg).init_cache(cfg, B, max_len, dtype)


def decode_step(cfg, params, token, cache, ctx=None, embed=None):
    return _mod(cfg).decode_step(cfg, params, token, cache, ctx, embed=embed)


def prefill(cfg, params, tokens, max_len, ctx=None, embeds=None):
    return _mod(cfg).prefill(cfg, params, tokens, max_len, ctx, embeds=embeds)


def cache_write_slot(batch_cache, one_cache, slot, n):
    """Splice a single-request prefill cache into row ``slot`` of a batch
    cache (family-agnostic pytree surgery; see models/common.py) — the
    slot-granular state handling continuous batching is built on."""
    from repro.models import common
    return common.cache_write_slot(batch_cache, one_cache, slot, n)
