"""Architecture configuration schema.

One ``ArchConfig`` fully describes a model in the zoo.  The 10 assigned
architectures (src/repro/configs/) plus the paper's own ship-detection CNN
are all instances of this schema; ``reduced()`` derives the CPU-smoke-test
variant of any config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:
    from repro.core.policy_map import PolicyMap


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    n_shared_experts: int = 0      # always-on shared experts (Kimi K2 style)
    n_dense_layers: int = 0        # leading layers that stay dense
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """For SSM (rwkv6) and hybrid (recurrentgemma) families."""
    kind: str                      # "rwkv6" | "rglru"
    d_conv: int = 4                # griffin conv1d width
    lru_width: Optional[int] = None
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    attn_window: int = 2048
    head_dim: int = 64             # rwkv6 head size


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # "transformer" | "rwkv" | "hybrid" | "cnn"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qk_norm: bool = False
    swa_window: Optional[int] = None        # sliding-window attention
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    input_mode: str = "tokens"              # "tokens" | "embeddings" (audio/vlm stubs)
    sub_quadratic: bool = False             # True ⇒ long_500k cell is runnable
    # distribution hints
    fsdp_params: bool = False               # shard weights over the data axis too
    layout: str = "tp"                      # "tp" (model axis = tensor/expert
                                            # parallel) | "dp" (model axis is
                                            # extra data parallelism — right
                                            # call for small archs whose heads
                                            # don't divide the model axis)
    seq_shard: bool = False                 # sequence parallelism: shard the
                                            # seq dim of inter-block
                                            # activations over the model axis
                                            # (turns TP activation all-reduce
                                            # into reduce-scatter+all-gather,
                                            # halving collective bytes)
    param_dtype: str = "float32"            # "float32" | "bfloat16"
    compute_dtype: str = "bfloat16"         # activation/matmul dtype
    optimizer: str = "adamw"                # "adamw" | "adafactor"
    remat: str = "save_dots"                # "none" | "save_dots" | "full"
    grad_accum: int = 1                     # microbatches per step (activation
                                            # memory ÷ grad_accum; the lever
                                            # that makes 405B @ 4k seq fit
                                            # 16 GB HBM)
    quant: str = "none"                     # "none" | "w8a8_ffn" (the paper's
                                            # int8 technique on FFN/expert
                                            # weights+activations)
    quant_kv: bool = False                  # int8 KV cache with per-row
                                            # scales (serving: halves cache
                                            # reads vs bf16)
    attn_impl: str = "chunked"              # "chunked" (jnp online-softmax)
                                            # | "flash" (Pallas fwd+bwd
                                            # kernels; scores never in HBM)
    policy_map: Optional["PolicyMap"] = None   # per-site dependability
                                            # assignment (core/policy_map.py)
                                            # for the quantized hot paths:
                                            # ``ffn.*`` matmul sites resolve
                                            # through it in-graph.  None ⇒
                                            # legacy unprotected path,
                                            # byte-identical dispatch.  Set
                                            # via models.api.with_policy_map
                                            # (validates rule backends)
    backend: Optional[str] = None           # execution backend for the
                                            # quantized primitives ("jnp" |
                                            # "ref" | "pallas" — the
                                            # core/backend.py registry); the
                                            # per-layer rung of the selection
                                            # ladder.  None (default) defers
                                            # to the global default, so
                                            # use_backend scopes still reach
                                            # models that never pinned one
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory napkin math)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "rwkv":
            attn = 4 * d * d + d * d // 2   # r,k,v,g,o + low-rank adapters (approx)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.moe is not None:
            m = self.moe
            dense_ffn = 3 * d * self.d_ff * m.n_dense_layers
            shared = 3 * d * m.d_expert * m.n_shared_experts * (L - m.n_dense_layers)
            routed = 3 * d * m.d_expert * m.n_experts * (L - m.n_dense_layers)
            router = d * m.n_experts * (L - m.n_dense_layers)
            ffn = dense_ffn + shared + routed + router
        else:
            ffn = 3 * d * self.d_ff * L
        return attn * L + ffn + embed + 2 * d * L + d

    def active_param_count(self) -> int:
        """Active-per-token params (MoE: top-k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        m = self.moe
        full = self.param_count()
        routed_all = 3 * d * m.d_expert * m.n_experts * (L - m.n_dense_layers)
        routed_active = 3 * d * m.d_expert * m.top_k * (L - m.n_dense_layers)
        return full - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def valid_cells(cfg: ArchConfig):
    """The (arch × shape) cells this config runs; long_500k needs sub-quadratic."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small same-family variant for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_expert=32,
            n_dense_layers=min(cfg.moe.n_dense_layers, 1))
    if cfg.recurrent is not None:
        kw["recurrent"] = dataclasses.replace(
            cfg.recurrent, head_dim=8, attn_window=16,
            lru_width=64 if cfg.recurrent.lru_width else None)
    if cfg.swa_window is not None:
        kw["swa_window"] = 16
    return dataclasses.replace(cfg, **kw)
