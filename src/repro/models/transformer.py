"""Unified decoder-only transformer LM.

One parameterized implementation covers 8 of the 10 assigned architectures:
dense GQA (smollm, qwen3 w/ qk-norm, command-r+, llama3-405b), MoE (mixtral
8×7b w/ SWA, kimi-k2 384-expert w/ shared expert + leading dense layer), and
the embedding-input backbones (musicgen, llava-next).

Structure:
  * params are plain pytrees; layers are stacked on a leading axis and the
    forward pass is a `lax.scan` over them — 126-layer llama405b lowers to the
    same compact HLO as 2-layer smollm (essential for 512-device dry-run
    compile times).
  * attention is the chunked online-softmax from models/common.py (never
    materializes S×S).
  * the routed-expert FFN runs inside `shard_map` (explicit EP over the model
    axis + FSDP all-gather of expert weights over the data axes), because
    sort-and-scatter token routing is something GSPMD cannot be trusted to
    partition well — see DESIGN.md §6.  Everything else is GSPMD (pjit +
    sharding constraints).
  * quantized serving: every linear can execute as W8A8 int8 (the paper's
    technique) via `quant_mode="int8"` — weights are pre-quantized once
    (`quantize_params`) and matmuls run int8×int8→int32 on the MXU with a
    fused dequant epilogue.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.models.config import ArchConfig
from repro.core import quant


class ShardCtx(NamedTuple):
    """Mesh context threaded through model code.

    dp: tuple of data-parallel mesh axis names (("data",) or ("pod", "data")).
    model: the tensor/expert-parallel axis name.
    mesh: the jax Mesh (required for the shard_map MoE block).
    batch: axes the *activation batch* shards over. Defaults to ``dp``;
      set to ``()`` when global_batch isn't divisible by the dp extent
      (e.g. long_500k decode with batch=1) — weights stay FSDP over ``dp``
      while activations replicate.
    """
    mesh: Any
    dp: Tuple[str, ...] = ("data",)
    model: str = "model"
    batch: Any = None                    # None → same as dp

    @property
    def batch_axes(self):
        """Activation-batch mesh axes; None (replicated) if empty."""
        b = self.dp if self.batch is None else self.batch
        return b or None

    @property
    def dp_size(self) -> int:
        return int(__import__("numpy").prod([self.mesh.shape[a] for a in self.dp]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model])


def _pdt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def _cdt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def _w(cfg: ArchConfig, w):
    """Cast a weight to the compute dtype at point of use."""
    return w.astype(_cdt(cfg))


# ------------------------- W8A8 (the paper's technique) --------------------
#
# cfg.quant == "w8a8_ffn" stores every FFN / expert weight as int8 with a
# per-output-channel scale and runs the matmul as int8×int8→int32 with a
# fused float rescale (Jacob et al., the paper's conv+requant scheme applied
# to the transformer's matmul-shaped hot spot).  On the TPU MXU the int8
# path doubles peak FLOPs and quarters weight HBM traffic vs f32.


def quantize_ffn_weight(w: jax.Array):
    """Per-channel symmetric int8 over the contraction dim (axis -2).

    (..., K, N) → int8 (..., K, N), f32 scale (..., N).  Works on stacked
    (L, ..., K, N) weights — scales stay per-(layer, channel).
    """
    a = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
    scale = jnp.maximum(a, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[..., None, :]),
                   -127, 127).astype(jnp.int8)
    return w_q, scale


_FFN_WEIGHTS = ("wi", "wg", "wd", "we_g", "we_i", "we_o", "ws_g", "ws_i",
                "ws_o")


def quantize_ffn_params(cfg: ArchConfig, params):
    """Replace FFN weight leaves with {name}_q int8 + {name}_s f32 scales."""
    def conv_block(bp):
        if bp is None:
            return None
        out = dict(bp)
        for name in _FFN_WEIGHTS:
            if name in out:
                w_q, w_s = quantize_ffn_weight(out.pop(name))
                out[name + "_q"] = w_q
                out[name + "_s"] = w_s
        return out

    p = dict(params)
    for blk in ("dense_blocks", "moe_blocks"):
        if p.get(blk) is not None:
            p[blk] = conv_block(p[blk])
    return p


def _quantize_act(x):
    """Dynamic symmetric per-row int8 activation quant (serving-style)."""
    x_s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    x_s = jnp.maximum(x_s, 1e-8) / 127.0
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / x_s),
                   -127, 127).astype(jnp.int8)
    return x_q, x_s


def _qdot(cfg: ArchConfig, x, bp, name):
    """x @ W[name], W8A8 when quantized params are present.

    The int8 accumulator comes from the execution-backend registry
    (``cfg.backend``): jnp dot_general by default, the Pallas qmatmul
    kernel when the config asks for the co-processor path.  Bit-identical
    either way (integer accumulation, exact mod 2^32).

    With ``cfg.policy_map`` set, the site ``ffn.<name>`` resolves to a
    dependability policy (and optionally a backend) and the accumulator
    runs through ``dependable_matmul_acc`` — selective hardening of the
    FFN hot path.  Clean-path outputs stay bit-identical to the unmapped
    dispatch for every policy (exact integer checks never fire); the scan
    over layers means the assignment is per-matmul-name, uniform across
    the layer stack (see core/policy_map.py)."""
    if name + "_q" in bp:
        from repro.kernels import dispatch
        x_q, x_s = _quantize_act(x)
        w_q = bp[name + "_q"]
        lead = x_q.shape[:-1]
        x2 = x_q.reshape(-1, x_q.shape[-1])
        if cfg.policy_map is not None:
            from repro.core import dependability as dep
            pol, pm_backend = cfg.policy_map.resolve("ffn." + name)
            be = pm_backend or cfg.backend
            if pol is dep.Policy.NONE:
                acc = dispatch.matmul_acc(x2, w_q, backend=be)
            else:
                acc, _ = dep.dependable_matmul_acc(pol, x2, w_q, backend=be)
        else:
            acc = dispatch.matmul_acc(x2, w_q, backend=cfg.backend)
        acc = acc.reshape(*lead, w_q.shape[-1])
        y = acc.astype(jnp.float32) * x_s * bp[name + "_s"]
        return y.astype(x.dtype)
    return x @ _w(cfg, bp[name])


def _qeinsum(cfg: ArchConfig, spec, x, bp, name):
    """Expert einsum (ecd,edf->ecf / ecf,efd->ecd), W8A8 when quantized."""
    if name + "_q" in bp:
        x_q, x_s = _quantize_act(x)              # (E, C, K), (E, C, 1)
        acc = jnp.einsum(spec, x_q, bp[name + "_q"],
                         preferred_element_type=jnp.int32)
        y = acc.astype(jnp.float32) * x_s * bp[name + "_s"][..., None, :]
        return y.astype(x.dtype)
    return jnp.einsum(spec, x, _w(cfg, bp[name]))


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    """Build the parameter pytree. Layers stacked on axis 0 for scan."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, ff, V = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size
    pdt = _pdt(cfg)
    keys = iter(jax.random.split(key, 64))

    def dense(shape, k=None):
        return common.dense_init(next(keys) if k is None else k, shape, dtype=pdt)

    def stack(shape, n):
        return common.dense_init(next(keys), (n,) + shape, in_axis=1, dtype=pdt)

    n_moe = 0
    n_dense = cfg.n_layers
    if cfg.moe is not None:
        n_moe = cfg.n_layers - cfg.moe.n_dense_layers
        n_dense = cfg.moe.n_dense_layers

    def block_params(n, moe: bool):
        if n == 0:
            return None
        p = {
            "ln1": jnp.zeros((n, d), pdt),
            "ln2": jnp.zeros((n, d), pdt),
            "wq": stack((d, H * hd), n),
            "wk": stack((d, KV * hd), n),
            "wv": stack((d, KV * hd), n),
            "wo": stack((H * hd, d), n),
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((n, hd), pdt)
            p["k_norm"] = jnp.zeros((n, hd), pdt)
        if cfg.use_bias:
            p["bq"] = jnp.zeros((n, H * hd), pdt)
            p["bk"] = jnp.zeros((n, KV * hd), pdt)
            p["bv"] = jnp.zeros((n, KV * hd), pdt)
        if not moe:
            p.update({
                "wi": stack((d, ff), n),
                "wg": stack((d, ff), n),
                "wd": stack((ff, d), n),
            })
        else:
            m = cfg.moe
            p.update({
                "router": stack((d, m.n_experts), n).astype(jnp.float32),
                "we_g": stack((m.n_experts, d, m.d_expert), n),
                "we_i": stack((m.n_experts, d, m.d_expert), n),
                "we_o": stack((m.n_experts, m.d_expert, d), n),
            })
            if m.n_shared_experts:
                ds = m.d_expert * m.n_shared_experts
                p.update({
                    "ws_g": stack((d, ds), n),
                    "ws_i": stack((d, ds), n),
                    "ws_o": stack((ds, d), n),
                })
        return p

    params = {
        "embed": common.embed_init(next(keys), (V, d), dtype=pdt),
        "final_norm": jnp.zeros((d,), pdt),
        "dense_blocks": block_params(n_dense, moe=False),
        "moe_blocks": block_params(n_moe, moe=True),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense((d, V))
    params = {k: v for k, v in params.items() if v is not None}
    if cfg.quant == "w8a8_ffn":
        params = quantize_ffn_params(cfg, params)
    return params


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _attention(cfg: ArchConfig, bp, x, positions, ctx: Optional[ShardCtx]):
    """Pre-norm GQA attention (full-sequence / training / prefill)."""
    B, S, d = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    h = common.rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = h @ _w(cfg, bp["wq"])
    k = h @ _w(cfg, bp["wk"])
    v = h @ _w(cfg, bp["wv"])
    if cfg.use_bias:
        q, k, v = q + _w(cfg, bp["bq"]), k + _w(cfg, bp["bk"]), v + _w(cfg, bp["bv"])
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, bp["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, bp["k_norm"], cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        # TP over heads only when they divide the model axis; GQA KV heads
        # (usually 8 < model=16) stay replicated over model — the MaxText
        # recipe for TP > n_kv_heads.
        msize = ctx.model_size
        bax = ctx.batch_axes
        # no head sharding when the model axis is folded into dp (layout=dp)
        tp_ok = ctx.model not in ctx.dp
        qspec = P(bax, None, ctx.model, None) if H % msize == 0 and tp_ok \
            else P(bax, None, None, None)
        kvspec = P(bax, None, ctx.model, None) if KV % msize == 0 and tp_ok \
            else P(bax, None, None, None)
        q = jax.lax.with_sharding_constraint(q, jax.sharding.NamedSharding(ctx.mesh, qspec))
        k = jax.lax.with_sharding_constraint(k, jax.sharding.NamedSharding(ctx.mesh, kvspec))
        v = jax.lax.with_sharding_constraint(v, jax.sharding.NamedSharding(ctx.mesh, kvspec))
    o = _attention_core(cfg, q, k, v, positions, ctx)
    return x + o.reshape(B, S, H * hd) @ _w(cfg, bp["wo"])


def _attention_core(cfg: ArchConfig, q, k, v, positions, ctx):
    """Dispatch chunked-jnp vs Pallas flash (fwd+bwd kernels).

    Flash under a mesh runs inside shard_map — attention is batch/head
    parallel, so the body needs no collectives; heads shard over the model
    axis when they divide it (same rule as the constraint above), otherwise
    the kernel runs replicated over model (layout="dp" folds it into batch).
    """
    if cfg.attn_impl != "flash":
        return common.chunked_causal_attention(q, k, v, window=cfg.swa_window,
                                               positions=positions)
    from repro.kernels.flashattn.ops import flash_attn_model
    if ctx is None:
        return flash_attn_model(q, k, v, window=cfg.swa_window)

    from repro.compat import shard_map
    H, KV = cfg.n_heads, cfg.n_kv_heads
    msize = ctx.model_size
    tp_ok = (ctx.model not in ctx.dp and H % msize == 0 and KV % msize == 0)
    hax = ctx.model if tp_ok else None
    bax = ctx.batch_axes
    qs = P(bax, None, hax, None)
    fn = shard_map(
        lambda q, k, v: flash_attn_model(q, k, v, window=cfg.swa_window),
        mesh=ctx.mesh, in_specs=(qs, qs, qs), out_specs=qs,
        check_vma=False,
    )
    return fn(q, k, v)


def _dense_ffn(cfg: ArchConfig, bp, x):
    h = common.rms_norm(x, bp["ln2"], cfg.norm_eps)
    act = jax.nn.silu(_qdot(cfg, h, bp, "wg")) * _qdot(cfg, h, bp, "wi")
    return x + _qdot(cfg, act, bp, "wd")


# --------------------------- MoE (shard_map EP) ----------------------------


def _local_route(xf, router_w, m, e_lo, E_loc, capacity):
    """Sort-based capacity routing for the E_loc experts starting at e_lo.

    ``E_loc`` is static (python int); ``e_lo`` may be traced (axis_index).

    xf: (n, d) local tokens. Returns (gather_idx (E_loc*C,), gates (E_loc*C,),
    keep mask (E_loc*C,)) mapping buffer rows → token rows.
    """
    n = xf.shape[0]
    logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (n, E)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)                 # (n, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize

    flat_e = top_i.reshape(-1)                                   # (n*k,)
    flat_g = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), m.top_k)                  # token ids

    local_e = flat_e - e_lo
    is_local = (local_e >= 0) & (local_e < E_loc)
    sort_key = jnp.where(is_local, local_e, E_loc)               # invalid last
    order = jnp.argsort(sort_key)
    se = sort_key[order]                                          # sorted expert ids
    st = flat_t[order]
    sg = flat_g[order]
    # position within each expert's contiguous run
    starts = jnp.searchsorted(se, jnp.arange(E_loc + 1))
    pos = jnp.arange(se.shape[0]) - starts[jnp.clip(se, 0, E_loc)]
    keep = (se < E_loc) & (pos < capacity)
    slot = jnp.where(keep, se * capacity + pos, E_loc * capacity)  # overflow slot

    # buffer row r ← token index; build inverse map via scatter
    gather_idx = jnp.zeros((E_loc * capacity + 1,), jnp.int32).at[slot].set(
        st.astype(jnp.int32), mode="drop")
    gates = jnp.zeros((E_loc * capacity + 1,), jnp.float32).at[slot].set(
        sg, mode="drop")
    filled = jnp.zeros((E_loc * capacity + 1,), jnp.bool_).at[slot].set(
        keep, mode="drop")
    # aux-loss ingredients (load balance over the *global* expert set)
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_i, probs.shape[-1], dtype=jnp.float32),
                  axis=(0, 1))
    aux = probs.shape[-1] * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gather_idx[:-1], gates[:-1], filled[:-1], aux, z_loss


def _moe_ffn_local(cfg: ArchConfig, bp, x, ctx: ShardCtx, mode: str = "ep"):
    """Per-device MoE FFN body (runs under shard_map).

    x: (B_loc, S, d) — batch sharded over ctx.batch, replicated over model.

    mode="ep"  (n_experts % model_size == 0): experts sharded over the model
      axis (E_loc = E/msize each), d_expert FSDP-sharded over dp and gathered
      before compute.  The classic expert-parallel layout.
    mode="etp" (n_experts < model_size, e.g. mixtral 8e on a 16-way axis):
      every device holds ALL experts but only a 1/msize slice of d_expert
      (tensor parallelism *within* each expert); d_model is FSDP over dp and
      gathered.  The closing psum over the model axis then sums d_expert
      partial products instead of disjoint expert sets — same math, and the
      per-device matmul volume is identical (E·d·de/msize).
    """
    m = cfg.moe
    B, S, d = x.shape
    n = B * S
    xf = x.reshape(n, d)
    h = common.rms_norm(xf, bp["ln2"], cfg.norm_eps)

    if mode == "ep":
        E_loc = m.n_experts // ctx.model_size
        midx = jax.lax.axis_index(ctx.model)
        e_lo = midx * E_loc
    else:
        E_loc = m.n_experts
        e_lo = 0

    capacity = max(int(m.top_k * n * m.capacity_factor / m.n_experts), 4)

    gather_idx, gates, filled, aux, z_loss = _local_route(
        h, bp["router"], m, e_lo, E_loc, capacity)

    # FSDP: gather the dp-sharded weight dim (de for ep, d for etp)
    def gather_w(w, axis):
        for a in reversed(ctx.dp):
            w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
        return w

    quant = "we_g_q" in bp
    suffix = "_q" if quant else ""
    if mode == "ep":
        we_g = gather_w(bp["we_g" + suffix], 2)      # (E_loc, d, de)
        we_i = gather_w(bp["we_i" + suffix], 2)
        we_o = gather_w(bp["we_o" + suffix], 1)      # (E_loc, de, d)
        if quant:   # per-out-channel scales follow their channel dim
            we_g_s = gather_w(bp["we_g_s"], 1)       # (E_loc, de)
            we_i_s = gather_w(bp["we_i_s"], 1)
            we_o_s = bp["we_o_s"]                    # (E_loc, d) unsharded
    else:
        we_g = gather_w(bp["we_g" + suffix], 1)      # (E, d, de_loc)
        we_i = gather_w(bp["we_i" + suffix], 1)
        we_o = gather_w(bp["we_o" + suffix], 2)      # (E, de_loc, d)
        if quant:
            we_g_s = bp["we_g_s"]                    # (E, de_loc)
            we_i_s = bp["we_i_s"]
            we_o_s = gather_w(bp["we_o_s"], 1)       # (E, d)

    buf = jnp.where(filled[:, None], h[gather_idx], 0)            # (E_loc*C, d)
    buf = buf.reshape(E_loc, capacity, d)

    def expert_mm(spec, x, w, w_s):
        if not quant:
            return jnp.einsum(spec, x, _w(cfg, w))
        x_q, x_s = _quantize_act(x)
        acc = jnp.einsum(spec, x_q, w, preferred_element_type=jnp.int32)
        return (acc.astype(jnp.float32) * x_s
                * w_s[..., None, :]).astype(x.dtype)

    act = jax.nn.silu(expert_mm("ecd,edf->ecf", buf, we_g,
                                we_g_s if quant else None)) * \
        expert_mm("ecd,edf->ecf", buf, we_i, we_i_s if quant else None)
    out = expert_mm("ecf,efd->ecd", act, we_o,
                    we_o_s if quant else None)                     # (E_loc, C, d)
    out = out.reshape(E_loc * capacity, d) * gates[:, None]

    combined = jnp.zeros((n, d), out.dtype).at[gather_idx].add(
        jnp.where(filled[:, None], out, 0))
    combined = jax.lax.psum(combined, ctx.model)

    # shared experts: plain dense FFN, tensor-parallel over model axis
    if m.n_shared_experts:
        sact = jax.nn.silu(_qdot(cfg, h, bp, "ws_g")) * _qdot(cfg, h, bp, "ws_i")
        sout = _qdot(cfg, sact, bp, "ws_o")
        combined = combined + jax.lax.psum(sout, ctx.model)

    aux = jax.lax.pmean(aux, ctx.dp + (ctx.model,))
    z_loss = jax.lax.pmean(z_loss, ctx.dp + (ctx.model,))
    return (x + combined.reshape(B, S, d).astype(x.dtype)), aux, z_loss


def moe_mode(cfg: ArchConfig, model_size: int) -> str:
    """'ep' when experts divide the model axis, else expert-TP fallback."""
    return "ep" if cfg.moe.n_experts % model_size == 0 else "etp"


def _moe_ffn(cfg: ArchConfig, bp, x, ctx: ShardCtx):
    """shard_map wrapper: explicit EP (or expert-TP) + FSDP for the experts."""
    from repro.compat import shard_map
    m = cfg.moe
    dp = ctx.dp
    mode = moe_mode(cfg, ctx.model_size)

    bax = ctx.batch_axes
    x_spec = P(bax, None, None)
    specs = {"ln2": P(None), "router": P(None, None)}
    quant = "we_g_q" in bp
    sfx = "_q" if quant else ""
    if mode == "ep":
        # (E, d, de): E → model, de → dp (FSDP)
        specs["we_g" + sfx] = P(ctx.model, None, dp)
        specs["we_i" + sfx] = P(ctx.model, None, dp)
        specs["we_o" + sfx] = P(ctx.model, dp, None)
        if quant:   # scales: (E, de) / (E, d)
            specs["we_g_s"] = P(ctx.model, dp)
            specs["we_i_s"] = P(ctx.model, dp)
            specs["we_o_s"] = P(ctx.model, None)
    else:
        # (E, d, de): de → model (TP within expert), d → dp (FSDP)
        specs["we_g" + sfx] = P(None, dp, ctx.model)
        specs["we_i" + sfx] = P(None, dp, ctx.model)
        specs["we_o" + sfx] = P(None, ctx.model, dp)
        if quant:
            specs["we_g_s"] = P(None, ctx.model)
            specs["we_i_s"] = P(None, ctx.model)
            specs["we_o_s"] = P(None, dp)
    if m.n_shared_experts:
        specs["ws_g" + sfx] = P(None, ctx.model)
        specs["ws_i" + sfx] = P(None, ctx.model)
        specs["ws_o" + sfx] = P(ctx.model, None)
        if quant:   # scales: (ds,) / (d,)
            specs["ws_g_s"] = P(ctx.model)
            specs["ws_i_s"] = P(ctx.model)
            specs["ws_o_s"] = P(None)

    bp_in = {k: bp[k] for k in specs}

    fn = shard_map(
        functools.partial(_moe_ffn_local, cfg, ctx=ctx, mode=mode),
        mesh=ctx.mesh,
        in_specs=(dict(specs), x_spec),
        out_specs=(x_spec, P(), P()),
        check_vma=False,
    )
    return fn(bp_in, x)


def _moe_ffn_single(cfg: ArchConfig, bp, x):
    """Meshless fallback (unit tests / reference): all experts local."""
    m = cfg.moe
    B, S, d = x.shape
    n = B * S
    xf = x.reshape(n, d)
    h = common.rms_norm(xf, bp["ln2"], cfg.norm_eps)
    capacity = max(int(m.top_k * n * m.capacity_factor / m.n_experts), 4)
    gather_idx, gates, filled, aux, z_loss = _local_route(
        h, bp["router"], m, 0, m.n_experts, capacity)
    buf = jnp.where(filled[:, None], h[gather_idx], 0).reshape(m.n_experts, capacity, d)
    act = jax.nn.silu(_qeinsum(cfg, "ecd,edf->ecf", buf, bp, "we_g")) * \
        _qeinsum(cfg, "ecd,edf->ecf", buf, bp, "we_i")
    out = _qeinsum(cfg, "ecf,efd->ecd", act, bp, "we_o").reshape(-1, d) * gates[:, None]
    combined = jnp.zeros((n, d), out.dtype).at[gather_idx].add(
        jnp.where(filled[:, None], out, 0))
    if m.n_shared_experts:
        sact = jax.nn.silu(_qdot(cfg, h, bp, "ws_g")) * _qdot(cfg, h, bp, "ws_i")
        combined = combined + _qdot(cfg, sact, bp, "ws_o")
    return x + combined.reshape(B, S, d).astype(x.dtype), aux, z_loss


# ---------------------------------------------------------------------------
# Full forward (training / prefill)
# ---------------------------------------------------------------------------


class ForwardOut(NamedTuple):
    logits: jax.Array
    aux_loss: jax.Array
    z_loss: jax.Array


def _remat_policy(cfg: ArchConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims


def forward(cfg: ArchConfig, params, tokens: jax.Array,
            ctx: Optional[ShardCtx] = None,
            embeds: Optional[jax.Array] = None) -> ForwardOut:
    """tokens: (B, S) int32 — or embeds (B, S, d) for audio/vlm stub inputs."""
    if embeds is not None:
        x = embeds.astype(_cdt(cfg))
        B, S, _ = embeds.shape
    else:
        B, S = tokens.shape
        x = params["embed"][tokens]
    x = x.astype(_cdt(cfg))
    positions = jnp.arange(S)[None, :]
    aux = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)

    policy = _remat_policy(cfg)

    def seq_sp(x):
        """Sequence parallelism: pin inter-block activations to a seq-sharded
        layout.  The row-parallel psum after wo/wd then lowers as
        reduce-scatter (+ all-gather before the next block's column-parallel
        matmuls) — half the bytes of the pure-TP all-reduce, and the
        norms/elementwise between blocks run on S/msize rows per device."""
        if ctx is None or not cfg.seq_shard:
            return x
        if ctx.model in ctx.dp or x.shape[1] % ctx.model_size != 0:
            return x
        spec = P(ctx.batch_axes, ctx.model, None)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(ctx.mesh, spec))

    def dense_body(x, bp):
        x = _attention(cfg, bp, x, positions, ctx)
        x = _dense_ffn(cfg, bp, x)
        return seq_sp(x), None

    def moe_body(carry, bp):
        x, aux, zl = carry
        x = _attention(cfg, bp, x, positions, ctx)
        if ctx is not None:
            x, a, z = _moe_ffn(cfg, bp, x, ctx)
        else:
            x, a, z = _moe_ffn_single(cfg, bp, x)
        return (seq_sp(x), aux + a, zl + z), None

    if policy is not None:
        dense_body = jax.checkpoint(dense_body, policy=policy, prevent_cse=False)
        moe_body = jax.checkpoint(moe_body, policy=policy, prevent_cse=False)

    if params.get("dense_blocks") is not None:
        x, _ = jax.lax.scan(dense_body, x, params["dense_blocks"])
    if params.get("moe_blocks") is not None:
        (x, aux, zl), _ = jax.lax.scan(moe_body, (x, aux, zl), params["moe_blocks"])

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    n_moe = cfg.n_layers - (cfg.moe.n_dense_layers if cfg.moe else 0)
    denom = max(n_moe, 1)
    return ForwardOut(logits, aux / denom, zl / denom)


def loss_fn(cfg: ArchConfig, params, batch, ctx: Optional[ShardCtx] = None):
    out = forward(cfg, params, batch["tokens"], ctx,
                  embeds=batch.get("embeds"))
    loss = common.cross_entropy_loss(out.logits, batch["labels"],
                                     batch.get("mask"))
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss * out.aux_loss + cfg.moe.router_z_loss * out.z_loss
    return loss, {"ce": loss, "aux": out.aux_loss, "z": out.z_loss}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with (ring-buffer) KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array          # (L, B, T, KV, hd) — compute dtype, or int8 when
    v: jax.Array          #   cfg.quant_kv (k_s/v_s hold per-row scales)
    length: jax.Array     # (B,) int32 — per-row tokens currently in cache
    k_s: Any = None       # (L, B, T, KV) f32 — int8-KV scales (else None)
    v_s: Any = None


def _quantize_kv_rows(x: jax.Array):
    """Per-(…, KV)-row symmetric int8 over hd: (..., KV, hd) → q, scale."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(s, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s


def cache_len(cfg: ArchConfig, max_len: int) -> int:
    """SWA archs only need a window-sized ring buffer."""
    if cfg.swa_window is not None:
        return min(cfg.swa_window, max_len)
    return max_len


def init_cache(cfg: ArchConfig, B: int, max_len: int, dtype=None) -> KVCache:
    dtype = dtype or _cdt(cfg)
    T = cache_len(cfg, max_len)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, B, T, KV, hd)
    # per-row lengths: the serving engine admits requests with ragged prompt
    # lengths into one decode batch (continuous batching)
    if cfg.quant_kv:
        return KVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                       jnp.zeros((B,), jnp.int32),
                       jnp.zeros(shape[:-1], jnp.float32),
                       jnp.zeros(shape[:-1], jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((B,), jnp.int32))


def _block_decode(cfg: ArchConfig, bp, x, k_cache, v_cache, pos, T,
                  ks=None, vs=None):
    """One block's single-token attention. x: (B, 1, d), pos: (B,) per-row
    positions (ragged continuous batching). ks/vs: int8-KV scale pages
    (B, T, KV) when cfg.quant_kv. Returns new x, cache pages (+ scales)."""
    B = x.shape[0]
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    h = common.rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = (h @ _w(cfg, bp["wq"])).reshape(B, 1, H, hd)
    k = (h @ _w(cfg, bp["wk"])).reshape(B, 1, KV, hd)
    v = (h @ _w(cfg, bp["wv"])).reshape(B, 1, KV, hd)
    if cfg.use_bias:
        q = q + _w(cfg, bp["bq"]).reshape(1, 1, H, hd)
        k = k + _w(cfg, bp["bk"]).reshape(1, 1, KV, hd)
        v = v + _w(cfg, bp["bv"]).reshape(1, 1, KV, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, bp["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, bp["k_norm"], cfg.norm_eps)
    pos_b = pos[:, None]                             # (B, 1) per-row positions
    q = common.apply_rope(q, pos_b, cfg.rope_theta)
    k = common.apply_rope(k, pos_b, cfg.rope_theta)

    slot = pos % T                                   # (B,) ring-buffer slots
    rows = jnp.arange(B)
    valid = jnp.minimum(pos + 1, T)                  # (B,)
    if ks is not None:                               # int8 KV cache
        k_q, k_sc = _quantize_kv_rows(k[:, 0])       # (B, KV, hd), (B, KV)
        v_q, v_sc = _quantize_kv_rows(v[:, 0])
        k_cache = k_cache.at[rows, slot].set(k_q)
        v_cache = v_cache.at[rows, slot].set(v_q)
        ks = ks.at[rows, slot].set(k_sc)
        vs = vs.at[rows, slot].set(v_sc)
        o = common.decode_attention(q, k_cache, v_cache, valid,
                                    k_scale=ks, v_scale=vs)
        out = (x + (o.reshape(B, 1, H * hd) @ _w(cfg, bp["wo"])).astype(x.dtype))
        return out, k_cache, v_cache, ks, vs
    k_cache = k_cache.at[rows, slot].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[rows, slot].set(v[:, 0].astype(v_cache.dtype))
    o = common.decode_attention(q, k_cache, v_cache, valid)
    x = x + (o.reshape(B, 1, H * hd) @ _w(cfg, bp["wo"])).astype(x.dtype)
    return x, k_cache, v_cache, None, None


def _block_decode_inplace(cfg: ArchConfig, bp, x, k_all, v_all, li, pos, T):
    """Like _block_decode, but scatters the new token row DIRECTLY into the
    full (L, B, T, KV, hd) cache buffer at [li] — a B-row write instead of a
    (B, T, ·) page-out — then reads the layer page once for attention (the
    irreducible cache read)."""
    B = x.shape[0]
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    h = common.rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = (h @ _w(cfg, bp["wq"])).reshape(B, 1, H, hd)
    k = (h @ _w(cfg, bp["wk"])).reshape(B, 1, KV, hd)
    v = (h @ _w(cfg, bp["wv"])).reshape(B, 1, KV, hd)
    if cfg.use_bias:
        q = q + _w(cfg, bp["bq"]).reshape(1, 1, H, hd)
        k = k + _w(cfg, bp["bk"]).reshape(1, 1, KV, hd)
        v = v + _w(cfg, bp["bv"]).reshape(1, 1, KV, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, bp["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, bp["k_norm"], cfg.norm_eps)
    pos_b = pos[:, None]
    q = common.apply_rope(q, pos_b, cfg.rope_theta)
    k = common.apply_rope(k, pos_b, cfg.rope_theta)

    slot = pos % T                                   # (B,) ring-buffer slots
    rows = jnp.arange(B)
    li_b = jnp.broadcast_to(li, (B,))
    k_all = k_all.at[li_b, rows, slot].set(k[:, 0].astype(k_all.dtype))
    v_all = v_all.at[li_b, rows, slot].set(v[:, 0].astype(v_all.dtype))
    kc = jax.lax.dynamic_index_in_dim(k_all, li, 0, keepdims=False)
    vc = jax.lax.dynamic_index_in_dim(v_all, li, 0, keepdims=False)
    valid = jnp.minimum(pos + 1, T)
    o = common.decode_attention(q, kc, vc, valid)
    x = x + (o.reshape(B, 1, H * hd) @ _w(cfg, bp["wo"])).astype(x.dtype)
    return x, k_all, v_all


def decode_step(cfg: ArchConfig, params, token: jax.Array, cache: KVCache,
                ctx: Optional[ShardCtx] = None,
                embed: Optional[jax.Array] = None):
    """token: (B,) int32 (or embed (B, d)). Returns (logits (B, V), cache).

    Cache pages ride the layer scan as xs/ys: the per-layer (B, T, ·) page
    gets a one-row scatter and is emitted as a ys — XLA's loop-residual
    stacking performs the page write as an in-place dynamic-update-slice
    under donation.  (A carried-full-buffer variant with a dynamic layer
    index was measured 2.8× WORSE: scatter through a traced layer index on
    the (L,·) buffer lowers to full-buffer masked selects per layer.)
    """
    if embed is not None:
        x = embed[:, None, :].astype(_cdt(cfg))
        B = embed.shape[0]
    else:
        B = token.shape[0]
        x = params["embed"][token][:, None, :].astype(_cdt(cfg))
    pos = cache.length
    T = cache.k.shape[2]

    n_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    qkv_cache = cfg.quant_kv

    def make_body(moe: bool):
        def body(x, layer):
            if qkv_cache:
                bp, kc, vc, ksp, vsp = layer
            else:
                (bp, kc, vc), ksp, vsp = layer, None, None
            x, kc, vc, ksp, vsp = _block_decode(cfg, bp, x, kc, vc, pos, T,
                                                ksp, vsp)
            if moe:
                if ctx is not None:
                    x, _, _ = _moe_ffn(cfg, bp, x, ctx)
                else:
                    x, _, _ = _moe_ffn_single(cfg, bp, x)
            else:
                x = _dense_ffn(cfg, bp, x)
            return x, ((kc, vc, ksp, vsp) if qkv_cache else (kc, vc))
        return body

    def xs_for(blocks, lo, hi):
        if qkv_cache:
            return (blocks, cache.k[lo:hi], cache.v[lo:hi],
                    cache.k_s[lo:hi], cache.v_s[lo:hi])
        return (blocks, cache.k[lo:hi], cache.v[lo:hi])

    new_k, new_v, new_ks, new_vs = [], [], [], []

    def collect(ys):
        if qkv_cache:
            kc, vc, ksp, vsp = ys
            new_ks.append(ksp)
            new_vs.append(vsp)
        else:
            kc, vc = ys
        new_k.append(kc)
        new_v.append(vc)

    if params.get("dense_blocks") is not None:
        nd = jax.tree_util.tree_leaves(params["dense_blocks"])[0].shape[0]
        x, ys = jax.lax.scan(make_body(False), x,
                             xs_for(params["dense_blocks"], 0, nd))
        collect(ys)
    if params.get("moe_blocks") is not None:
        x, ys = jax.lax.scan(make_body(True), x,
                             xs_for(params["moe_blocks"], n_dense,
                                    cfg.n_layers))
        collect(ys)

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).reshape(B, -1)

    def cat(parts):
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    return logits, KVCache(
        cat(new_k), cat(new_v), cache.length + 1,
        cat(new_ks) if qkv_cache else None,
        cat(new_vs) if qkv_cache else None)


def prefill(cfg: ArchConfig, params, tokens: jax.Array, max_len: int,
            ctx: Optional[ShardCtx] = None,
            embeds: Optional[jax.Array] = None):
    """Full-sequence forward that also fills the KV cache (teacher-forced).

    Implemented as forward() for logits + a lightweight second pass that
    recomputes per-layer K/V into the cache (scan, no attention) — keeps one
    code path for attention math.  Returns (logits, cache).
    """
    out = forward(cfg, params, tokens, ctx, embeds=embeds)
    B, S = (embeds.shape[:2] if embeds is not None else tokens.shape)
    cache = init_cache(cfg, B, max_len)
    T = cache.k.shape[2]
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    positions = jnp.arange(S)[None, :]

    if embeds is not None:
        x = embeds.astype(_cdt(cfg))
    else:
        x = params["embed"][tokens].astype(_cdt(cfg))

    def kv_body(x, bp):
        h = common.rms_norm(x, bp["ln1"], cfg.norm_eps)
        k = (h @ _w(cfg, bp["wk"])).reshape(B, S, KV, hd)
        v = (h @ _w(cfg, bp["wv"])).reshape(B, S, KV, hd)
        if cfg.use_bias:
            k = k + _w(cfg, bp["bk"]).reshape(1, 1, KV, hd)
            v = v + _w(cfg, bp["bv"]).reshape(1, 1, KV, hd)
        if cfg.qk_norm:
            k = common.rms_norm(k, bp["k_norm"], cfg.norm_eps)
        k = common.apply_rope(k, positions, cfg.rope_theta)
        # recompute the block output to feed the next layer
        x = _attention(cfg, bp, x, positions, ctx)
        if "wd" in bp or "wd_q" in bp:   # dense block (float or W8A8)
            x = _dense_ffn(cfg, bp, x)
        elif ctx is not None:
            x, _, _ = _moe_ffn(cfg, bp, x, ctx)
        else:
            x, _, _ = _moe_ffn_single(cfg, bp, x)
        # keep last T positions (ring layout: slot = pos % T)
        sl = jnp.maximum(S - T, 0)
        kk = jax.lax.dynamic_slice_in_dim(k, sl, min(T, S), axis=1)
        vv = jax.lax.dynamic_slice_in_dim(v, sl, min(T, S), axis=1)
        return x, (kk, vv)

    ks, vs = [], []
    if params.get("dense_blocks") is not None:
        x, (kk, vv) = jax.lax.scan(kv_body, x, params["dense_blocks"])
        ks.append(kk)
        vs.append(vv)
    if params.get("moe_blocks") is not None:
        x, (kk, vv) = jax.lax.scan(kv_body, x, params["moe_blocks"])
        ks.append(kk)
        vs.append(vv)
    k_all = jnp.concatenate(ks)           # (L, B, min(T,S), KV, hd)
    v_all = jnp.concatenate(vs)

    Tc = k_all.shape[2]
    ks_all = vs_all = None
    if cfg.quant_kv:
        k_all, ks_all = _quantize_kv_rows(k_all)
        v_all, vs_all = _quantize_kv_rows(v_all)
    if cfg.swa_window is not None and S >= T:
        # ring alignment: token position p sits at slot p % T
        idx = (jnp.arange(Tc) + (S - Tc)) % T
        kc = jnp.zeros_like(cache.k).at[:, :, idx].set(k_all.astype(cache.k.dtype))
        vc = jnp.zeros_like(cache.v).at[:, :, idx].set(v_all.astype(cache.v.dtype))
        if cfg.quant_kv:
            ks_all = jnp.zeros_like(cache.k_s).at[:, :, idx].set(ks_all)
            vs_all = jnp.zeros_like(cache.v_s).at[:, :, idx].set(vs_all)
    else:
        kc = jax.lax.dynamic_update_slice(
            cache.k, k_all.astype(cache.k.dtype), (0, 0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache.v, v_all.astype(cache.v.dtype), (0, 0, 0, 0, 0))
        if cfg.quant_kv:
            ks_all = jax.lax.dynamic_update_slice(
                cache.k_s, ks_all, (0, 0, 0, 0))
            vs_all = jax.lax.dynamic_update_slice(
                cache.v_s, vs_all, (0, 0, 0, 0))
    return out.logits, KVCache(kc, vc, jnp.full((B,), S, jnp.int32),
                               ks_all, vs_all)
