"""Ship Detection CNN — the paper's own workload (OBPMark-ML, YoloX-style).

A compact quantized detector backbone whose middle layers are *exactly* the
four Table-1 layers of the paper (kernel / image geometry):

    conv1:  24×3×3×24  @ 194×194×24
    conv2:  48×3×3×48  @  98× 98×48
    conv3:  96×3×3×96  @  50× 50×96
    conv4:  96×1×1×96  @  96× 96×96   (parallel 1×1 branch)

Every convolution executes as int8 conv + fused re-quantization through
kernels/qconv2d — i.e. the exact op the HPDP runs — composed into a network
by the framework (the role Klepsydra AI + RTG4 orchestration plays in the
paper).  Dependability policy applies per layer (core/dependability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import abft as abft_mod
from repro.core import quant
from repro.core.dependability import (
    DependabilityStats, Policy, dependable_qconv2d)
from repro.kernels.qconv2d import ops as qconv_ops


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    kh: int
    kw: int
    cin: int
    cout: int
    h: int                 # input spatial (square images per the paper's table)
    w: int
    stride: int = 1

    @property
    def macs(self) -> int:
        return self.h * self.w * self.cin * self.cout * self.kh * self.kw // (self.stride ** 2)


# The paper's Table-1 layers, exact geometry.
TABLE1_LAYERS = [
    ConvSpec("conv_24x3x3x24", 3, 3, 24, 24, 194, 194),
    ConvSpec("conv_48x3x3x48", 3, 3, 48, 48, 98, 98),
    ConvSpec("conv_96x3x3x96", 3, 3, 96, 96, 50, 50),
    ConvSpec("conv_96x1x1x96", 1, 1, 96, 96, 96, 96),
]


def network_specs(img: int = 194) -> List[ConvSpec]:
    """Full ship-detector: stem + Table-1 trunk + head."""
    return [
        ConvSpec("stem", 3, 3, 3, 24, img * 2, img * 2, stride=2),
        TABLE1_LAYERS[0],
        ConvSpec("down1", 3, 3, 24, 48, 194, 194, stride=2),
        TABLE1_LAYERS[1],
        ConvSpec("down2", 3, 3, 48, 96, 98, 98, stride=2),
        TABLE1_LAYERS[2],
        ConvSpec("head1x1", 1, 1, 96, 96, 50, 50),
        ConvSpec("det_head", 1, 1, 96, 6, 50, 50),     # 1 class + 4 box + obj
    ]


def reduced_specs() -> List[ConvSpec]:
    """Small variant for CPU smoke tests (same topology, 16× smaller maps)."""
    full = network_specs()
    out = []
    for s in full:
        out.append(dataclasses.replace(s, h=max(s.h // 8, 4), w=max(s.w // 8, 4)))
    return out


def init_params(specs: List[ConvSpec], key: jax.Array) -> List[Dict[str, Any]]:
    """Float master weights + static activation qparams per layer (calibrated)."""
    params = []
    keys = jax.random.split(key, len(specs))
    for s, k in zip(specs, keys):
        w = jax.random.normal(k, (s.kh, s.kw, s.cin, s.cout)) * (
            1.0 / jnp.sqrt(s.kh * s.kw * s.cin))
        b = jnp.zeros((s.cout,), jnp.float32)
        params.append({
            "qconv": qconv_ops.make_qconv_params(w, b),
            # static calibration (identity-ish ranges; real deployments run
            # the MinMaxObserver over a calibration set)
            "in_scale": jnp.float32(0.05), "in_zp": jnp.int32(0),
            "out_scale": jnp.float32(0.05), "out_zp": jnp.int32(0),
        })
    return params


def deploy_checks(params: List[Dict[str, Any]]) -> List[jax.Array]:
    """Deploy-time per-layer weight checksums (the Huang–Abraham conv
    identity over the known-good quantized weights).  Shipped alongside the
    model exactly like the fleet's storage checksums: a later ``forward``
    with ``w_checks=`` verifies the *live* weights against these, so a
    weight-memory SEU between deploy and execution is detected (ABFT) or
    healed by rollback to ``golden_weights`` (CKPT)."""
    return [abft_mod.conv_checksum_weight(p["qconv"].w_q) for p in params]


def golden_weights(params: List[Dict[str, Any]]) -> List[jax.Array]:
    """The known-good quantized weights per layer — the operand checkpoint
    CKPT rolls back to when a deploy-time check fails."""
    return [p["qconv"].w_q for p in params]


def forward(specs: List[ConvSpec], params: List[Dict[str, Any]], x: jax.Array,
            *, policy: Policy = Policy.NONE, policy_map=None,
            use_kernel: bool = False,
            interpret: bool = False, inject=None, inject_layer=None,
            backend=None, w_checks: Optional[List[jax.Array]] = None,
            golden_wq: Optional[List[jax.Array]] = None
            ) -> Tuple[jax.Array, Dict]:
    """x: (N, H, W, 3) float in [0,1]. Returns (det map, dependability stats).

    ``backend`` selects the quantized-conv execution engine (core/backend
    registry): a single name applies network-wide, a sequence applies
    per-layer — the software rendition of the paper reserving the rad-hard
    HPDP for the convolution trunk while other layers run elsewhere.

    ``w_checks`` (from ``deploy_checks``) turns ABFT/CKPT layers into
    deploy-time weight scrubs: the per-layer checksum is verified against
    the shipped value instead of one recomputed from the (possibly struck)
    live weights.  ``golden_wq`` (from ``golden_weights``) additionally
    gives CKPT layers a rollback target, so a weight SEU is *healed* by
    re-executing from the known-good weights, not just flagged.

    ``policy_map`` (core/policy_map.py) replaces the single network-wide
    ``policy`` with a per-layer assignment resolved by ``ConvSpec.name`` —
    the Python layer loop gives the CNN true per-layer granularity, so
    selective-hardening DSE searches this space directly.  Under a map,
    DMR/TMR run *in the op* per layer (layer-level temporal redundancy)
    rather than via network-level replication; clean outputs stay
    bit-identical to the unmapped path for every policy (exact integer
    checks never fire, votes of equal replicas are the replica).  Exactly
    one of ``policy`` / ``policy_map`` may be non-trivial.

    ``inject_layer`` overrides the default mid-network accumulator
    injection site with an explicit layer index (per-layer fault-injection
    campaigns; None keeps the legacy mid-layer hook).
    """
    if policy_map is not None and policy is not Policy.NONE:
        raise ValueError("pass either policy= or policy_map=, not both")
    stats = DependabilityStats.zero()
    if backend is None or isinstance(backend, str):
        layer_backends = [backend] * len(specs)
    else:
        layer_backends = list(backend)
        assert len(layer_backends) == len(specs), \
            (len(layer_backends), len(specs))
    hook_layer = len(specs) // 2 if inject_layer is None else inject_layer
    for i, (s, p) in enumerate(zip(specs, params)):
        stride = (s.stride, s.stride)
        layer_be = layer_backends[i]
        # uniform accumulator injection site: the mid-layer int32 accumulator
        # is reachable under every policy, so fault-injection campaigns
        # measure all policies on the same hook
        layer_inject = inject if i == hook_layer else None
        if policy_map is not None:
            layer_policy, pm_backend = policy_map.resolve(s.name)
            layer_be = pm_backend or layer_be
            in_op_policy = layer_policy
        else:
            layer_policy = policy
            # ABFT and CKPT run inside the op (checksum detect; recompute-
            # vs rollback-recover); NMR policies replicate at the network
            # level, so their per-layer call is the plain path
            in_op_policy = policy if policy in (Policy.ABFT, Policy.CKPT) \
                else Policy.NONE
        if layer_policy != Policy.NONE or layer_inject is not None \
                or layer_be is not None:
            x_q = quant.quantize(x, p["in_scale"], p["in_zp"])
            bias_i32 = jnp.round(
                p["qconv"].bias_f / (p["in_scale"] * p["qconv"].w_scale)
            ).astype(jnp.int32)
            rq = quant.requant_scale(p["in_scale"], p["qconv"].w_scale,
                                     p["out_scale"])
            y_q, lstats = dependable_qconv2d(
                in_op_policy,
                x_q, p["in_zp"], p["qconv"].w_q, bias_i32, rq, p["out_zp"],
                stride=stride, padding="SAME", inject=layer_inject,
                backend=layer_be,
                w_check=w_checks[i] if w_checks is not None else None,
                ckpt=((x_q, golden_wq[i]) if golden_wq is not None
                      else None))
            x = (y_q.astype(jnp.float32) - p["out_zp"]) * p["out_scale"]
            stats = DependabilityStats.merge(stats, lstats)
        else:
            x = qconv_ops.qconv_act(
                x, p["qconv"], p["in_scale"], p["in_zp"],
                p["out_scale"], p["out_zp"], stride=stride, padding="SAME",
                use_kernel=use_kernel, interpret=interpret)
        if i < len(specs) - 1:
            x = jax.nn.relu(x)
    return x, stats


def layer_forward(s: ConvSpec, p: Dict[str, Any], x: jax.Array,
                  quantized: bool = True, interpret: bool = True) -> jax.Array:
    """One layer, float in → float out; quantized=False is the float oracle
    (dequantized weights, float conv) used by the Fig.-4-style validation."""
    stride = (s.stride, s.stride)
    if quantized:
        return qconv_ops.qconv_act(
            x, p["qconv"], p["in_scale"], p["in_zp"],
            p["out_scale"], p["out_zp"], stride=stride, padding="SAME",
            use_kernel=True, interpret=interpret)
    w = p["qconv"].w_q.astype(jnp.float32) * p["qconv"].w_scale
    y = jax.lax.conv_general_dilated(
        x, w, stride, "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["qconv"].bias_f


def float_forward(specs: List[ConvSpec], params: List[Dict[str, Any]],
                  x: jax.Array) -> jax.Array:
    """Float-oracle network forward (dequantized weights)."""
    for i, (s, p) in enumerate(zip(specs, params)):
        x = layer_forward(s, p, x, quantized=False)
        if i < len(specs) - 1:
            x = jax.nn.relu(x)
    return x
