"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrence + local
attention, interleaved 2:1 (two recurrent blocks, then one local-MQA block).

RG-LRU:  a_t = exp(-c · softplus(Λ) · σ(W_a x_t)),  c = 8
         h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
— a linear recurrence with data-dependent per-channel gates, which maps onto
`jax.lax.associative_scan` (log-depth parallel on TPU) for train/prefill and
an O(1)-state step for decode.  The recurrent temporal-mix block is
    y = W_out( GeLU(x W_gate) ⊙ RG-LRU(conv1d_4(x W_x)) )
and the attention block is MQA (1 KV head) with a 2048-token sliding window,
so the KV cache is bounded ⇒ the long_500k decode cell is runnable.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ArchConfig
from repro.models.transformer import ForwardOut, ShardCtx, _cdt, _pdt

RGLRU_C = 8.0


def _counts(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(n_super, n_tail_rec, n_attn) for the (rec, rec, attn) repeating pattern."""
    L = cfg.n_layers
    n_super = L // 3
    tail = L - 3 * n_super              # leftover layers are recurrent
    return n_super, tail, n_super


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _rec_block_params(keys, n, d, W, ff, d_conv, pdt):
    def stack(shape):
        return common.dense_init(next(keys), (n,) + shape, in_axis=1, dtype=pdt)
    lam = jnp.tile(jnp.linspace(0.9, 5.0, W)[None], (n, 1)).astype(pdt)
    return {
        "ln": jnp.zeros((n, d), pdt),
        "w_x": stack((d, W)),
        "w_gate": stack((d, W)),
        "conv_w": (common.dense_init(next(keys), (n, d_conv, W), in_axis=1,
                                     dtype=pdt)),
        "lam": lam,                      # Λ (recurrence strength)
        "w_a": stack((W, W)),
        "w_i": stack((W, W)),
        "w_out": stack((W, d)),
        "ln_mlp": jnp.zeros((n, d), pdt),
        "mlp_g": stack((d, ff)),
        "mlp_i": stack((d, ff)),
        "mlp_o": stack((ff, d)),
    }


def _attn_block_params(keys, n, cfg, pdt):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, ff = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff

    def stack(shape):
        return common.dense_init(next(keys), (n,) + shape, in_axis=1, dtype=pdt)
    return {
        "ln": jnp.zeros((n, d), pdt),
        "wq": stack((d, H * hd)),
        "wk": stack((d, KV * hd)),
        "wv": stack((d, KV * hd)),
        "wo": stack((H * hd, d)),
        "ln_mlp": jnp.zeros((n, d), pdt),
        "mlp_g": stack((d, ff)),
        "mlp_i": stack((d, ff)),
        "mlp_o": stack((ff, d)),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    d, V, ff = cfg.d_model, cfg.vocab_size, cfg.d_ff
    W = cfg.recurrent.lru_width or d
    pdt = _pdt(cfg)
    keys = iter(jax.random.split(key, 80))
    n_super, tail, n_attn = _counts(cfg)
    params = {
        "embed": common.embed_init(next(keys), (V, d), dtype=pdt),
        "final_norm": jnp.zeros((d,), pdt),
        "rec_blocks": _rec_block_params(keys, 2 * n_super, d, W, ff,
                                        cfg.recurrent.d_conv, pdt),
        "attn_blocks": _attn_block_params(keys, n_attn, cfg, pdt),
    }
    if tail:
        params["tail_rec"] = _rec_block_params(keys, tail, d, W, ff,
                                               cfg.recurrent.d_conv, pdt)
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(next(keys), (d, V), dtype=pdt)
    return params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def rglru_parallel(x_in, gate_a, lam):
    """x_in, gate_a: (B, T, W) f32; lam: (W,). Associative-scan recurrence."""
    log_a = -RGLRU_C * jax.nn.softplus(lam)[None, None] * gate_a      # ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * x_in

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_step(x_in, gate_a, lam, h_prev):
    log_a = -RGLRU_C * jax.nn.softplus(lam)[None] * gate_a            # (B, W)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * x_in
    return a * h_prev + b


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B, T, W), w: (K, W). state: (B, K-1, W)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return out, xp[:, -(K - 1):]


def _rec_block(cfg, bp, x, state=None):
    """state: (conv_state (B,K-1,W), h (B,W)) or None. x: (B,T,d)."""
    B, T, d = x.shape
    h = common.rms_norm(x, bp["ln"], cfg.norm_eps)
    xb = h @ bp["w_x"]                                   # (B, T, W)
    gate = jax.nn.gelu(h @ bp["w_gate"])
    conv_state = state[0] if state is not None else None
    xb, new_conv = _causal_conv1d(xb, bp["conv_w"], conv_state)

    g_a = jax.nn.sigmoid((xb @ bp["w_a"]).astype(jnp.float32))
    g_i = jax.nn.sigmoid((xb @ bp["w_i"]).astype(jnp.float32))
    xin = g_i * xb.astype(jnp.float32)
    lam = bp["lam"].astype(jnp.float32)

    if state is not None and T == 1:
        hh = rglru_step(xin[:, 0], g_a[:, 0], lam, state[1])
        rec = hh[:, None]
        new_h = hh
    else:
        if state is not None:
            # fold carried state in as a virtual step 0
            pass
        rec = rglru_parallel(xin, g_a, lam)
        new_h = rec[:, -1]
    y = (rec.astype(x.dtype) * gate) @ bp["w_out"]
    x = x + y
    # MLP (GeGLU)
    hm = common.rms_norm(x, bp["ln_mlp"], cfg.norm_eps)
    x = x + (jax.nn.gelu(hm @ bp["mlp_g"]) * (hm @ bp["mlp_i"])) @ bp["mlp_o"]
    return x, (new_conv, new_h)


def _attn_block(cfg, bp, x, positions, kv_state=None, pos=None):
    """Local MQA. kv_state: (k_cache, v_cache) ring (B, Win, KV, hd) for decode."""
    B, T, d = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    win = cfg.recurrent.attn_window
    h = common.rms_norm(x, bp["ln"], cfg.norm_eps)
    q = (h @ bp["wq"]).reshape(B, T, H, hd)
    k = (h @ bp["wk"]).reshape(B, T, KV, hd)
    v = (h @ bp["wv"]).reshape(B, T, KV, hd)
    if kv_state is None:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
        o = common.chunked_causal_attention(q, k, v, window=win)
        new_state = None
    else:
        kc, vc = kv_state
        Tc = kc.shape[1]
        pb = jnp.full((B, 1), pos)
        q = common.apply_rope(q, pb, cfg.rope_theta)
        k = common.apply_rope(k, pb, cfg.rope_theta)
        slot = pos % Tc
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
        o = common.decode_attention(q.astype(jnp.float32),
                                    kc.astype(jnp.float32),
                                    vc.astype(jnp.float32),
                                    jnp.minimum(pos + 1, Tc))
        new_state = (kc, vc)
    x = x + (o.reshape(B, T, H * hd) @ bp["wo"]).astype(x.dtype)
    hm = common.rms_norm(x, bp["ln_mlp"], cfg.norm_eps)
    x = x + (jax.nn.gelu(hm @ bp["mlp_g"]) * (hm @ bp["mlp_i"])) @ bp["mlp_o"]
    return x, new_state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _cast(cfg, tree):
    return jax.tree_util.tree_map(lambda w: w.astype(_cdt(cfg)), tree)


def _super_params(params, n_super):
    """Regroup rec_blocks (2n, ...) into (n, 2, ...) to scan (rec,rec,attn)."""
    rec2 = jax.tree_util.tree_map(
        lambda a: a.reshape((n_super, 2) + a.shape[1:]), params["rec_blocks"])
    return rec2


def forward(cfg: ArchConfig, params, tokens, ctx: Optional[ShardCtx] = None,
            embeds=None) -> ForwardOut:
    x = (embeds if embeds is not None else params["embed"][tokens]).astype(_cdt(cfg))
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    n_super, tail, _ = _counts(cfg)

    def super_body(x, layer):
        rec2, attn = layer
        rec2, attn = _cast(cfg, rec2), _cast(cfg, attn)
        r0 = jax.tree_util.tree_map(lambda a: a[0], rec2)
        r1 = jax.tree_util.tree_map(lambda a: a[1], rec2)
        x, _ = _rec_block(cfg, r0, x)
        x, _ = _rec_block(cfg, r1, x)
        x, _ = _attn_block(cfg, attn, x, positions)
        return x, None

    if cfg.remat != "none":
        super_body = jax.checkpoint(
            super_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False)

    x, _ = jax.lax.scan(super_body, x,
                        (_super_params(params, n_super), params["attn_blocks"]))
    if tail:
        def tail_body(x, bp):
            x, _ = _rec_block(cfg, _cast(cfg, bp), x)
            return x, None
        x, _ = jax.lax.scan(tail_body, x, params["tail_rec"])

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    z = jnp.zeros((), jnp.float32)
    return ForwardOut(logits, z, z)


def loss_fn(cfg, params, batch, ctx=None):
    out = forward(cfg, params, batch["tokens"], ctx, embeds=batch.get("embeds"))
    loss = common.cross_entropy_loss(out.logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss}


class GriffinCache(NamedTuple):
    conv: jax.Array        # (n_rec, B, K-1, W)
    h: jax.Array           # (n_rec, B, W)
    k: jax.Array           # (n_attn, B, Win, KV, hd)
    v: jax.Array
    length: jax.Array


def init_cache(cfg: ArchConfig, B: int, max_len: int, dtype=None) -> GriffinCache:
    dtype = dtype or _cdt(cfg)
    n_super, tail, n_attn = _counts(cfg)
    n_rec = 2 * n_super + tail
    W = cfg.recurrent.lru_width or cfg.d_model
    K = cfg.recurrent.d_conv
    win = min(cfg.recurrent.attn_window, max_len)
    return GriffinCache(
        jnp.zeros((n_rec, B, K - 1, W), dtype),
        jnp.zeros((n_rec, B, W), jnp.float32),
        jnp.zeros((n_attn, B, win, cfg.n_kv_heads, cfg.resolved_head_dim), dtype),
        jnp.zeros((n_attn, B, win, cfg.n_kv_heads, cfg.resolved_head_dim), dtype),
        jnp.zeros((), jnp.int32),
    )


def decode_step(cfg, params, token, cache: GriffinCache,
                ctx: Optional[ShardCtx] = None, embed=None):
    x = (embed if embed is not None else params["embed"][token])
    x = x[:, None, :].astype(_cdt(cfg))
    n_super, tail, n_attn = _counts(cfg)
    pos = cache.length

    def super_body(x, layer):
        rec2, attn, conv2, h2, kc, vc = layer
        rec2, attn = _cast(cfg, rec2), _cast(cfg, attn)
        new_conv, new_h = [], []
        for i in range(2):
            r = jax.tree_util.tree_map(lambda a: a[i], rec2)
            x, (cv, hh) = _rec_block(cfg, r, x, state=(conv2[i], h2[i]))
            new_conv.append(cv)
            new_h.append(hh)
        x, (kc, vc) = _attn_block(cfg, attn, x, None, kv_state=(kc, vc), pos=pos)
        return x, (jnp.stack(new_conv), jnp.stack(new_h), kc, vc)

    rec2 = _super_params(params, n_super)
    conv2 = cache.conv[:2 * n_super].reshape((n_super, 2) + cache.conv.shape[1:])
    h2 = cache.h[:2 * n_super].reshape((n_super, 2) + cache.h.shape[1:])
    x, (nconv, nh, kc, vc) = jax.lax.scan(
        super_body, x, (rec2, params["attn_blocks"], conv2, h2, cache.k, cache.v))
    nconv = nconv.reshape((2 * n_super,) + cache.conv.shape[1:])
    nh = nh.reshape((2 * n_super,) + cache.h.shape[1:])

    if tail:
        def tail_body(x, layer):
            bp, cv, hh = layer
            x, (cv, hh) = _rec_block(cfg, _cast(cfg, bp), x, state=(cv, hh))
            return x, (cv, hh)
        x, (tconv, th) = jax.lax.scan(
            tail_body, x,
            (params["tail_rec"], cache.conv[2 * n_super:], cache.h[2 * n_super:]))
        nconv = jnp.concatenate([nconv, tconv])
        nh = jnp.concatenate([nh, th])

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype))[:, 0]
    return logits, GriffinCache(nconv, nh, kc, vc, cache.length + 1)


def prefill(cfg, params, tokens, max_len: int, ctx=None, embeds=None):
    """Forward pass that also materializes the decode cache (states + window KV)."""
    x = (embeds if embeds is not None else params["embed"][tokens]).astype(_cdt(cfg))
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    n_super, tail, n_attn = _counts(cfg)
    cache = init_cache(cfg, B, max_len)
    win = cache.k.shape[2]

    def super_body(x, layer):
        rec2, attn = layer
        rec2c, attnc = _cast(cfg, rec2), _cast(cfg, attn)
        states = []
        for i in range(2):
            r = jax.tree_util.tree_map(lambda a: a[i], rec2c)
            x, st = _rec_block(cfg, r, x)
            states.append(st)
        # attention with KV collection
        h = common.rms_norm(x, attnc["ln"], cfg.norm_eps)
        hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        k = (h @ attnc["wk"]).reshape(B, S, KV, hd)
        v = (h @ attnc["wv"]).reshape(B, S, KV, hd)
        k = common.apply_rope(k, positions, cfg.rope_theta)
        x, _ = _attn_block(cfg, attnc, x, positions)
        # ring-aligned last-window slice: position p sits at slot p % win
        Tc = min(win, S)
        kk = jax.lax.dynamic_slice_in_dim(k, max(S - Tc, 0), Tc, axis=1)
        vv = jax.lax.dynamic_slice_in_dim(v, max(S - Tc, 0), Tc, axis=1)
        idx = (jnp.arange(Tc) + max(S - Tc, 0)) % win
        kc = jnp.zeros((B, win, KV, hd), kk.dtype).at[:, idx].set(kk)
        vc = jnp.zeros((B, win, KV, hd), vv.dtype).at[:, idx].set(vv)
        conv2 = jnp.stack([states[0][0], states[1][0]])
        h2 = jnp.stack([states[0][1], states[1][1]])
        return x, (conv2, h2, kc, vc)

    x, (conv2, h2, kc, vc) = jax.lax.scan(
        super_body, x, (_super_params(params, n_super), params["attn_blocks"]))
    nconv = conv2.reshape((2 * n_super,) + conv2.shape[2:])
    nh = h2.reshape((2 * n_super,) + h2.shape[2:])

    if tail:
        def tail_body(x, bp):
            x, st = _rec_block(cfg, _cast(cfg, bp), x)
            return x, st
        x, (tconv, th) = jax.lax.scan(tail_body, x, params["tail_rec"])
        nconv = jnp.concatenate([nconv, tconv])
        nh = jnp.concatenate([nh, th])

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return logits, GriffinCache(nconv, nh, kc.astype(cache.k.dtype),
                                vc.astype(cache.v.dtype),
                                jnp.asarray(S, jnp.int32))
