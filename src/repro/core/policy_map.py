"""Per-layer dependability policy maps — selective hardening as data.

``dependable_qmatmul`` and friends take one ``Policy`` per call; a real
deployment mixes them: the paper reserves the rad-hard HPDP for the
convolution hot path while the RTG4 orchestrates, and Safe-NEureka-style
selective hardening protects only the layers whose corruption actually
escapes masking.  A :class:`PolicyMap` is that assignment, reified: an
ordered rule list mapping *site patterns* to a policy (and optionally an
execution backend), with a default for everything unmatched.

Sites are dotted names chosen by each integration point:

  transformer FFN matmuls   ``ffn.wg`` / ``ffn.wi`` / ``ffn.wd`` (dense),
                            ``ffn.ws_g`` / ``ffn.ws_i`` / ``ffn.ws_o``
                            (MoE shared experts) — uniform across the
                            scanned layer stack (``lax.scan`` executes one
                            program for every layer, so per-layer-index
                            policies cannot exist there by construction)
  shipdet conv layers       the ``ConvSpec.name`` of each layer (``stem``,
                            ``conv_24x3x3x24``, …, ``det_head``) — true
                            per-layer granularity (Python loop)
  engine state sites        ``weights`` / ``kv_cache`` / ``decode_state``
                            — consumed by ``Engine(policy_map=)`` to derive
                            its scrub schedule (:meth:`PolicyMap.scrub_mode`
                            / :meth:`PolicyMap.storage_policy`)

Resolution precedence mirrors ``core.backend.resolve`` (per-call > per-layer
> global): an **exact** rule beats a **glob** rule (``fnmatch`` patterns, in
declaration order) beats the **default**; explicit per-call ``policy=``
arguments at the op layer always beat the map entirely.  Maps are frozen
and hashable, so they ride inside ``ArchConfig`` through jit closures, and
they round-trip through plain JSON (``to_doc``/``from_doc``) — the genome
serialization the DSE search (``repro.dse``) and the CLIs share.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import pathlib
from typing import Optional, Tuple, Union

from repro.core.dependability import Policy

_GLOB_CHARS = frozenset("*?[")


def _is_glob(pattern: str) -> bool:
    return any(c in _GLOB_CHARS for c in pattern)


def _as_policy(p: Union[Policy, str]) -> Policy:
    return p if isinstance(p, Policy) else Policy(str(p).lower())


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One ``pattern -> (policy, backend)`` assignment.  ``backend=None``
    inherits the map default (and ultimately the config/global backend)."""

    pattern: str
    policy: Policy
    backend: Optional[str] = None

    def to_doc(self) -> dict:
        doc = {"pattern": self.pattern, "policy": self.policy.value}
        if self.backend is not None:
            doc["backend"] = self.backend
        return doc

    @classmethod
    def from_doc(cls, doc: dict) -> "PolicyRule":
        return cls(pattern=str(doc["pattern"]),
                   policy=_as_policy(doc["policy"]),
                   backend=doc.get("backend"))


@dataclasses.dataclass(frozen=True)
class PolicyMap:
    """Ordered site-pattern → policy assignment with a default."""

    rules: Tuple[PolicyRule, ...] = ()
    default: Policy = Policy.NONE
    default_backend: Optional[str] = None

    # -- resolution --------------------------------------------------------

    def resolve(self, site: str) -> Tuple[Policy, Optional[str]]:
        """(policy, backend) for ``site``: exact rule > glob rule (in
        declaration order) > default.  A rule without a backend inherits
        ``default_backend`` (which may itself be None → config/global)."""
        for r in self.rules:
            if not _is_glob(r.pattern) and r.pattern == site:
                return r.policy, r.backend or self.default_backend
        for r in self.rules:
            if _is_glob(r.pattern) and fnmatch.fnmatchcase(site, r.pattern):
                return r.policy, r.backend or self.default_backend
        return self.default, self.default_backend

    def policy_for(self, site: str) -> Policy:
        return self.resolve(site)[0]

    def backends(self) -> Tuple[str, ...]:
        """Every backend name the map can resolve to (for validation)."""
        names = {r.backend for r in self.rules if r.backend is not None}
        if self.default_backend is not None:
            names.add(self.default_backend)
        return tuple(sorted(names))

    # -- engine scrub derivation ------------------------------------------

    def scrub_mode(self) -> str:
        """Decode-state scrub mode implied by the transient-site policies
        (``kv_cache`` / ``decode_state``): the stronger ask wins — any CKPT
        ⇒ ``rollback`` (snapshot restore), any ABFT/DMR ⇒ ``detect``
        (alarm only), else ``off``."""
        pols = {self.policy_for("kv_cache"), self.policy_for("decode_state")}
        if Policy.CKPT in pols or Policy.TMR in pols:
            return "rollback"
        if Policy.ABFT in pols or Policy.DMR in pols:
            return "detect"
        return "off"

    def storage_policy(self) -> Policy:
        """Policy assigned to the persistent ``weights`` site — consumed by
        the engine's in-serve storage scrub (ABFT ⇒ detect every pump, CKPT
        ⇒ amortized verify + golden-parameter rollback)."""
        return self.policy_for("weights")

    # -- constructors ------------------------------------------------------

    @classmethod
    def uniform(cls, policy: Union[Policy, str],
                backend: Optional[str] = None) -> "PolicyMap":
        """The degenerate map: every site gets ``policy`` — semantically the
        legacy all-or-nothing configuration (and bit-identical to it; see
        tests/test_policy_map.py)."""
        return cls(rules=(), default=_as_policy(policy),
                   default_backend=backend)

    def is_uniform(self) -> Optional[Policy]:
        """The single policy every site resolves to, or None if mixed."""
        pols = {r.policy for r in self.rules} | {self.default}
        return self.default if len(pols) == 1 else None

    # -- serialization -----------------------------------------------------

    def to_doc(self) -> dict:
        doc = {"default": self.default.value,
               "rules": [r.to_doc() for r in self.rules]}
        if self.default_backend is not None:
            doc["default_backend"] = self.default_backend
        return doc

    @classmethod
    def from_doc(cls, doc: dict) -> "PolicyMap":
        return cls(rules=tuple(PolicyRule.from_doc(r)
                               for r in doc.get("rules", ())),
                   default=_as_policy(doc.get("default", Policy.NONE)),
                   default_backend=doc.get("default_backend"))

    def dumps(self) -> str:
        return json.dumps(self.to_doc(), indent=2)

    @classmethod
    def loads(cls, text: str) -> "PolicyMap":
        return cls.from_doc(json.loads(text))

    def save(self, path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.dumps() + "\n")
        return p

    @classmethod
    def load(cls, path) -> "PolicyMap":
        return cls.loads(pathlib.Path(path).read_text())

    def describe(self) -> str:
        """One-line human rendition, for logs and report tables."""
        parts = [f"{r.pattern}={r.policy.value}"
                 + (f"@{r.backend}" if r.backend else "")
                 for r in self.rules]
        parts.append(f"*={self.default.value}")
        return " ".join(parts)


def as_policy_map(value, *,
                  allow_none: bool = True) -> Optional[PolicyMap]:
    """Coerce user-facing inputs (PolicyMap | dict doc | JSON text | path to
    a JSON file | None) into a PolicyMap — the CLI/engine entry normalizer."""
    if value is None:
        if allow_none:
            return None
        raise ValueError("policy map required")
    if isinstance(value, PolicyMap):
        return value
    if isinstance(value, dict):
        return PolicyMap.from_doc(value)
    if isinstance(value, (str, pathlib.Path)):
        text = str(value)
        if text.lstrip().startswith("{"):
            return PolicyMap.loads(text)
        return PolicyMap.load(text)
    raise TypeError(f"cannot build a PolicyMap from {type(value).__name__}")
