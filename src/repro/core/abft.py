"""Algorithm-Based Fault Tolerance for integer matmul/conv (exact checksums).

The paper achieves dependability *physically* (radiation-hardened silicon).
On a commodity TPU fleet the equivalent threat — SEU bit-flips causing silent
data corruption — is answered *algorithmically*: Huang–Abraham checksums.

The key observation this module exploits: because the paper's technique makes
the hot path **integer** (int8 × int8 → int32), checksums are **exact in
modular arithmetic**.  XLA integer ops wrap (two's complement), so every sum
below is computed mod 2^32, and the identity

    rowsum_N( X·W )  ==  X · (W · 1_N)        (mod 2^32)

holds bit-for-bit.  A flipped bit b < 32 in any accumulator or operand changes
the checksum by ±2^b ≠ 0 (mod 2^32), so single-fault detection has **zero
false positives and zero false negatives** — impossible with float ABFT,
where roundoff forces tolerance windows.  This is a genuine dependability
*improvement* unlocked by the paper's integer-only design.

Detection granularity is per output row; recovery recomputes the affected
block (faults are rare, so `lax.cond` makes the recompute cost ~0 amortized).

The accumulator and check vector both come from the pluggable execution
backend (``core.backend`` / ``kernels.dispatch``): on ``backend="pallas"``
the check vector is fused into the kernel itself — one extra block-row
matvec per K step — so detection covers the paper's actual co-processor
path with no separate checksum pass (see docs/backends.md).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod


class AbftResult(NamedTuple):
    acc: jax.Array        # (M, N) int32 accumulator (possibly corrected)
    ok: jax.Array         # () bool — no fault detected (after correction)
    faults_detected: jax.Array  # () int32 — rows flagged in the first pass


def _dot_i32(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def checksum_vector(w_q: jax.Array) -> jax.Array:
    """W · 1_N — the column-sum check vector, precomputable per layer. (K,) i32."""
    return jnp.sum(w_q.astype(jnp.int32), axis=1)


def zp_bias_correct(acc_dot: jax.Array, x_zp: jax.Array, w_q: jax.Array,
                    bias: jax.Array) -> jax.Array:
    """The matmul dequant algebra, in exactly one place: the zero-point
    correction hoisted out of the inner product plus the bias,
    acc = X·W - zp·colsum(W) + bias.  Shared by the ABFT path here and by
    every non-ABFT policy in core/dependability.py, so the epilogue cannot
    drift between them."""
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    return acc_dot - x_zp.astype(jnp.int32) * colsum[None, :] + bias[None, :]


def verify_rows(x_q: jax.Array, acc_dot: jax.Array, w_check: jax.Array) -> jax.Array:
    """Per-row fault mask for acc_dot = X·W. True == row is clean (mod 2^32)."""
    got = jnp.sum(acc_dot, axis=1)                       # rowsum, wraps mod 2^32
    want = _dot_i32(x_q, w_check[:, None])[:, 0]         # X · (W·1)
    return got == want


def abft_qmatmul(
    x_q: jax.Array,          # (M, K) int8
    x_zp: jax.Array,         # scalar i32
    w_q: jax.Array,          # (K, N) int8
    bias: jax.Array,         # (N,)  i32
    *,
    inject=None,             # optional fn(acc)->acc used by tests to corrupt
    w_check=None,            # precomputed checksum_vector(w) from *deploy time*
    backend: backend_mod.BackendLike = None,
) -> AbftResult:
    """Checksummed quantized matmul accumulator with detect + recompute-recover.

    Overhead: one (M,K)×(K,1) matvec + one row reduction ≈ 1/N of the matmul
    FLOPs (0.8 % for N=128); on ``backend="pallas"`` the matvec is fused into
    the kernel itself (one extra block-row per K step, no second pass over X).

    ``w_check`` lets the caller supply the check vector computed from a known-
    good weight copy (e.g. at checkpoint load).  With it, ABFT also catches
    weight-memory SEUs: a flipped ``w_q`` no longer matches the stored
    checksum.  Without it the checksum is derived from the (possibly already
    corrupted) live weights, so only compute-path faults are covered.
    """
    be = backend_mod.resolve(backend)
    if w_check is None:
        w_check = checksum_vector(w_q)
    acc_dot, want = be.matmul_acc_checksum(x_q, w_q, w_check)
    if inject is not None:
        acc_dot = inject(acc_dot)

    row_ok = jnp.sum(acc_dot, axis=1) == want        # rowsum wraps mod 2^32
    faults = jnp.sum(~row_ok).astype(jnp.int32)

    def recover(acc):
        # Recompute the full product (fault rate is tiny; the recompute branch
        # is taken ~never, so its cost does not affect steady-state throughput).
        fresh = be.matmul_acc(x_q, w_q)
        return jnp.where(row_ok[:, None], acc, fresh)

    acc_dot = jax.lax.cond(faults > 0, recover, lambda a: a, acc_dot)
    ok = jnp.all(jnp.sum(acc_dot, axis=1) == want)
    return AbftResult(zp_bias_correct(acc_dot, x_zp, w_q, bias), ok, faults)


# ---------------------------------------------------------------------------
# Storage scrubbing: the w_check idea generalized to whole parameter pytrees
# ---------------------------------------------------------------------------


def storage_checksums(params):
    """Per-leaf mod-2^32 storage checksums for an arbitrary parameter pytree.

    ``checksum_vector`` protects one matmul's weights; a serving fleet needs
    the same deploy-time guarantee over *every* stored tensor (float params
    included).  Each leaf is bitcast to its same-width unsigned view and
    summed mod 2^32: a flipped bit b changes the sum by ±2^b ≠ 0 (mod 2^32),
    so any single-bit weight-memory SEU is detected exactly — zero false
    positives, zero false negatives, dtype-uniform.

    Returns a pytree of () uint32 leaves mirroring ``params``; compute it
    from the known-good copy at deploy/checkpoint time and scrub live
    replicas against it (``verify_storage``).
    """
    from repro.core.fault_injection import _as_bits

    def one(x):
        bits, _ = _as_bits(jnp.asarray(x))
        return jnp.sum(bits.astype(jnp.uint32))

    return jax.tree_util.tree_map(one, params)


def verify_storage(params, checks):
    """Pytree of () bool leaves: True == leaf still matches its deploy-time
    checksum.  ``jax.tree_util.tree_all`` of the result is the scrub verdict."""
    fresh = storage_checksums(params)
    return jax.tree_util.tree_map(lambda a, b: a == b, fresh, checks)


def output_row_checksums(x: jax.Array) -> jax.Array:
    """``storage_checksums`` at row granularity: the exact mod-2^32 sum of
    ``x``'s bit patterns over its last axis, uint32 with the last axis
    reduced away.

    This is the verification side of the float-op output checksum: a kernel
    that emits its own per-row bit checksum alongside the output (e.g.
    ``kernels.flashattn.flash_attention_checked``) lets the consumer compare
    bit-exactly, so any single-bit flip of the *emitted output* is detected
    with zero false positives/negatives — even though the float compute path
    itself only admits tolerance-based checking.
    """
    from repro.core.fault_injection import _as_bits
    bits, _ = _as_bits(jnp.asarray(x))
    return jnp.sum(bits.astype(jnp.uint32), axis=-1)


# ---------------------------------------------------------------------------
# Conv variant: checksum over output channels
# ---------------------------------------------------------------------------


def conv_checksum_weight(w_q: jax.Array) -> jax.Array:
    """(KH, KW, Cin, Cout) → (KH, KW, Cin, 1): the Cout-summed check filter."""
    return jnp.sum(w_q.astype(jnp.int32), axis=3, keepdims=True)


def abft_qconv2d(
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    stride=(1, 1), padding="SAME", *, inject=None, w_check=None,
    backend: backend_mod.BackendLike = None,
) -> AbftResult:
    """Checksummed quantized conv accumulator (detection per output pixel).

    ``w_check`` — optional precomputed ``conv_checksum_weight`` from a known-
    good weight copy; see ``abft_qmatmul``.
    """
    be = backend_mod.resolve(backend)
    if w_check is None:
        w_check = conv_checksum_weight(w_q)
    acc_dot, want = be.conv_acc_checksum(x_q, x_zp, w_q, w_check, stride,
                                         padding)
    if inject is not None:
        acc_dot = inject(acc_dot)

    got = jnp.sum(acc_dot, axis=3)
    pix_ok = got == want                                 # (N, OH, OW)
    faults = jnp.sum(~pix_ok).astype(jnp.int32)

    def recover(acc):
        fresh = be.conv_acc(x_q, x_zp, w_q, stride, padding)
        return jnp.where(pix_ok[..., None], acc, fresh)

    acc_dot = jax.lax.cond(faults > 0, recover, lambda a: a, acc_dot)
    ok = jnp.all(jnp.sum(acc_dot, axis=3) == want)
    return AbftResult(acc_dot + bias[None, None, None, :], ok, faults)
