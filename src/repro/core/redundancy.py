"""N-modular redundancy with bitwise majority voting.

The classical alternative to rad-hard silicon (and the one the paper cites as
"redundant execution").  Two deployment shapes:

* ``vote`` / ``tmr_apply`` — temporal redundancy: the same computation
  evaluated multiple times (with independent fault injection points in
  tests).  NOTE: XLA will CSE bit-identical pure subgraphs, so temporal
  redundancy against *hardware* faults must go through distinct devices; the
  pure form exists for the fault-injection harness and for voting on values
  that already come from different replicas.

* ``replicated_vote`` — spatial redundancy: `shard_map` over a replica mesh
  axis; each device computes the full function on identical inputs, then an
  all-gather + bitwise-majority vote masks any single-replica corruption.
  This is the cluster rendition of flying three flight computers.

Bitwise majority of three: maj(a,b,c) = (a&b) | (b&c) | (a&c) applied on the
bit-pattern (works for every dtype via bitcast, exact, branch-free, VPU-friendly).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.fault_injection import _as_bits


def _bitwise_majority3(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    ab, u = _as_bits(a)
    bb, _ = _as_bits(b)
    cb, _ = _as_bits(c)
    maj = (ab & bb) | (bb & cb) | (ab & cb)
    return jax.lax.bitcast_convert_type(maj, a.dtype)


def vote(replicas: Sequence[jax.Array]) -> jax.Array:
    """Majority vote across replica outputs (pytree-compatible leaves).

    3 replicas → bitwise majority (corrects any single corrupted replica).
    2 replicas → detection only: returns replica 0; use ``agree`` to check.
    """
    if len(replicas) == 3:
        return jax.tree_util.tree_map(_bitwise_majority3, *replicas)
    if len(replicas) == 2:
        return replicas[0]
    raise ValueError(f"vote() supports 2 or 3 replicas, got {len(replicas)}")


def agree(replicas: Sequence[jax.Array]) -> jax.Array:
    """() bool — all replicas bit-identical (DMR detection predicate)."""
    flat0 = jax.tree_util.tree_leaves(replicas[0])
    ok = jnp.array(True)
    for other in replicas[1:]:
        for a, b in zip(flat0, jax.tree_util.tree_leaves(other)):
            ab, _ = _as_bits(a)
            bb, _ = _as_bits(b)
            ok = ok & jnp.all(ab == bb)
    return ok


def dmr_apply(f: Callable, *args, injectors: Sequence[Callable | None] = (None, None)):
    """Dual modular redundancy, detect-only: run ``f`` twice (each pass
    optionally perturbed by an injector) and compare bit-for-bit.

    Returns ``(y0, detected)`` — replica 0's output plus a () bool that is
    True when the replicas disagree.  DMR cannot vote a fault away (no
    majority exists); its role is the cheap detect-then-escalate partner of
    a failover layer: half the cost of TMR, full single-fault detection.
    """
    outs = []
    for inj in injectors:
        y = f(*args)
        if inj is not None:
            y = jax.tree_util.tree_map(inj, y)
        outs.append(y)
    return outs[0], ~agree(outs)


def tmr_apply(f: Callable, *args, injectors: Sequence[Callable | None] = (None, None, None)):
    """Run ``f`` three times, each optionally perturbed by an injector
    (tests thread fault injection through here), and vote."""
    outs = []
    for inj in injectors:
        y = f(*args)
        if inj is not None:
            y = jax.tree_util.tree_map(inj, y)
        outs.append(y)
    return vote(outs)


def replicated_vote(f: Callable, mesh: jax.sharding.Mesh, axis: str = "replica"):
    """Spatial TMR: each device along ``axis`` (size 3) computes f fully,
    results are all-gathered and majority-voted on every device.

    Returns a function with the same signature as f; inputs must be
    replicated along ``axis``.
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    def voted(*args):
        y = f(*args)

        def gather_vote(leaf):
            allr = jax.lax.all_gather(leaf, axis)          # (3, ...)
            return _bitwise_majority3(allr[0], allr[1], allr[2])

        return jax.tree_util.tree_map(gather_vote, y)

    return shard_map(voted, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_vma=False)
