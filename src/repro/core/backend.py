"""Pluggable execution backends for the quantized primitives.

The paper's central system claim is that the HPDP is a swappable
*mathematical backend*: "the AI framework executes workloads directly on
this co-processor without requiring additional hardware-specific coding".
This module is that claim as an API.  Every quantized primitive (qmatmul,
qconv2d) registers interchangeable implementations behind one registry:

  ref     independent jnp oracle (int32-upcast math / explicit tap loop) —
          the Fig.-4 "PyTorch reference" role
  jnp     XLA-native int8 dot_general / conv_general_dilated — the fleet
          default on CPU and the fastest path XLA fuses on its own
  pallas  the Pallas TPU kernels (interpret=True off-TPU) — the paper's
          actual co-processor path, including the fused ABFT checksum

The registry's uniform signature is **accumulator-level**: every backend
returns the raw int32 accumulator (and, for the checksummed entry, the
in-path ABFT check vector), so campaign ``inject`` hooks and the
Huang–Abraham verification compose with *any* backend — the dependability
layer is written once against a ``Backend`` handle and never mentions a
specific execution engine again.

Selection precedence (most specific wins):

  1. per-call   ``dependable_qmatmul(..., backend="pallas")``
  2. per-layer  model configs carry a backend (``ArchConfig.backend``,
                per-layer lists in ``models/shipdet.forward``)
  3. global     ``set_default_backend`` / ``use_backend`` context manager

All three accept either a backend name or a ``Backend`` instance.  Because
the hot path is integer (int8 × int8 → int32, exact mod 2^32), every
registered backend is **bit-identical** — the parity tests in
``tests/test_backend.py`` enforce it, and a campaign certified on one
backend transfers to another only because this property holds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax

BackendLike = Union[str, "Backend", None]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution engine for the quantized primitives.

    All entries are accumulator-level (no bias, no requantization — those
    are policy-layer algebra shared by every backend):

      matmul_acc(x_q i8 (M,K), w_q i8 (K,N)) -> i32 (M,N)
          the raw dot X·W (zero-point correction applied downstream)
      matmul_acc_checksum(x_q, w_q, w_check i32 (K,)) -> (acc, want (M,))
          acc as above plus the ABFT check vector want = X·w_check,
          computed *in the execution path* (fused into the kernel on the
          pallas backend)
      conv_acc(x_q i8 NHWC, x_zp i32, w_q i8 HWIO, stride, padding)
          -> i32 (N,OH,OW,Cout): conv(x_q - x_zp, w_q)
      conv_acc_checksum(x_q, x_zp, w_q, w_check i32 (KH,KW,Cin,1),
                        stride, padding) -> (acc, want (N,OH,OW))

    The attention entries cover the one float hot kernel (flash attention;
    optional so out-of-tree integer-only backends stay valid):

      attn(q (B,H,S,hd), k, v (B,KV,S,hd), *, causal, window)
          -> (B,H,S,hd): fused causal/sliding-window attention
      attn_checksum(q, k, v, *, causal, window) -> (out, check, csum)
          out as above; ``check`` (B,H,S) f32 is an independently accumulated
          rowsum_hd(out) column (tolerance-verified compute-path cover);
          ``csum`` (B,H,S) u32 is the exact mod-2^32 bit checksum of the
          emitted output rows (bit-exact output-integrity cover) — both
          fused into the kernel on the pallas backend
    """

    name: str
    matmul_acc: Callable[..., jax.Array]
    matmul_acc_checksum: Callable[..., Tuple[jax.Array, jax.Array]]
    conv_acc: Callable[..., jax.Array]
    conv_acc_checksum: Callable[..., Tuple[jax.Array, jax.Array]]
    description: str = ""
    attn: Optional[Callable[..., jax.Array]] = None
    attn_checksum: Optional[
        Callable[..., Tuple[jax.Array, jax.Array, jax.Array]]] = None


_REGISTRY: Dict[str, Backend] = {}
# thread-local so `use_backend` nesting in concurrent test runners can't
# bleed a temporary default across threads
_STATE = threading.local()
_GLOBAL_DEFAULT = "jnp"


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend to the registry (how out-of-tree engines plug in)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[backend.name] = backend
    return backend


def _ensure_builtins() -> None:
    # The built-in implementations live next to the kernels they wrap;
    # importing the dispatch module registers them.  Lazy so core/ never
    # imports kernels/ at module load (no cycle).
    if "jnp" not in _REGISTRY:
        from repro.kernels import dispatch  # noqa: F401  (registers on import)


def available_backends() -> List[str]:
    """Registered backend names, built-ins guaranteed present."""
    _ensure_builtins()
    return sorted(_REGISTRY)


def get_backend(name: str) -> Backend:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; known: {sorted(_REGISTRY)}"
                       ) from None


def default_backend() -> str:
    """The currently active global default (innermost ``use_backend`` wins)."""
    stack = getattr(_STATE, "stack", None)
    return stack[-1] if stack else _GLOBAL_DEFAULT


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend (validated)."""
    global _GLOBAL_DEFAULT
    get_backend(name)
    _GLOBAL_DEFAULT = name


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped global selection: every op inside the block that does not get
    a more specific (per-layer / per-call) choice runs on ``name``."""
    get_backend(name)
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


def resolve(backend: BackendLike = None) -> Backend:
    """Per-call > per-layer > global precedence collapses to one rule: the
    most specific non-None choice reaches this function first."""
    if isinstance(backend, Backend):
        return backend
    return get_backend(backend if backend is not None else default_backend())
