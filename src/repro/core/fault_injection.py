"""SEU (single-event upset) simulator: PRNG-driven bit flips in live tensors.

The paper's threat model is radiation-induced bit flips in non-hardened
logic/SRAM.  This module recreates that threat in software so the
dependability layers (ABFT, NMR, checkpoint/restart) can be *proven* to
detect and recover — the same role the XDBG fault-visibility tooling plays in
the paper's verification methodology.

Flips are implemented by bitcasting to the same-width unsigned integer type,
XOR-ing a single bit, and bitcasting back — works uniformly for int8/int32,
bf16, f32.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_UINT_FOR_WIDTH = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


def _as_bits(x: jax.Array) -> Tuple[jax.Array, jnp.dtype]:
    nbytes = x.dtype.itemsize
    u = _UINT_FOR_WIDTH[nbytes]
    return jax.lax.bitcast_convert_type(x, u), u


def _random_bit(x: jax.Array, key: jax.Array):
    """Pick one uniformly-random bit of one uniformly-random element.

    Returns (flat_bits, element_index, bit_mask, uint_dtype) — the shared
    targeting step of every single-bit fault model.
    """
    bits, u = _as_bits(x)
    flat = bits.reshape(-1)
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (), 0, flat.shape[0])
    bit = jax.random.randint(k2, (), 0, x.dtype.itemsize * 8)
    mask = (jnp.ones((), u) << bit.astype(u)).astype(u)
    return flat, idx, mask, u


def flip_one_bit(x: jax.Array, key: jax.Array) -> jax.Array:
    """Flip exactly one uniformly-random bit of one uniformly-random element."""
    flat, idx, mask, _ = _random_bit(x, key)
    flat = flat.at[idx].set(flat[idx] ^ mask)
    return jax.lax.bitcast_convert_type(flat.reshape(x.shape), x.dtype)


def flip_bit_at(x: jax.Array, key: jax.Array, bit) -> jax.Array:
    """Flip the given bit position of one uniformly-random element.

    The targeted cousin of ``flip_one_bit``: campaigns sweep ``bit`` over
    the word to map per-bit-position coverage (which accumulator bits
    requantization masks vs. which a policy detects).  ``bit`` may be a
    traced value, so a whole bit sweep vmaps in one compile.
    """
    bits, u = _as_bits(x)
    flat = bits.reshape(-1)
    idx = jax.random.randint(key, (), 0, flat.shape[0])
    mask = (jnp.ones((), u) << jnp.asarray(bit, u)).astype(u)
    flat = flat.at[idx].set(flat[idx] ^ mask)
    return jax.lax.bitcast_convert_type(flat.reshape(x.shape), x.dtype)


def flip_burst(x: jax.Array, key: jax.Array, elems: int = 2,
               bits: int = 2) -> jax.Array:
    """MBU burst: flip a seeded cluster of physically adjacent cells — the
    multi-bit upset signature neutron irradiation produces in dense SRAM
    (one particle strike upsetting neighbouring cells, not independent
    random bits).  The cluster is an ``elems × bits`` rectangle: the same
    ``bits`` adjacent bit positions flipped in ``elems`` adjacent elements
    of the flattened tensor, anchored at a uniformly-random (element, bit)
    and clamped inside the tensor/word so every burst has the same size.
    jit/vmap-safe for static (elems, bits).
    """
    bit_words, u = _as_bits(x)
    flat = bit_words.reshape(-1)
    n = flat.shape[0]
    width = x.dtype.itemsize * 8
    span_e = min(elems, n)
    span_b = min(bits, width)
    k1, k2 = jax.random.split(key)
    e0 = jnp.minimum(jax.random.randint(k1, (), 0, n),
                     jnp.asarray(n - span_e, jnp.int32))
    b0 = jax.random.randint(k2, (), 0, width - span_b + 1)
    mask = jnp.zeros((), u)
    for db in range(span_b):
        mask = mask | (jnp.ones((), u) << (b0 + db).astype(u)).astype(u)
    for de in range(span_e):
        flat = flat.at[e0 + de].set(flat[e0 + de] ^ mask)
    return jax.lax.bitcast_convert_type(flat.reshape(x.shape), x.dtype)


def flip_bits_at_rate(x: jax.Array, key: jax.Array, rate: float) -> jax.Array:
    """Flip each bit independently with probability ``rate`` (fleet-scale SEU model)."""
    bits, u = _as_bits(x)
    nbits = x.dtype.itemsize * 8
    k = jax.random.split(key, nbits)
    out = bits
    for b in range(nbits):
        hit = jax.random.bernoulli(k[b], rate, bits.shape)
        mask = jnp.where(hit, jnp.ones((), u) << jnp.array(b, u), jnp.zeros((), u))
        out = out ^ mask
    return jax.lax.bitcast_convert_type(out, x.dtype)


def stuck_at(x: jax.Array, key: jax.Array, stuck_value: int = 1) -> jax.Array:
    """Force one uniformly-random bit of one uniformly-random element to
    ``stuck_value`` (classic stuck-at-0 / stuck-at-1 fault model).

    Unlike ``flip_one_bit`` this is idempotent and can be *masked at the
    site*: if the chosen bit already holds ``stuck_value`` the tensor is
    unchanged, so campaigns over stuck-at faultloads see a ~50% intrinsic
    masking floor — the same behaviour DAVOS-style RTL campaigns report.
    """
    flat, idx, mask, u = _random_bit(x, key)
    stuck = jnp.where(jnp.asarray(stuck_value, u) != 0,
                      flat[idx] | mask, flat[idx] & ~mask)
    flat = flat.at[idx].set(stuck)
    return jax.lax.bitcast_convert_type(flat.reshape(x.shape), x.dtype)


def inject_pytree_with(params, key: jax.Array, fault):
    """Apply ``fault(x, key) -> x'`` to one random leaf of a pytree, chosen
    weighted by element count (uniform over elements).  Host-side: the leaf
    choice materializes, so this cannot run under jit/vmap."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    sizes = jnp.asarray([l.size for l in leaves], jnp.float32)
    k_leaf, k_fault = jax.random.split(key)
    leaf_idx = int(jax.random.choice(k_leaf, len(leaves), p=sizes / sizes.sum()))
    leaves[leaf_idx] = fault(leaves[leaf_idx], k_fault)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def inject_into_pytree(params, key: jax.Array, n_flips: int = 1):
    """Flip ``n_flips`` single bits, each in a random leaf of a pytree
    (weight-memory SEU model for checkpoint/restart tests)."""
    # an independent key per flip — re-flipping the same bit with a shared
    # key would XOR-cancel and silently weaken the drill
    for k in jax.random.split(key, n_flips):
        params = inject_pytree_with(params, k, flip_one_bit)
    return params
