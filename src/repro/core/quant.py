"""Integer-arithmetic-only quantization (Jacob et al., arXiv:1712.05877).

This module is the numerical core of the paper's technique: the HPDP executes
convolution with int8 weights/activations, accumulates in int32, and
*re-quantizes* the accumulator back to int8 so the next layer can consume it —
all driven purely by runtime parameters (scales, zero-points, bias).

Two requantization semantics are provided:

1. ``requantize`` (JAX, fp32 scaling) — the TPU-native path used by every
   kernel and model in this framework.  TPU Pallas has no int64, so the
   gemmlowp fixed-point pipeline (SRDHM + rounding shift) cannot run on the
   MXU; instead the int32 accumulator is scaled in fp32 and rounded
   half-to-even.  This is the XNNPACK/TFLite-GPU convention and is
   bit-identical to gemmlowp except on exact 0.5-ULP ties.

2. ``requantize_gemmlowp_np`` (NumPy, integer-exact) — the HPDP-faithful
   oracle implementing gemmlowp's SaturatingRoundingDoublingHighMul +
   RoundingDivideByPOT in int64.  Tests measure agreement between the two
   (`tests/test_quant.py`).

Conventions (TFLite-compatible):
  * activations: asymmetric int8 in [-128, 127], per-tensor (scale, zero_point)
  * weights:     symmetric  int8 in [-127, 127], per-channel scale, zp == 0
  * bias:        int32 with scale = s_in * s_w, zp == 0
  * accumulator: int32
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT8_MIN, INT8_MAX = -128, 127
WEIGHT_QMIN, WEIGHT_QMAX = -127, 127  # symmetric, avoids -128 asymmetry


# ---------------------------------------------------------------------------
# Quantized tensor container
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """An int8 tensor with its affine quantization parameters.

    ``scale`` is a scalar (per-tensor) or a 1-D vector along ``axis``
    (per-channel).  ``zero_point`` is int32, always per-tensor (0 for
    weights).
    """

    q: jax.Array                       # int8 payload
    scale: jax.Array                   # f32 scalar or per-channel vector
    zero_point: jax.Array              # i32 scalar
    axis: Optional[int] = dataclasses.field(default=None, metadata=dict(static=True))

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return self.q.dtype

    def dequantize(self) -> jax.Array:
        scale = self.scale
        if self.axis is not None:
            bshape = [1] * self.q.ndim
            bshape[self.axis] = -1
            scale = scale.reshape(bshape)
        return (self.q.astype(jnp.float32) - self.zero_point.astype(jnp.float32)) * scale


# ---------------------------------------------------------------------------
# Quantization parameter selection (calibration)
# ---------------------------------------------------------------------------


def affine_qparams(
    min_val: jax.Array, max_val: jax.Array, qmin: int = INT8_MIN, qmax: int = INT8_MAX
) -> Tuple[jax.Array, jax.Array]:
    """Asymmetric (scale, zero_point) covering [min_val, max_val].

    The range is nudged to always include 0.0 (required so that zero padding
    is exactly representable — Jacob et al. §2.1).
    """
    min_val = jnp.minimum(min_val, 0.0)
    max_val = jnp.maximum(max_val, 0.0)
    scale = (max_val - min_val) / (qmax - qmin)
    scale = jnp.maximum(scale, 1e-9)
    zp = qmin - min_val / scale
    zero_point = jnp.clip(jnp.round(zp), qmin, qmax).astype(jnp.int32)
    return scale.astype(jnp.float32), zero_point


def symmetric_qparams(
    abs_max: jax.Array, qmax: int = WEIGHT_QMAX
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric (scale, zero_point=0) for weights."""
    scale = jnp.maximum(abs_max, 1e-9) / qmax
    return scale.astype(jnp.float32), jnp.zeros((), jnp.int32)


def quantize(x: jax.Array, scale: jax.Array, zero_point: jax.Array,
             qmin: int = INT8_MIN, qmax: int = INT8_MAX) -> jax.Array:
    """Float → int8 with round-half-to-even (matches XLA/TPU rounding)."""
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, qmin, qmax).astype(jnp.int8)


def quantize_activation(x: jax.Array) -> QTensor:
    """Per-tensor asymmetric activation quantization from observed min/max."""
    scale, zp = affine_qparams(jnp.min(x), jnp.max(x))
    return QTensor(quantize(x, scale, zp), scale, zp)


def quantize_weight(w: jax.Array, axis: int = -1) -> QTensor:
    """Per-channel symmetric weight quantization along ``axis``."""
    axis = axis % w.ndim
    reduce_dims = tuple(d for d in range(w.ndim) if d != axis)
    abs_max = jnp.max(jnp.abs(w), axis=reduce_dims)
    scale, zp = symmetric_qparams(abs_max)
    bshape = [1] * w.ndim
    bshape[axis] = -1
    q = jnp.clip(jnp.round(w / scale.reshape(bshape)), WEIGHT_QMIN, WEIGHT_QMAX)
    return QTensor(q.astype(jnp.int8), scale, zp, axis=axis)


def quantize_bias(b: jax.Array, input_scale: jax.Array, weight_scale: jax.Array) -> jax.Array:
    """Bias is int32 at scale s_in * s_w (per-channel if the weight is)."""
    scale = input_scale * weight_scale
    return jnp.round(b / scale).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Requantization — fp32 path (TPU-native, used in kernels and jnp refs)
# ---------------------------------------------------------------------------


def requant_scale(input_scale, weight_scale, output_scale) -> jax.Array:
    """The real multiplier M = s_in * s_w / s_out  (per-channel if s_w is)."""
    return (input_scale * weight_scale / output_scale).astype(jnp.float32)


def requantize(acc: jax.Array, scale: jax.Array, out_zero_point: jax.Array,
               qmin: int = INT8_MIN, qmax: int = INT8_MAX) -> jax.Array:
    """int32 accumulator → int8 output, fp32 scaling, round-half-to-even.

    ``scale`` broadcasts against the trailing (channel) dimension when
    per-channel.
    """
    y = acc.astype(jnp.float32) * scale
    y = jnp.round(y) + out_zero_point.astype(jnp.float32)
    return jnp.clip(y, qmin, qmax).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Requantization — gemmlowp integer-exact path (HPDP-faithful NumPy oracle)
# ---------------------------------------------------------------------------


def quantize_multiplier_np(real_multiplier: float) -> Tuple[int, int]:
    """real ≈ qm * 2**(shift-31) with qm an int32 in [2^30, 2^31).

    TFLite's ``QuantizeMultiplier``.  Returns (quantized_multiplier, shift).
    """
    if real_multiplier == 0.0:
        return 0, 0
    m, exponent = math.frexp(real_multiplier)  # real = m * 2**exponent, m in [0.5, 1)
    qm = int(round(m * (1 << 31)))
    if qm == (1 << 31):
        qm //= 2
        exponent += 1
    assert qm <= (1 << 31)
    return qm, exponent


def srdhm_np(a: np.ndarray, b: int) -> np.ndarray:
    """gemmlowp SaturatingRoundingDoublingHighMul (vectorized int64)."""
    a = a.astype(np.int64)
    ab = a * np.int64(b)
    nudge = np.where(ab >= 0, np.int64(1 << 30), np.int64(1 - (1 << 30)))
    result = (ab + nudge) >> np.int64(31)
    # saturate the single overflow case a == b == INT32_MIN
    overflow = (a == np.int64(-(1 << 31))) & (np.int64(b) == np.int64(-(1 << 31)))
    return np.where(overflow, np.int64((1 << 31) - 1), result).astype(np.int64)


def rounding_divide_by_pot_np(x: np.ndarray, exponent: int) -> np.ndarray:
    """gemmlowp RoundingDivideByPOT: round-half-away division by 2**exponent."""
    if exponent == 0:
        return x
    mask = np.int64((1 << exponent) - 1)
    remainder = x & mask
    threshold = (mask >> 1) + np.where(x < 0, np.int64(1), np.int64(0))
    return (x >> np.int64(exponent)) + np.where(remainder > threshold, np.int64(1), np.int64(0))


def requantize_gemmlowp_np(
    acc: np.ndarray, real_multiplier: np.ndarray, out_zero_point: int,
    qmin: int = INT8_MIN, qmax: int = INT8_MAX,
) -> np.ndarray:
    """Integer-exact requantization — the HPDP/gemmlowp reference.

    ``real_multiplier`` may be a scalar or a per-channel vector broadcast
    against acc's last dim.
    """
    acc = np.asarray(acc, dtype=np.int64)
    multipliers = np.broadcast_to(np.atleast_1d(real_multiplier), (acc.shape[-1],))
    out = np.empty_like(acc)
    for c in range(acc.shape[-1]):
        qm, shift = quantize_multiplier_np(float(multipliers[c]))
        left_shift = max(shift, 0)
        right_shift = max(-shift, 0)
        x = acc[..., c] << np.int64(left_shift)
        x = srdhm_np(x, qm)
        x = rounding_divide_by_pot_np(x, right_shift)
        out[..., c] = x
    out = out + np.int64(out_zero_point)
    return np.clip(out, qmin, qmax).astype(np.int8)


# ---------------------------------------------------------------------------
# Fake quantization (QAT) with straight-through estimator
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fake_quant(x, scale, zero_point, qmin: int = INT8_MIN, qmax: int = INT8_MAX):
    q = jnp.clip(jnp.round(x / scale) + zero_point, qmin, qmax)
    return (q - zero_point) * scale


def _fake_quant_fwd(x, scale, zero_point, qmin, qmax):
    q = jnp.round(x / scale) + zero_point
    mask = (q >= qmin) & (q <= qmax)
    y = (jnp.clip(q, qmin, qmax) - zero_point) * scale
    return y, mask


def _fake_quant_bwd(qmin, qmax, mask, g):
    # straight-through inside the clip range, zero outside
    return (jnp.where(mask, g, 0.0), None, None)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


# ---------------------------------------------------------------------------
# Calibration observer (min/max running stats)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MinMaxObserver:
    """EMA min/max observer for post-training calibration."""

    min_val: jax.Array
    max_val: jax.Array
    momentum: float = dataclasses.field(default=0.99, metadata=dict(static=True))

    @staticmethod
    def init() -> "MinMaxObserver":
        return MinMaxObserver(jnp.zeros(()), jnp.zeros(()))

    def update(self, x: jax.Array) -> "MinMaxObserver":
        m = self.momentum
        new_min = m * self.min_val + (1 - m) * jnp.min(x)
        new_max = m * self.max_val + (1 - m) * jnp.max(x)
        return MinMaxObserver(new_min, new_max, self.momentum)

    def qparams(self):
        return affine_qparams(self.min_val, self.max_val)
