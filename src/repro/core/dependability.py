"""Dependability policy layer — composes ABFT / NMR / retry around the
quantized compute primitives.

This is the framework-level rendition of the paper's thesis: *dependable AI
execution is a property of the execution system, not of the model*.  Models
ask for a ``qlinear``; the policy decides how it is executed:

  NONE  — plain fused kernel (maximum throughput; rad-hard hardware assumed,
          as on the HPDP itself).
  ABFT  — exact integer checksum verify + recompute-recover (default for
          fleet deployment; ~1/N FLOP overhead).
  DMR   — dual execution + bitwise compare (2× cost, detect-only): raises
          the alarm but returns replica 0's output unchanged.  The cheap
          partner of a failover layer — the fleet supervisor quarantines the
          flagged replica and replays the work elsewhere.
  TMR   — triple execution + bitwise majority vote (3× cost; for the few
          layers whose corruption is mission-fatal, e.g. the final
          classification head of the ship detector).
  CKPT  — checkpoint/restart: detect via the same exact mod-2^32 checksum
          ABFT uses, but recover by *rolling back to the golden
          checkpointed operands and re-executing the whole op* instead of
          ABFT's selective row recompute.  With a golden operand checkpoint
          (``ckpt=``) the rollback also heals weight-memory SEUs — the one
          storage fault class ABFT can detect but never repair in place.
          Detection cost is ABFT's ~1/N; recovery cost is one full
          re-execution, paid only on (rare) detection.  See
          docs/recovery.md.

Policies are data (config enums), so a deployment can mix them per layer —
matching how the paper reserves the rad-hard HPDP for the convolution hot
path while the RTG4 handles orchestration.

*Where* the accumulator is computed is equally data: every policy is built
around a pluggable execution backend (``core.backend``), so the same
NONE/ABFT/DMR/TMR algebra runs unchanged on the jnp path, the independent
ref oracle, or the Pallas kernels — the swappable-co-processor property the
paper claims for the HPDP.  The zero-point/bias dequant algebra lives in
one shared helper (``abft.zp_bias_correct``), used by every backend and
policy, so the epilogue cannot drift between paths.
"""
from __future__ import annotations

import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import abft as abft_mod
from repro.core import backend as backend_mod
from repro.core import redundancy
from repro.core.quant import requantize


class Policy(str, enum.Enum):
    NONE = "none"
    ABFT = "abft"
    DMR = "dmr"
    TMR = "tmr"
    CKPT = "ckpt"


class DependabilityStats:
    """Host-side counters exported by dependable ops (pytree of scalars).

    ``faults_detected``  checks that flagged a divergence (ABFT checksum
                         mismatch, DMR/TMR replica disagreement).
    ``faults_corrected`` detected faults the op also healed in-place (ABFT
                         recompute-recovery that re-verified clean, TMR
                         majority votes that out-voted the bad replica).
                         DMR never corrects — its count stays 0 and the gap
                         vs ``faults_detected`` is exactly the failover
                         layer's workload.
    ``faults_recovered`` detected faults healed by *rollback* — checkpoint/
                         restart re-execution from golden state (CKPT ops,
                         engine snapshot restores, fleet incremental
                         restores).  Disjoint accounting from
                         ``faults_corrected`` so reports can separate
                         in-place correction from restart recovery.
    ``checks_run``       how many verification opportunities executed.
    """

    @staticmethod
    def zero():
        return {"faults_detected": jnp.zeros((), jnp.int32),
                "faults_corrected": jnp.zeros((), jnp.int32),
                "faults_recovered": jnp.zeros((), jnp.int32),
                "checks_run": jnp.zeros((), jnp.int32)}

    @staticmethod
    def merge(a: dict, b: dict) -> dict:
        """Keywise sum over the union of two stats pytrees (campaign /
        engine rollups; tolerant of older dicts missing newer counters)."""
        zero = jnp.zeros((), jnp.int32)
        return {k: a.get(k, zero) + b.get(k, zero)
                for k in {*a, *b}}

    @staticmethod
    def to_host(stats: dict) -> dict:
        """Device scalars → plain ints, for JSON reports and log lines."""
        return {k: int(v) for k, v in stats.items()}


def _bump(stats: dict, detected, corrected, recovered=False) -> dict:
    """One verification round folded into the running counters."""
    return {
        "faults_detected": stats["faults_detected"]
        + jnp.asarray(detected).astype(jnp.int32),
        "faults_corrected": stats.get("faults_corrected", jnp.int32(0))
        + jnp.asarray(corrected).astype(jnp.int32),
        "faults_recovered": stats.get("faults_recovered", jnp.int32(0))
        + jnp.asarray(recovered).astype(jnp.int32),
        "checks_run": stats["checks_run"] + 1,
    }


def dependable_qmatmul(
    policy: Policy,
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    scale: jax.Array, out_zp: jax.Array,
    *, inject=None, stats: Optional[dict] = None, w_check=None,
    ckpt=None, backend: backend_mod.BackendLike = None,
):
    """Quantized matmul + requant executed under a dependability policy.

    ``inject`` corrupts the int32 accumulator (the campaign engine's
    accumulator injection site); ``w_check`` is the optional deploy-time
    checksum vector (see ``abft.abft_qmatmul``); ``ckpt`` is the optional
    golden operand checkpoint ``(x_q, w_q)`` the CKPT policy rolls back to
    (defaults to the live operands — transient coverage only); ``backend``
    picks the execution engine (per-call > per-layer > global, see
    core/backend.py).  Returns (y_q int8, stats).
    """
    if stats is None:
        stats = DependabilityStats.zero()
    be = backend_mod.resolve(backend)

    def finish(acc_dot):
        # shared dequant epilogue (abft.zp_bias_correct is the same algebra
        # the ABFT path applies), then requant
        return requantize(abft_mod.zp_bias_correct(acc_dot, x_zp, w_q, bias),
                          scale, out_zp)

    if policy == Policy.ABFT:
        res = abft_mod.abft_qmatmul(x_q, x_zp, w_q, bias, inject=inject,
                                    w_check=w_check, backend=be)
        y = requantize(res.acc, scale, out_zp)
        corrected = res.faults_detected * res.ok.astype(jnp.int32)
        return y, _bump(stats, res.faults_detected, corrected)

    if policy == Policy.CKPT:
        # checkpoint/restart: checksum-detect, then roll back to the golden
        # operand checkpoint and re-execute everything (epilogue included —
        # a corrupted w_q must not leak through zp/colsum algebra)
        ck_x, ck_w = (x_q, w_q) if ckpt is None else ckpt
        wc = w_check if w_check is not None else abft_mod.checksum_vector(ck_w)
        acc_dot, want = be.matmul_acc_checksum(x_q, w_q, wc)
        if inject is not None:
            acc_dot = inject(acc_dot)
        detected = jnp.any(jnp.sum(acc_dot, axis=1) != want)

        def rollback(_):
            return be.matmul_acc(ck_x, ck_w), ck_w

        acc_dot, w_eff = jax.lax.cond(
            detected, rollback, lambda a: (a, w_q), acc_dot)
        # re-verify the restart: clean ⇒ the fault did not recur
        recovered = detected & jnp.all(jnp.sum(acc_dot, axis=1) == want)
        y = requantize(abft_mod.zp_bias_correct(acc_dot, x_zp, w_eff, bias),
                       scale, out_zp)
        return y, _bump(stats, detected, False, recovered)

    def run(inj):
        # inject corrupts replica 0's accumulator — the same site as the
        # ABFT/NONE paths, so policy sweeps compare like for like
        acc = be.matmul_acc(x_q, w_q)
        if inj is not None:
            acc = inj(acc)
        return finish(acc)

    if policy == Policy.DMR:
        # detect-only: replica 0 (possibly faulted) is returned as-is;
        # disagreement with the clean re-execution raises the alarm
        y = run(inject)
        detected = ~redundancy.agree([y, run(None)])
        return y, _bump(stats, detected, False)

    if policy == Policy.TMR:
        r0, r1 = run(inject), run(None)
        # replicas 1–2 are clean, so r0-vs-r1 disagreement is exactly the
        # set of faults the majority vote is about to mask — count them
        disagreed = ~redundancy.agree([r0, r1])
        y = redundancy.vote([r0, r1, run(None)])
        return y, _bump(stats, disagreed, disagreed)

    # Policy.NONE — plain path
    return run(inject), stats


def dependable_matmul_acc(
    policy: Policy,
    x_q: jax.Array, w_q: jax.Array,
    *, inject=None, stats: Optional[dict] = None, w_check=None,
    backend: backend_mod.BackendLike = None,
):
    """Bare int32 accumulator ``x_q @ w_q`` under a dependability policy —
    the building block :class:`~repro.core.policy_map.PolicyMap` threads
    into hot paths that own their *own* dequant epilogue (the transformer's
    W8A8 FFN ``_qdot``), where ``dependable_qmatmul``'s zero-point/requant
    algebra does not apply.

    All policies are bit-identical to the plain ``be.matmul_acc`` on clean
    runs: the math is exact integer, checks never fire, votes of equal
    replicas are the replica.  Per policy:

      ABFT  Huang–Abraham row-checksum verify; flagged *rows* recompute
            under ``lax.cond`` (exact math ⇒ bit-stable) and the fresh rows
            are selected in.  Heals transient accumulator faults in place.
      CKPT  same detection; rollback re-executes the *whole* op from the
            live operands under ``lax.cond``.
      DMR   dual execution; detect-only.  NOTE: inside a ``lax.scan`` layer
            stack the alarm has no surface to escape through, so the
            serving DSE search space excludes DMR at FFN sites — the stats
            counter is the only witness.
      TMR   triple execution + bitwise majority vote.  Under jit, XLA CSE
            may collapse bit-identical clean replicas — temporal redundancy
            is modeled, not physically enforced; the measured cost oracle
            (repro/dse/cost.py) reports whatever the compiled program
            actually costs.

    Returns ``(acc int32, stats)``.
    """
    if stats is None:
        stats = DependabilityStats.zero()
    be = backend_mod.resolve(backend)

    if policy in (Policy.ABFT, Policy.CKPT):
        wc = w_check if w_check is not None else abft_mod.checksum_vector(w_q)
        acc, want = be.matmul_acc_checksum(x_q, w_q, wc)
        if inject is not None:
            acc = inject(acc)
        row_bad = jnp.sum(acc, axis=1) != want
        detected = jnp.any(row_bad)

        if policy == Policy.ABFT:
            def recover(a):
                fresh = be.matmul_acc(x_q, w_q)
                return jnp.where(row_bad[:, None], fresh, a)
            acc = jax.lax.cond(detected, recover, lambda a: a, acc)
        else:
            def rollback(_):
                return be.matmul_acc(x_q, w_q)
            acc = jax.lax.cond(detected, rollback, lambda a: a, acc)
        healed = detected & jnp.all(jnp.sum(acc, axis=1) == want)
        corrected = healed if policy == Policy.ABFT else False
        recovered = healed if policy == Policy.CKPT else False
        return acc, _bump(stats, detected, corrected, recovered)

    def run(inj):
        acc = be.matmul_acc(x_q, w_q)
        if inj is not None:
            acc = inj(acc)
        return acc

    if policy == Policy.DMR:
        acc = run(inject)
        detected = ~redundancy.agree([acc, run(None)])
        return acc, _bump(stats, detected, False)

    if policy == Policy.TMR:
        r0, r1 = run(inject), run(None)
        disagreed = ~redundancy.agree([r0, r1])
        acc = redundancy.vote([r0, r1, run(None)])
        return acc, _bump(stats, disagreed, disagreed)

    return run(inject), stats


def dependable_attention(
    policy: Policy,
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal=True, window=None,
    inject=None, stats: Optional[dict] = None,
    backend: backend_mod.BackendLike = None, tol: float = 1e-3,
):
    """Fused attention (B,H,S,hd) under a dependability policy — the float
    twin of ``dependable_qmatmul`` covering the one hot kernel the integer
    quantization story cannot absorb.

    Float math admits no exact compute checksum, so ABFT here is two-tier
    (see kernels/flashattn and docs/backends.md):

      * a float check column accumulated *in the execution path* alongside
        the output, verified as ``|rowsum_hd(out) - check| <= tol*(|check|+1)``
        — tolerance-based, covers the softmax/accumulate compute path;
      * an exact mod-2^32 bit checksum of the emitted output rows, verified
        bit-for-bit — covers the emitted result itself, so any single bit
        flip of the output is detected with zero false negatives (the float
        tier alone would miss low-mantissa flips).

    ``inject`` corrupts the kernel output (the campaign's activations site);
    recovery recomputes flagged rows from the plain ``be.attn`` path, which
    is bit-identical to the checked kernel's output (enforced by
    tests/test_flashattn.py), so ABFT correction is bit-exact.
    Returns (out, stats).
    """
    if stats is None:
        stats = DependabilityStats.zero()
    be = backend_mod.resolve(backend)
    if be.attn is None or be.attn_checksum is None:
        raise ValueError(f"backend {be.name!r} does not register attention")

    def plain(inj):
        out = be.attn(q, k, v, causal=causal, window=window)
        if inj is not None:
            out = inj(out)
        return out

    def row_ok_mask(out, check, csum):
        bit_ok = abft_mod.output_row_checksums(out) == csum
        flt_ok = jnp.abs(jnp.sum(out.astype(jnp.float32), axis=-1) - check) \
            <= tol * (jnp.abs(check) + 1.0)
        return bit_ok & flt_ok

    # NOTE on recovery: integer ABFT recomputes under ``lax.cond`` because
    # exact math is bit-stable across compilation contexts.  Float attention
    # is not — a cond branch compiles as its own fused XLA program whose
    # low-order bits can differ from the in-context result — so both float
    # policies recompute *unconditionally in the same execution context* and
    # select.  Eagerly the recompute dispatches the same ops (bit-identical);
    # under jit/vmap both calls live in one program and CSE collapses them,
    # so recovery is bit-exact and the recompute is free on the clean path.

    if policy == Policy.ABFT:
        out, check, csum = be.attn_checksum(q, k, v, causal=causal,
                                            window=window)
        if inject is not None:
            out = inject(out)
        row_ok = row_ok_mask(out, check, csum)
        faults = jnp.sum(~row_ok).astype(jnp.int32)
        fresh = be.attn(q, k, v, causal=causal, window=window)
        out = jnp.where(row_ok[..., None], out, fresh)
        ok = jnp.all(row_ok_mask(out, check, csum))
        corrected = faults * ok.astype(jnp.int32)
        return out, _bump(stats, faults, corrected)

    if policy == Policy.CKPT:
        # detect via the fused two-tier check, recover by re-executing the
        # whole op from the operands instead of selective rows
        out, check, csum = be.attn_checksum(q, k, v, causal=causal,
                                            window=window)
        if inject is not None:
            out = inject(out)
        detected = jnp.any(~row_ok_mask(out, check, csum))
        fresh = be.attn(q, k, v, causal=causal, window=window)
        out = jnp.where(detected, fresh, out)
        recovered = detected & jnp.all(row_ok_mask(out, check, csum))
        return out, _bump(stats, detected, False, recovered)

    if policy == Policy.DMR:
        out = plain(inject)
        detected = ~redundancy.agree([out, plain(None)])
        return out, _bump(stats, detected, False)

    if policy == Policy.TMR:
        r0, r1 = plain(inject), plain(None)
        disagreed = ~redundancy.agree([r0, r1])
        out = redundancy.vote([r0, r1, plain(None)])
        return out, _bump(stats, disagreed, disagreed)

    return plain(inject), stats


def dependable_qconv2d(
    policy: Policy,
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    scale: jax.Array, out_zp: jax.Array,
    *, stride=(1, 1), padding="SAME",
    inject=None, stats: Optional[dict] = None, w_check=None,
    ckpt=None, backend: backend_mod.BackendLike = None,
):
    """Quantized NHWC conv + requant under a dependability policy — the conv
    twin of ``dependable_qmatmul`` so every campaign injection site drives
    matmul and conv through one uniform hook surface.

    Returns (y_q int8, stats dict).
    """
    if stats is None:
        stats = DependabilityStats.zero()
    be = backend_mod.resolve(backend)

    def finish(acc):
        return requantize(acc + bias[None, None, None, :], scale, out_zp)

    if policy == Policy.ABFT:
        res = abft_mod.abft_qconv2d(x_q, x_zp, w_q, bias, stride=stride,
                                    padding=padding, inject=inject,
                                    w_check=w_check, backend=be)
        y = requantize(res.acc, scale, out_zp)
        corrected = res.faults_detected * res.ok.astype(jnp.int32)
        return y, _bump(stats, res.faults_detected, corrected)

    if policy == Policy.CKPT:
        ck_x, ck_w = (x_q, w_q) if ckpt is None else ckpt
        wc = w_check if w_check is not None \
            else abft_mod.conv_checksum_weight(ck_w)
        acc_dot, want = be.conv_acc_checksum(x_q, x_zp, w_q, wc, stride,
                                             padding)
        if inject is not None:
            acc_dot = inject(acc_dot)
        detected = jnp.any(jnp.sum(acc_dot, axis=3) != want)

        def rollback(_):
            return be.conv_acc(ck_x, x_zp, ck_w, stride, padding)

        acc_dot = jax.lax.cond(detected, rollback, lambda a: a, acc_dot)
        recovered = detected & jnp.all(jnp.sum(acc_dot, axis=3) == want)
        y = finish(acc_dot)
        return y, _bump(stats, detected, False, recovered)

    def run(inj):
        acc = be.conv_acc(x_q, x_zp, w_q, stride, padding)
        if inj is not None:
            acc = inj(acc)
        return finish(acc)

    if policy == Policy.DMR:
        y = run(inject)
        detected = ~redundancy.agree([y, run(None)])
        return y, _bump(stats, detected, False)

    if policy == Policy.TMR:
        r0, r1 = run(inject), run(None)
        disagreed = ~redundancy.agree([r0, r1])
        y = redundancy.vote([r0, r1, run(None)])
        return y, _bump(stats, disagreed, disagreed)

    return run(inject), stats
