"""Dependability policy layer — composes ABFT / NMR / retry around the
quantized compute primitives.

This is the framework-level rendition of the paper's thesis: *dependable AI
execution is a property of the execution system, not of the model*.  Models
ask for a ``qlinear``; the policy decides how it is executed:

  NONE  — plain fused kernel (maximum throughput; rad-hard hardware assumed,
          as on the HPDP itself).
  ABFT  — exact integer checksum verify + recompute-recover (default for
          fleet deployment; ~1/N FLOP overhead).
  TMR   — triple execution + bitwise majority vote (3× cost; for the few
          layers whose corruption is mission-fatal, e.g. the final
          classification head of the ship detector).

Policies are data (config enums), so a deployment can mix them per layer —
matching how the paper reserves the rad-hard HPDP for the convolution hot
path while the RTG4 handles orchestration.
"""
from __future__ import annotations

import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import abft as abft_mod
from repro.core import redundancy
from repro.core.quant import requantize


class Policy(str, enum.Enum):
    NONE = "none"
    ABFT = "abft"
    TMR = "tmr"


class DependabilityStats:
    """Host-side counters exported by dependable ops (pytree of scalars)."""

    @staticmethod
    def zero():
        return {"faults_detected": jnp.zeros((), jnp.int32),
                "checks_run": jnp.zeros((), jnp.int32)}


def dependable_qmatmul(
    policy: Policy,
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    scale: jax.Array, out_zp: jax.Array,
    *, inject=None, stats: Optional[dict] = None,
):
    """Quantized matmul + requant executed under a dependability policy.

    Returns (y_q int8, stats dict).
    """
    if stats is None:
        stats = DependabilityStats.zero()

    if policy == Policy.ABFT:
        res = abft_mod.abft_qmatmul(x_q, x_zp, w_q, bias, inject=inject)
        y = requantize(res.acc, scale, out_zp)
        stats = {
            "faults_detected": stats["faults_detected"] + res.faults_detected,
            "checks_run": stats["checks_run"] + 1,
        }
        return y, stats

    if policy == Policy.TMR:
        def run():
            acc = jax.lax.dot_general(
                x_q, w_q, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
            acc = acc - x_zp.astype(jnp.int32) * colsum[None, :] + bias[None, :]
            return requantize(acc, scale, out_zp)

        injectors = (inject, None, None) if inject is not None else (None, None, None)
        y = redundancy.tmr_apply(lambda: run(), injectors=injectors)
        stats = {**stats, "checks_run": stats["checks_run"] + 1}
        return y, stats

    # Policy.NONE — plain path
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    acc = acc - x_zp.astype(jnp.int32) * colsum[None, :] + bias[None, :]
    if inject is not None:
        acc = inject(acc)
    return requantize(acc, scale, out_zp), stats
