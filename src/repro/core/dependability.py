"""Dependability policy layer — composes ABFT / NMR / retry around the
quantized compute primitives.

This is the framework-level rendition of the paper's thesis: *dependable AI
execution is a property of the execution system, not of the model*.  Models
ask for a ``qlinear``; the policy decides how it is executed:

  NONE  — plain fused kernel (maximum throughput; rad-hard hardware assumed,
          as on the HPDP itself).
  ABFT  — exact integer checksum verify + recompute-recover (default for
          fleet deployment; ~1/N FLOP overhead).
  DMR   — dual execution + bitwise compare (2× cost, detect-only): raises
          the alarm but returns replica 0's output unchanged.  The cheap
          partner of a failover layer — the fleet supervisor quarantines the
          flagged replica and replays the work elsewhere.
  TMR   — triple execution + bitwise majority vote (3× cost; for the few
          layers whose corruption is mission-fatal, e.g. the final
          classification head of the ship detector).

Policies are data (config enums), so a deployment can mix them per layer —
matching how the paper reserves the rad-hard HPDP for the convolution hot
path while the RTG4 handles orchestration.
"""
from __future__ import annotations

import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import abft as abft_mod
from repro.core import redundancy
from repro.core.quant import requantize


class Policy(str, enum.Enum):
    NONE = "none"
    ABFT = "abft"
    DMR = "dmr"
    TMR = "tmr"


class DependabilityStats:
    """Host-side counters exported by dependable ops (pytree of scalars)."""

    @staticmethod
    def zero():
        return {"faults_detected": jnp.zeros((), jnp.int32),
                "checks_run": jnp.zeros((), jnp.int32)}

    @staticmethod
    def merge(a: dict, b: dict) -> dict:
        """Elementwise sum of two stats pytrees (campaign / engine rollups)."""
        return {k: a[k] + b[k] for k in a}

    @staticmethod
    def to_host(stats: dict) -> dict:
        """Device scalars → plain ints, for JSON reports and log lines."""
        return {k: int(v) for k, v in stats.items()}


def dependable_qmatmul(
    policy: Policy,
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    scale: jax.Array, out_zp: jax.Array,
    *, inject=None, stats: Optional[dict] = None, w_check=None,
):
    """Quantized matmul + requant executed under a dependability policy.

    ``inject`` corrupts the int32 accumulator (the campaign engine's
    accumulator injection site); ``w_check`` is the optional deploy-time
    checksum vector (see ``abft.abft_qmatmul``).  Returns (y_q int8, stats).
    """
    if stats is None:
        stats = DependabilityStats.zero()

    if policy == Policy.ABFT:
        res = abft_mod.abft_qmatmul(x_q, x_zp, w_q, bias, inject=inject,
                                    w_check=w_check)
        y = requantize(res.acc, scale, out_zp)
        stats = {
            "faults_detected": stats["faults_detected"] + res.faults_detected,
            "checks_run": stats["checks_run"] + 1,
        }
        return y, stats

    if policy in (Policy.TMR, Policy.DMR):
        # inject corrupts replica 0's accumulator — the same site as the
        # ABFT/NONE paths, so policy sweeps compare like for like
        def run(inj):
            acc = jax.lax.dot_general(
                x_q, w_q, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            if inj is not None:
                acc = inj(acc)
            colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
            acc = acc - x_zp.astype(jnp.int32) * colsum[None, :] + bias[None, :]
            return requantize(acc, scale, out_zp)

        if policy == Policy.DMR:
            # detect-only: replica 0 (possibly faulted) is returned as-is;
            # disagreement with the clean re-execution raises the alarm
            y = run(inject)
            detected = ~redundancy.agree([y, run(None)])
            stats = {
                "faults_detected": stats["faults_detected"]
                + detected.astype(jnp.int32),
                "checks_run": stats["checks_run"] + 1,
            }
            return y, stats

        y = redundancy.vote([run(inject), run(None), run(None)])
        stats = {**stats, "checks_run": stats["checks_run"] + 1}
        return y, stats

    # Policy.NONE — plain path
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    if inject is not None:
        acc = inject(acc)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    acc = acc - x_zp.astype(jnp.int32) * colsum[None, :] + bias[None, :]
    return requantize(acc, scale, out_zp), stats


def dependable_qconv2d(
    policy: Policy,
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    scale: jax.Array, out_zp: jax.Array,
    *, stride=(1, 1), padding="SAME",
    inject=None, stats: Optional[dict] = None, w_check=None,
):
    """Quantized NHWC conv + requant under a dependability policy — the conv
    twin of ``dependable_qmatmul`` so every campaign injection site drives
    matmul and conv through one uniform hook surface.

    Returns (y_q int8, stats dict).
    """
    if stats is None:
        stats = DependabilityStats.zero()

    def plain_acc():
        x = x_q.astype(jnp.int32) - x_zp.astype(jnp.int32)
        return jax.lax.conv_general_dilated(
            x, w_q.astype(jnp.int32), stride, padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32)

    if policy == Policy.ABFT:
        res = abft_mod.abft_qconv2d(x_q, x_zp, w_q, bias, stride=stride,
                                    padding=padding, inject=inject,
                                    w_check=w_check)
        y = requantize(res.acc, scale, out_zp)
        stats = {
            "faults_detected": stats["faults_detected"] + res.faults_detected,
            "checks_run": stats["checks_run"] + 1,
        }
        return y, stats

    if policy in (Policy.TMR, Policy.DMR):
        def run(inj):
            acc = plain_acc()
            if inj is not None:
                acc = inj(acc)
            return requantize(acc + bias[None, None, None, :], scale, out_zp)

        if policy == Policy.DMR:
            y = run(inject)
            detected = ~redundancy.agree([y, run(None)])
            stats = {
                "faults_detected": stats["faults_detected"]
                + detected.astype(jnp.int32),
                "checks_run": stats["checks_run"] + 1,
            }
            return y, stats

        y = redundancy.vote([run(inject), run(None), run(None)])
        stats = {**stats, "checks_run": stats["checks_run"] + 1}
        return y, stats

    acc = plain_acc()
    if inject is not None:
        acc = inject(acc)
    return requantize(acc + bias[None, None, None, :], scale, out_zp), stats
