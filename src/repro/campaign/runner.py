"""Campaign trial execution: workload cases, policy wrapping, classification.

Each workload case exposes one method —

    run_trials(policy, site, fault, keys) -> (detected[n], mismatch[n])

where ``fault(x, key) -> x'`` is a fault-model primitive.  The *golden*
reference for a configuration is the same code path run with an identity
fault, so classification measures exactly the injected fault's effect, never
incidental numeric differences between execution paths.

Injection-site semantics per policy:

  accumulator   fault the int32 matmul/conv accumulator via the ``inject=``
                hook (compute-path SEU — what ABFT's checksum covers)
  weights       fault the stored quantized weights before execution
                (memory SEU — ABFT detects it only with a deploy-time
                checksum; recompute-recovery cannot fix it, CKPT's
                golden-checkpoint rollback can)
  activations   fault the layer input (upstream data SEU — outside any
                single layer's ABFT contract; TMR still corrects it when
                only one replica's copy is hit)
  kv_cache      fault the live KV cache / recurrent state of a serving
                engine mid-decode (transient state SEU — covered by the
                decode-state scrub, runtime/serving.py, docs/recovery.md)
  decode_state  fault the engine's sampled-token buffer mid-decode (the
                other transient decode-state tensor; same scrub)

CKPT (checkpoint/restart) classifies through the same machinery: detection
comes from the op/engine's own checksum verdicts, recovery is rollback —
re-execution from golden state — and every recovered trial lands
``detected_corrected`` with its measured recovery latency rolled into the
report's recovery columns.

TMR is evaluated at the campaign level with explicit replica voting
(``redundancy.vote``/``agree``): replica 0 executes with the fault, replicas
1–2 clean, matching spatial TMR where a single event upsets one replica.
DMR is its detect-only half: replica 0 (faulted) vs one clean replica,
disagreement raises the alarm but replica 0's output ships unchanged —
manifested faults classify ``detected_uncorrected`` (covered, because a
failover layer takes over; the ``fleet`` workload closes that loop).

Kernel-shaped cases (qmatmul, qconv2d) are pure JAX all the way through, so
trials are vmapped and jitted in one batch; model/serving cases inject on
the host (pytree surgery) and loop over jitted forwards.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign import engine as engine_mod
from repro.campaign import faultload as fl
from repro.campaign import stats as stats_mod
from repro.campaign.report import BitCoverageRow, ConfigResult, classify_counts
from repro.core import abft as abft_mod
from repro.core import fault_injection as fi
from repro.core import redundancy
from repro.core.dependability import (
    Policy, dependable_attention, dependable_qconv2d, dependable_qmatmul)
from repro.core.fault_injection import _as_bits
from repro.obs import EventLog

_IDENTITY = lambda x, key: x


def _timeline_columns(ev_log: EventLog) -> Tuple[dict, List[dict]]:
    """Reduce an event log to the report's timeline columns (and the raw
    reconstructed chains, for ``--events-out``).  Drains the log."""
    tls = ev_log.timelines()
    ev_log.clear()
    det = [t["detection_latency_ticks"] for t in tls if t["detected"]]
    rec = [t["recovery_latency_ticks"] for t in tls if t["recovered"]]
    cols = {
        "strikes_logged": len(tls),
        "detections_logged": len(det),
        "detection_ticks_mean": float(np.mean(det)) if det else 0.0,
        "detection_ticks_max": int(max(det)) if det else 0,
        "recovery_ticks_mean": float(np.mean(rec)) if rec else 0.0,
        "recovery_ticks_max": int(max(rec)) if rec else 0,
    }
    return cols, tls


def _bitwise_mismatch(a, b) -> jax.Array:
    """() bool — any leaf of pytree ``a`` differs bit-for-bit from ``b``
    (bit-pattern compare: NaN-safe, dtype-uniform)."""
    out = jnp.asarray(False)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        ab, _ = _as_bits(la)
        bb, _ = _as_bits(lb)
        out = out | jnp.any(ab != bb)
    return out


def _tmr_vote(faulty, clean) -> Tuple[jax.Array, jax.Array]:
    """(voted_output, detected) for replicas [faulty, clean, clean]."""
    detected = ~redundancy.agree([faulty, clean])
    voted = redundancy.vote([faulty, clean, clean])
    return voted, detected


def _dmr_check(faulty, clean) -> Tuple[jax.Array, jax.Array]:
    """(replica-0 output, detected) for replicas [faulty, clean] — DMR is
    detect-only, so the faulted replica's output ships unchanged."""
    return faulty, ~redundancy.agree([faulty, clean])


class _RecoveryLog:
    """Host-side recovery accounting shared by the engine/fleet cases:
    accumulates rollback counts + wall-clock latencies during run_trials,
    drained into the report's recovery columns by the campaign runner."""

    def __init__(self):
        self.count = 0
        self.seconds: List[float] = []

    def drain_raw(self) -> Tuple[int, List[float]]:
        """(count, wall seconds) since the last drain — the chunk-shippable
        form the adaptive engine merges across workers."""
        count, secs = self.count, self.seconds
        self.count, self.seconds = 0, []
        return count, secs

    def drain(self) -> dict:
        count, secs = self.drain_raw()
        return {"faults_recovered": count,
                "recovery_ms_mean": float(np.mean(secs) * 1e3) if secs else 0.0,
                "recovery_ms_max": float(np.max(secs) * 1e3) if secs else 0.0}


# ---------------------------------------------------------------------------
# Kernel-shaped cases: fully vmappable
# ---------------------------------------------------------------------------


class _KernelCase:
    """Shared trial machinery for the pure-JAX op cases: subclasses build the
    quantized operands in __init__ and implement ``_op`` (the dependable op
    call); site dispatch, TMR voting, and the vmapped trial loop live here.

    ``backend`` selects the execution engine (core/backend.py) every trial
    runs on — the axis that lets one campaign certify the jnp path and the
    Pallas kernel path side by side."""

    sites = ("accumulator", "weights", "activations")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR, Policy.CKPT)

    backend = "jnp"
    # pure-JAX cases scale by widening the vmapped trial batch, not by
    # fanning chunks across processes (SamplingPlan.kernel_chunk)
    shardable = False

    def _op(self, policy: Policy, x_q, w_q, inject, w_check):
        raise NotImplementedError

    def _one(self, policy: Policy, site: str, fault, key):
        x_q, w_q, inject = self.x_q, self.w_q, None
        if site == "weights":
            w_q = fault(w_q, key)
        elif site == "activations":
            x_q = fault(x_q, key)
        else:
            inject = lambda acc: fault(acc, key)

        if policy in (Policy.TMR, Policy.DMR) and site != "accumulator":
            # spatial redundancy: the SEU hit one replica's *operand copy*,
            # so the clean replicas and the vote live at the campaign level
            y, _ = self._op(Policy.NONE, x_q, w_q, inject, None)
            y_clean, _ = self._op(Policy.NONE, self.x_q, self.w_q, None, None)
            check = _tmr_vote if policy == Policy.TMR else _dmr_check
            return check(y, y_clean)

        # accumulator faults (and every NONE/ABFT/CKPT trial) drive the
        # dependable op itself — its stats are the detection verdict, so TMR
        # correction counts, ABFT checksum hits, and CKPT rollbacks surface
        # exactly as deployed code would report them
        y, st = self._op(policy, x_q, w_q, inject,
                         self.w_check if policy in (Policy.ABFT, Policy.CKPT)
                         else None)
        if policy == Policy.NONE:
            return y, jnp.asarray(False)
        return y, st["faults_detected"] > 0

    def run_trials(self, policy, site, fault, keys):
        # golden is computed INSIDE the jitted trial program (not hoisted
        # eagerly): for the float case XLA fusion perturbs low-order output
        # bits between compilation contexts, so a bit-exact mismatch verdict
        # needs both streams from one program (integer cases are bit-stable
        # either way, and CSE makes the in-program golden free)
        def trial(key):
            golden, _ = self._one(policy, site, _IDENTITY, key)
            y, detected = self._one(policy, site, fault, key)
            return detected, _bitwise_mismatch(y, golden)

        detected, mismatch = jax.jit(jax.vmap(trial))(keys)
        return np.asarray(detected), np.asarray(mismatch)


class QMatmulCase(_KernelCase):
    """int8×int8→int32 matmul + requant (the paper's hot-path primitive)."""

    name = "qmatmul"

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 m: int = 32, k: int = 64, n: int = 48):
        self.backend = backend
        kx, kw, kb = jax.random.split(key, 3)
        self.x_q = jax.random.randint(kx, (m, k), -128, 128).astype(jnp.int8)
        self.w_q = jax.random.randint(kw, (k, n), -127, 128).astype(jnp.int8)
        self.bias = jax.random.randint(kb, (n,), -500, 500).astype(jnp.int32)
        self.x_zp = jnp.int32(3)
        self.out_zp = jnp.int32(0)
        self.scale = jnp.full((n,), 1e-3, jnp.float32)
        # deploy-time checksum from the known-good weights (weight-SEU cover)
        self.w_check = abft_mod.checksum_vector(self.w_q)

    def _op(self, policy, x_q, w_q, inject, w_check):
        # the case's pristine operands ARE the golden checkpoint CKPT rolls
        # back to — healing weight-site SEUs the other in-op policies can
        # only detect
        ckpt = (self.x_q, self.w_q) if policy == Policy.CKPT else None
        return dependable_qmatmul(
            policy, x_q, self.x_zp, w_q, self.bias, self.scale, self.out_zp,
            inject=inject, w_check=w_check, ckpt=ckpt, backend=self.backend)


class QConv2dCase(_KernelCase):
    """int8 NHWC conv + requant (the HPDP's Table-1 op, reduced geometry)."""

    name = "qconv2d"

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 h: int = 12, w: int = 12, cin: int = 8, cout: int = 8,
                 kh: int = 3, kw: int = 3):
        self.backend = backend
        kx, kkw, kb = jax.random.split(key, 3)
        self.x_q = jax.random.randint(kx, (1, h, w, cin), -128, 128).astype(jnp.int8)
        self.w_q = jax.random.randint(kkw, (kh, kw, cin, cout), -127, 128).astype(jnp.int8)
        self.bias = jax.random.randint(kb, (cout,), -100, 100).astype(jnp.int32)
        self.x_zp = jnp.int32(2)
        self.out_zp = jnp.int32(0)
        self.scale = jnp.full((cout,), 1e-3, jnp.float32)
        self.w_check = abft_mod.conv_checksum_weight(self.w_q)

    def _op(self, policy, x_q, w_q, inject, w_check):
        ckpt = (self.x_q, self.w_q) if policy == Policy.CKPT else None
        return dependable_qconv2d(
            policy, x_q, self.x_zp, w_q, self.bias, self.scale, self.out_zp,
            inject=inject, w_check=w_check, ckpt=ckpt, backend=self.backend)


class FlashAttnCase(_KernelCase):
    """Float flash attention under the two-tier ABFT check — the one hot
    kernel the integer-checksum story cannot absorb (kernels/flashattn,
    ``dependable_attention``).

    Site mapping onto the kernel-case hooks: ``x_q`` is the query tensor
    (the ``activations`` site strikes an operand, covered at campaign level
    by the DMR/TMR replicas like every operand SEU); the ``accumulator``
    site strikes the kernel's *emitted output* — the float analog of the
    int32 accumulator hook — where the fused exact bit checksum certifies
    detection of every flip, including the low-mantissa ones a tolerance
    check must wave through."""

    name = "flashattn"
    sites = ("accumulator", "activations")

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 b: int = 1, h: int = 2, s: int = 24, hd: int = 16):
        self.backend = backend
        kq, kk, kv = jax.random.split(key, 3)
        self.x_q = jax.random.normal(kq, (b, h, s, hd), jnp.float32)
        self.k = jax.random.normal(kk, (b, h, s, hd), jnp.float32)
        self.v = jax.random.normal(kv, (b, h, s, hd), jnp.float32)
        self.w_q = None          # attention has no weight operand
        self.w_check = None

    def _op(self, policy, x_q, w_q, inject, w_check):
        return dependable_attention(policy, x_q, self.k, self.v,
                                    inject=inject, backend=self.backend)


# ---------------------------------------------------------------------------
# Model cases: host-side pytree injection + jitted forwards
# ---------------------------------------------------------------------------


class ShipdetCase:
    """The paper's ship-detection CNN (reduced geometry), full-network
    forward under a per-layer dependability policy.

    Deploy-time weight integrity (``shipdet.deploy_checks``) makes the
    ``weights`` site a *covered* site at model level: ABFT layers verify the
    live weights against the shipped checksums (detect), CKPT layers roll
    back to the shipped golden weights and re-execute (heal) — the same
    contract the serving fleet's storage scrub provides, pushed into the op.
    """

    name = "shipdet"
    sites = ("accumulator", "weights", "activations")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR, Policy.CKPT)
    shardable = True          # host-side trial loop: chunks fan across a pool

    def __init__(self, key: jax.Array, backend: str = "jnp"):
        from repro.models import shipdet
        self._shipdet = shipdet
        self.backend = backend
        kp, kx = jax.random.split(key)
        self.specs = shipdet.reduced_specs()
        self.params = shipdet.init_params(self.specs, kp)
        s0 = self.specs[0]
        self.x = jax.random.uniform(kx, (1, s0.h, s0.w, 3))
        # deploy-time golden state: checksums for ABFT scrubs, weights for
        # CKPT rollback (computed once, from the known-good parameters)
        self.w_checks = shipdet.deploy_checks(self.params)
        self.golden_wq = shipdet.golden_weights(self.params)

    def _wq_pytree(self, params) -> List[jax.Array]:
        return [p["qconv"].w_q for p in params]

    def _with_wq(self, wq_leaves) -> list:
        return [{**p, "qconv": p["qconv"]._replace(w_q=wq)}
                for p, wq in zip(self.params, wq_leaves)]

    def run_trials(self, policy, site, fault, keys):
        sd = self._shipdet
        base = Policy.NONE if policy in (Policy.TMR, Policy.DMR) else policy
        deploy = base in (Policy.ABFT, Policy.CKPT)

        def fwd(params, x, inject=None):
            out, st = sd.forward(
                self.specs, params, x, policy=base,
                inject=inject, backend=self.backend,
                w_checks=self.w_checks if deploy else None,
                golden_wq=self.golden_wq if base == Policy.CKPT else None)
            return out, st["faults_detected"] > 0

        detected_l, mismatch_l = [], []
        if site == "weights":
            run = jax.jit(lambda p, x: fwd(p, x))
            golden, _ = run(self.params, self.x)
            clean = golden
            for k in keys:
                wq = fl.inject_pytree_with(self._wq_pytree(self.params), k, fault)
                out, det = run(self._with_wq(wq), self.x)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, clean)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, clean)
                detected_l.append(bool(det) if policy != Policy.NONE else False)
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        else:
            if site == "activations":
                def one(key):
                    x = fault(self.x, key)
                    return fwd(self.params, x)

                golden, _ = jax.jit(lambda: fwd(self.params, self.x))()
            else:   # accumulator — mid-layer int32 accumulator hook
                def one(key):
                    return fwd(self.params, self.x,
                               inject=lambda acc: fault(acc, key))

                golden, _ = jax.jit(
                    lambda: fwd(self.params, self.x, inject=lambda a: a))()

            one_j = jax.jit(one)
            clean = golden
            for k in keys:
                out, det = one_j(k)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, clean)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, clean)
                detected_l.append(bool(det) if policy != Policy.NONE else False)
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        return np.asarray(detected_l), np.asarray(mismatch_l)


class TransformerCase:
    """Small transformer LM forward from the config registry (float path —
    no integer checksum exists, so the supported policies are NONE/TMR)."""

    name = "transformer"
    sites = ("weights", "activations")
    policies = (Policy.NONE, Policy.DMR, Policy.TMR)
    shardable = True

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m"):
        from repro.configs import registry
        from repro.models import api as model_api
        from repro.models.config import reduced
        self._api = model_api
        kp, kt = jax.random.split(key)
        self.cfg = model_api.with_backend(reduced(registry.get(arch)), backend)
        self.params = model_api.init_params(self.cfg, kp)
        self.tokens = jax.random.randint(kt, (2, 16), 0, self.cfg.vocab_size)

    def run_trials(self, policy, site, fault, keys):
        api = self._api

        def logits_from_params(params):
            return api.forward(self.cfg, params, self.tokens).logits

        def logits_from_embeds(embeds):
            return api.forward(self.cfg, self.params, self.tokens,
                               embeds=embeds).logits

        detected_l, mismatch_l = [], []
        if site == "weights":
            run = jax.jit(logits_from_params)
            golden = run(self.params)
            for k in keys:
                out = run(fl.inject_pytree_with(self.params, k, fault))
                det = jnp.asarray(False)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, golden)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, golden)
                detected_l.append(bool(det))
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        else:   # activations — fault the token embeddings feeding the stack
            embeds = self.params["embed"][self.tokens]

            def one(key):
                return logits_from_embeds(fault(embeds, key))

            one_j = jax.jit(one)
            golden = jax.jit(lambda: logits_from_embeds(embeds))()
            for k in keys:
                out = one_j(k)
                det = jnp.asarray(False)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, golden)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, golden)
                detected_l.append(bool(det))
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        return np.asarray(detected_l), np.asarray(mismatch_l)


class ServingCase:
    """End-to-end serving drill: SEUs strike a live continuous-batching
    engine — its weight memory (``weights``) or its transient decode state
    (``kv_cache`` / ``decode_state``) — and classification compares full
    generated token streams.  Detected faults are rolled into the engine's
    DependabilityStats so the serving layer reports campaign results like
    any other counter.

    Policy rendition at engine level:

      NONE      undefended baseline (nonzero SDC is the point)
      ABFT      detect-only scrubbing: weight sites are checked against
                deploy-time storage checksums after the run, transient
                sites by the engine's decode-state scrub in ``detect``
                mode — alarms are raised but the corrupted stream ships
                (``detected_uncorrected``; a fleet closes the loop)
      CKPT      checkpoint/restart: the same detection, plus recovery —
                transient faults roll the engine back to its verified
                snapshot mid-run, weight faults restore the golden
                parameters and re-execute — measured recovery latency,
                stream bit-identical to golden (``detected_corrected``)
      DMR/TMR   temporal redundancy judged on the replayed stream
                (weights site, as before)
    """

    name = "serving"
    sites = ("weights", "kv_cache", "decode_state")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR, Policy.CKPT)
    quant_kv = False    # subclass hook: run on the int8-quantized KV cache
    shardable = True          # host-side trial loop: chunks fan across a pool
    event_logged = True       # emits real EventLog chains (no synthesis)
    recovery_logged = True    # host recovery accounting in _RecoveryLog

    # the tick (engine step) after which mid-run state strikes land; >0 so
    # prefill and at least one decode step have populated real state
    STRIKE_STEP = 2

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m"):
        from repro.configs import registry
        from repro.core import abft as abft_api
        from repro.models import api as model_api
        from repro.models.config import reduced
        from repro.runtime.serving import Engine, Request
        self._Request = Request
        self._abft = abft_api
        self.cfg = reduced(registry.get(arch))
        if self.quant_kv:
            self.cfg = dataclasses.replace(self.cfg, quant_kv=True)
        # subclass hook (e.g. the DSE's policy-mapped case): adjust the
        # config before params/engine are built — quant toggles, baked-in
        # policy maps — without re-plumbing the constructor
        self.cfg = self._customize_cfg(self.cfg)
        self.params = model_api.init_params(self.cfg, key)
        # structured dependability events on the engine's tick clock: engine
        # strikes/scrubs/rollbacks emit into it directly; weight-site
        # injections (host pytree surgery) are stamped by run_trials
        self.events = EventLog()
        self.engine = Engine(self.cfg, self.params, capacity=2, max_len=64,
                             prefill_pad=8, snapshot_every=2, backend=backend,
                             event_log=self.events)
        # deploy-time storage checksums: the scrub baseline for weight sites
        self.storage_checks = jax.jit(abft_api.storage_checksums)(self.params)
        self._verify_storage = jax.jit(abft_api.verify_storage)
        self.prompts = [[5, 9, 2], [3, 1, 4, 1]]
        self._recovery = _RecoveryLog()

    def _customize_cfg(self, cfg):
        return cfg

    @staticmethod
    def supports(policy: Policy, site: str) -> bool:
        # DMR/TMR here are stream-replay drills over persistent faults; the
        # transient sites belong to the scrubbing policies (ABFT detects,
        # CKPT recovers) and the NONE baseline
        if policy in (Policy.DMR, Policy.TMR):
            return site == "weights"
        return True

    def _run_engine(self, params, scrub_mode: str = "off",
                    state_site: str = None, fault=None, key=None,
                    ) -> Tuple[Tuple[int, ...], ...]:
        eng = self.engine
        eng.state_scrub = scrub_mode
        eng.reset(params=params)
        reqs = [self._Request(uid=i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(self.prompts)]
        for r in reqs:
            eng.submit(r)
        steps = 0
        while (eng.queue or eng.active) and steps < 1000:
            eng.step()
            steps += 1
            if steps == self.STRIKE_STEP and state_site is not None:
                # per-stage injection: the decode stage owns both transient
                # sites (runtime/dataflow.py, StreamingExecutor.strike)
                eng.strike(state_site, fault, key)
        return tuple(tuple(r.output) for r in reqs)

    def _weight_scrub_failed(self) -> bool:
        ok = self._verify_storage(self.engine.params, self.storage_checks)
        return not all(bool(x) for x in jax.tree_util.tree_leaves(ok))

    def run_trials(self, policy, site, fault, keys):
        import time as _time
        scrub_mode = {Policy.ABFT: "detect", Policy.CKPT: "rollback"}.get(
            policy, "off")
        state_site = site if site in ("kv_cache", "decode_state") else None
        self.events.ctx.update(policy=policy.value)

        def serve(params, key):
            return self._run_engine(params, scrub_mode=scrub_mode,
                                    state_site=state_site,
                                    fault=fault, key=key)

        golden = self._run_engine(self.params)
        self.events.clear()               # golden pass leaves no timelines
        detected_l, mismatch_l = [], []
        for k in keys:
            params = self.params if state_site is not None \
                else fl.inject_pytree_with(self.params, k, fault)
            if state_site is None:
                # weight-site injection happens here (pytree surgery), not
                # through Engine.strike — stamp the injection event so the
                # chain has its strike anchor
                self.events.emit(
                    "strike", tick=self.engine.tick, site=site,
                    fault=getattr(fault, "__name__", ""))
            out = serve(params, k)
            events = self.engine.drain_state_events()
            detected = len(events) > 0
            self._recovery.count += sum(1 for e in events if e["recovered"])
            self._recovery.seconds += [e["seconds"] for e in events
                                       if e["recovered"]]
            if site == "weights" and policy in (Policy.ABFT, Policy.CKPT):
                # post-run storage scrub against deploy-time checksums
                bad = self._weight_scrub_failed()
                self.engine.record_dependability({
                    "faults_detected": jnp.int32(1 if bad else 0),
                    "checks_run": jnp.int32(1)})
                detected = detected or bad
                if bad and policy == Policy.CKPT:
                    # rollback-and-reexecute from the golden checkpoint
                    t0 = _time.perf_counter()
                    out = self._run_engine(self.params)
                    seconds = _time.perf_counter() - t0
                    self._recovery.seconds.append(seconds)
                    self._recovery.count += 1
                    self.engine.record_dependability({
                        "faults_recovered": jnp.int32(1)})
                    self.events.emit(
                        "recovery", tick=self.engine.tick, site="weights",
                        seconds=seconds,
                        detail={"action": "golden_reexecute"})
            differs = out != golden
            if policy == Policy.TMR:
                # temporal TMR: clean replicas replay deterministically, so a
                # per-token majority of (faulty, clean, clean) is the clean
                # stream; disagreement is the detection signal.
                detected_l.append(differs)
                mismatch_l.append(False)
                if differs:
                    self.engine.record_dependability({
                        "faults_detected": jnp.int32(1),
                        "checks_run": jnp.int32(1)})
            elif policy == Policy.DMR:
                # detect-only: the pair disagrees but the faulted stream is
                # what shipped — detected_uncorrected until a failover layer
                # (the fleet workload) replays it
                detected_l.append(differs)
                mismatch_l.append(differs)
                if differs:
                    self.engine.record_dependability({
                        "faults_detected": jnp.int32(1),
                        "checks_run": jnp.int32(1)})
            elif policy == Policy.NONE:
                detected_l.append(False)
                mismatch_l.append(differs)
            else:                                   # ABFT / CKPT
                detected_l.append(bool(detected))
                mismatch_l.append(differs)
        return np.asarray(detected_l), np.asarray(mismatch_l)

    def drain_recovery_stats(self) -> dict:
        return self._recovery.drain()


class ServingInt8KVCase(ServingCase):
    """ServingCase with the int8-quantized KV cache (``ArchConfig.quant_kv``)
    — the raw-speed decode configuration.  The ``kv_cache`` site now strikes
    a *mixed pytree* (int8 rows plus float32 per-row scales), the worst case
    for detection: a scale-tensor SEU perturbs every value dequantized from
    its row.  The engine's decode-state scrub is dtype-uniform (exact
    mod-2^32 bit checksums), so ABFT detects and CKPT snapshot-rollback
    heals these strikes exactly as it does for the f32 cache — the campaign
    rows certify that quantizing the cache does not narrow the dependability
    envelope."""

    name = "serving_int8kv"
    quant_kv = True


class FleetCase:
    """Fleet-level end-to-end drill: an SEU strikes ONE replica of a live
    multi-replica serving fleet (src/repro/fleet/) and the campaign judges
    the *released output stream* — the paper's actual system property.

    Sites:
      weights       persistent storage SEU in replica 0's parameters.  The
                    scrub-gated policies (ABFT, CKPT) verify against
                    deploy-time storage checksums, quarantine, restore from
                    the golden checkpoint (*incrementally* — only the
                    corrupted leaves are re-read), re-verify, readmit, and
                    replay recalled requests — trials end
                    ``detected_corrected`` with a measured recovery time.
      kv_cache      transient SEU in replica 0's live KV cache / recurrent
                    state mid-flight.
      decode_state  transient SEU in replica 0's sampled-token buffer
                    mid-flight.  Both transient sites are caught by the
                    engine's decode-state scrub: a CKPT fleet rolls the
                    engine back to its verified snapshot in place, an ABFT
                    fleet detects and drains + fails over, and DMR
                    pair-serving detects by stream divergence — three
                    recovery strategies, one certified outcome (SDC = 0).

    Under NONE the fleet releases whatever the corrupted replica produced:
    nonzero SDC, the baseline every dependable policy is judged against.
    One fleet instance is reused across all trials (engines stay compiled);
    ``Fleet.reset`` restores golden params and a fully-healthy fleet.
    """

    name = "fleet"
    sites = ("weights", "kv_cache", "decode_state")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.CKPT)
    shardable = True
    event_logged = True
    recovery_logged = True
    transport = "inproc"
    max_new_tokens = 4

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m"):
        from repro.configs import registry
        from repro.fleet.fleet import Fleet
        from repro.models import api as model_api
        from repro.models.config import reduced
        from repro.runtime.serving import Request
        self._Request = Request
        self.cfg = reduced(registry.get(arch))
        self.params = model_api.init_params(self.cfg, key)
        self.fleet = Fleet(self.cfg, self.params, n_replicas=2,
                           policy=Policy.NONE, capacity=2, max_len=64,
                           prefill_pad=8, scrub_every=3, snapshot_every=2,
                           backend=backend, transport=self.transport)
        self.prompts = [[5, 9, 2], [3, 1, 4, 1], [2, 7]]
        self._recovery = _RecoveryLog()
        # accumulates the fleet's per-trial dependability events (fleet-tick
        # clock) across a configuration's trials, drained by the runner into
        # the report's timeline columns
        self.events = EventLog()

    @staticmethod
    def supports(policy: Policy, site: str) -> bool:
        # DMR pair-serving judges output streams, so a cache strike that
        # never manifests in tokens is invisible to it — the pair agrees
        # and the (clean) stream releases.  That is masked, not SDC, so
        # the combination stays supported; every policy covers every site.
        return True

    def _serve(self, policy: Policy, site: str, fault, key):
        fleet = self.fleet
        fleet.reset(policy=policy)
        reqs = [self._Request(uid=i, prompt=list(p),
                              max_new_tokens=self.max_new_tokens)
                for i, p in enumerate(self.prompts)]
        for r in reqs:
            fleet.submit(r)
        if site == "weights":
            # strike the parameter store before serving (deploy-window SEU)
            fleet.strike(0, "weights", fault, key)
        else:   # transient sites: strike the live decode stage two ticks in
            fleet.tick()
            fleet.tick()
            fleet.strike(0, site, fault, key)
        fleet.run()
        return self._collect(reqs)

    def _collect(self, reqs):
        """Reduce a finished trial to (released streams, detected flag) and
        fold recovery/timeline accounting into the case's logs."""
        fleet = self.fleet
        outs = tuple(
            tuple(fleet.released[r.uid].output) if r.uid in fleet.released
            else None
            for r in reqs)
        m = fleet.metrics
        self._recovery.count += m.recoveries + m.state_rollbacks \
            + m.state_drains
        rec_hist = m.recovery_seconds
        if rec_hist.count:
            # histogram, not a list: reconstruct count entries preserving the
            # exact sum and max — all the report's recovery columns need
            n, total, peak = rec_hist.count, rec_hist.sum, float(rec_hist.max)
            if n == 1:
                self._recovery.seconds.append(total)
            else:
                self._recovery.seconds += [(total - peak) / (n - 1)] * (n - 1)
                self._recovery.seconds.append(peak)
        self.events.events.extend(fleet.event_log.drain())
        return outs, m.detections > 0

    def run_trials(self, policy, site, fault, keys):
        golden, _ = self._serve(policy, site, _IDENTITY, keys[0])
        # the golden pass must not contribute recovery or timeline accounting
        self._recovery.drain()
        self.events.clear()
        detected_l, mismatch_l = [], []
        for k in keys:
            out, det = self._serve(policy, site, fault, k)
            detected_l.append(bool(det))
            mismatch_l.append(out != golden)
        return np.asarray(detected_l), np.asarray(mismatch_l)

    def drain_recovery_stats(self) -> dict:
        return self._recovery.drain()


class FleetMPCase(FleetCase):
    """The ``rolling_deploy`` scenario on the process-isolation transport:
    a 2-replica fleet whose engines live in spawned worker processes
    (``fleet/transport.py``) performs a zero-drain rolling weight deploy
    *while serving*, and the SEU strikes **during the in-flight swap** —
    ``mid_swap`` fires while replica 1 is out of the router being patched,
    and the strike lands on replica 0, which is already swapped and
    carrying the fleet alone at that instant.

    This is the ROADMAP's campaign gate for the multi-host fleet: under
    ABFT/CKPT the certify-before-release scrub (against the *new* storage
    checksums) catches the corruption before any token ships — SDC = 0
    through the deploy window; under NONE the corrupted stream releases.

    ``shardable = False``: each trial drives real worker processes, so the
    case must own them — the campaign pool would fork chaos.  One fleet
    (and its two workers) is reused across all trials via ``Fleet.reset``.
    """

    name = "fleet_mp"
    sites = ("weights",)
    policies = (Policy.NONE, Policy.ABFT, Policy.CKPT)
    shardable = False
    transport = "proc"
    max_new_tokens = 6

    def _serve(self, policy: Policy, site: str, fault, key):
        fleet = self.fleet
        fleet.reset(policy=policy)
        reqs = [self._Request(uid=i, prompt=list(p),
                              max_new_tokens=self.max_new_tokens)
                for i, p in enumerate(self.prompts)]
        for r in reqs:
            fleet.submit(r)
        fleet.tick()
        fleet.tick()
        strike = fault is not _IDENTITY

        def mid_swap(rid):
            # replica 1 is mid-swap (out of the router, weights half new):
            # strike the already-swapped replica 0 — the only one serving
            if strike and rid == 1:
                fleet.strike(0, "weights", fault, key)

        fleet.deploy(params=self.params, mid_swap=mid_swap)
        fleet.run()
        return self._collect(reqs)

    def close(self):
        self.fleet.close()

    def __del__(self):
        try:
            self.close()
        except Exception:       # noqa: BLE001 — interpreter teardown
            pass


# ---------------------------------------------------------------------------
# Campaign driver
# ---------------------------------------------------------------------------

CASES: Dict[str, type] = {
    "qmatmul": QMatmulCase,
    "qconv2d": QConv2dCase,
    "flashattn": FlashAttnCase,
    "shipdet": ShipdetCase,
    "transformer": TransformerCase,
    "serving": ServingCase,
    "serving_int8kv": ServingInt8KVCase,
    "fleet": FleetCase,
    "fleet_mp": FleetMPCase,
}

SUPPORTED = {name: (cls.sites, cls.policies) for name, cls in CASES.items()}


def build_case(workload: str, seed: int = 0, backend: str = "jnp"):
    if workload not in CASES:
        raise KeyError(f"unknown workload {workload!r}; known: {sorted(CASES)}")
    return CASES[workload](jax.random.key(seed), backend)


def _spec_supported(spec: fl.CampaignSpec, cls: type) -> bool:
    """Class-level support check — no case instance needed, so sharded
    campaigns can filter the grid without paying a parent-side build."""
    supported = (spec.site in cls.sites and spec.policy in cls.policies)
    if supported and hasattr(cls, "supports"):
        supported = cls.supports(spec.policy, spec.site)
    return supported


def _finalize_config(spec: fl.CampaignSpec, cls: type,
                     acc: "engine_mod.ConfigAccumulator",
                     plan: stats_mod.SamplingPlan,
                     event_sink: List[dict] | None) -> ConfigResult:
    """Reduce an accumulator (however its chunks were executed) to a report
    row: classification, recovery columns, timeline columns, CI columns."""
    detected = np.asarray(acc.detected, bool)
    mismatch = np.asarray(acc.mismatch, bool)
    counts = classify_counts(detected, mismatch)
    n = acc.n
    if getattr(cls, "recovery_logged", False):
        secs = acc.recovery_seconds
        recovery = {
            "faults_recovered": acc.recovery_count,
            "recovery_ms_mean": float(np.mean(secs) * 1e3) if secs else 0.0,
            "recovery_ms_max": float(np.max(secs) * 1e3) if secs else 0.0}
    elif spec.policy == Policy.CKPT:
        # in-graph rollback (kernel/shipdet workloads): every corrected
        # trial was a rollback re-execution; latency is in-op, not host
        recovery = {"faults_recovered": counts["detected_corrected"]}
    else:
        recovery = {}
    if getattr(cls, "event_logged", False):
        # real chains, merged from the chunk outcomes (worker-drained when
        # sharded) in key order — identical to what a serial run logs
        elog = EventLog()
        elog.events.extend(acc.events)
        tl_cols, tls = _timeline_columns(elog)
    else:
        # in-graph trials (kernels, model forwards) cannot emit host
        # events mid-vmap — synthesize the equivalent chains from the
        # trial verdicts: strike at trial index i, same-tick detection
        # (the in-op check verdict lands within the op call itself)
        synth = EventLog(policy=spec.policy.value, site=spec.site,
                         fault=spec.fault_model)
        for i, (det, mis) in enumerate(zip(detected, mismatch)):
            synth.emit("strike", tick=i)
            if det:
                synth.emit("detection", tick=i, detail={"check": "in_op"})
                if spec.policy == Policy.CKPT and not mis:
                    synth.emit("recovery", tick=i,
                               detail={"action": "in_op_rollback"})
        tl_cols, tls = _timeline_columns(synth)
    if event_sink is not None:
        event_sink.append({"config": spec.label(), "timelines": tls})
    sdc_lo, sdc_hi = plan.sdc_interval(counts["sdc"], n)
    det_lo, det_hi = stats_mod.binomial_interval(
        counts["detected_corrected"] + counts["detected_uncorrected"], n,
        plan.confidence, plan.ci_method)
    return ConfigResult(
        workload=spec.workload, policy=spec.policy.value, site=spec.site,
        fault_model=spec.fault_model, trials=n, backend=spec.backend,
        max_trials=spec.trials, early_stopped=acc.early_stopped,
        ci_method=plan.ci_method, ci_confidence=plan.confidence,
        sdc_ci_lo=sdc_lo, sdc_ci_hi=sdc_hi,
        detection_ci_lo=det_lo, detection_ci_hi=det_hi,
        **counts, **recovery, **tl_cols)


def run_campaign(specs: Sequence[fl.CampaignSpec],
                 log: Callable[[str], None] = lambda s: None,
                 cache: Dict[Tuple[str, int, str], object] | None = None,
                 event_sink: List[dict] | None = None,
                 plan: stats_mod.SamplingPlan | None = None,
                 journal: "engine_mod.CampaignJournal | None" = None,
                 pool: "engine_mod.CampaignPool | None" = None,
                 run_stats: dict | None = None,
                 _abort_after_chunks: int | None = None,
                 ) -> List[ConfigResult]:
    """Execute every configuration; returns one ConfigResult per spec.

    Deterministic: results depend only on (specs, their seeds, the plan's
    stopping rule) — never on how trials were scheduled.  Chunked, sharded
    (``plan.workers``), and resumed (``journal``) executions all merge the
    same key slices in the same order, so their counts, CI columns, and
    timeline columns are bit-identical to a serial run.

    Workload cases are cached per (workload, seed, backend) so all
    configurations of one workload share data, params, and compiled
    functions; pass ``cache`` (a dict, populated in place) to reuse the
    built cases afterwards, e.g. for a ``run_bit_sweep`` over the same
    workloads.  Sharded host-side cases are built inside the pool workers
    instead and never appear in ``cache``.

    ``plan`` selects fixed-budget (default) or sequential-sampling
    execution — see ``stats.SamplingPlan``.  ``journal`` makes the run
    resumable; ``run_stats`` (a dict, populated in place) reports
    ``{"trials_live", "trials_resumed", "configs_resumed"}``.

    Every configuration also yields injection→detection→recovery timelines:
    the engine/fleet cases maintain a live ``repro.obs.EventLog`` during
    their trials (drained per chunk, shipped across the pool when sharded),
    and for the in-graph cases (kernels, model forwards) the runner
    synthesizes the equivalent chains from the trial verdicts.  The reduced
    latency distributions land in each ``ConfigResult``'s timeline columns;
    pass ``event_sink`` (a list, appended in place) to also capture the raw
    per-configuration chains, e.g. for ``--events-out``.
    """
    if cache is None:
        cache = {}
    if plan is None:
        plan = stats_mod.SamplingPlan()
    if run_stats is None:
        run_stats = {}
    run_stats.setdefault("trials_live", 0)
    run_stats.setdefault("trials_resumed", 0)
    run_stats.setdefault("configs_resumed", 0)
    abort = engine_mod.AbortAfter(_abort_after_chunks) \
        if _abort_after_chunks is not None else None
    own_pool = None
    if pool is None and plan.workers > 0 and any(
            getattr(CASES.get(s.workload), "shardable", False)
            for s in specs):
        own_pool = pool = engine_mod.CampaignPool(plan.workers)
    results: List[ConfigResult] = []
    try:
        for spec in specs:
            if spec.workload not in CASES:
                raise KeyError(f"unknown workload {spec.workload!r}; "
                               f"known: {sorted(CASES)}")
            cls = CASES[spec.workload]
            if not _spec_supported(spec, cls):
                log(f"skip {spec.label()}: unsupported for workload")
                continue
            sharded = pool is not None and getattr(cls, "shardable", False)
            case = None
            if not sharded:
                cache_key = (spec.workload, spec.seed, spec.backend)
                case = cache.get(cache_key)
                if case is None:
                    case = build_case(spec.workload, spec.seed, spec.backend)
                    cache[cache_key] = case
            chunk_size = plan.kernel_chunk if issubclass(cls, _KernelCase) \
                else plan.chunk
            acc = engine_mod.run_config(
                spec, plan, chunk_size, case=case,
                pool=pool if sharded else None, journal=journal, abort=abort)
            run_stats["trials_resumed"] += acc.resumed_trials
            run_stats["trials_live"] += acc.n - acc.resumed_trials
            if acc.resumed_trials and acc.resumed_trials == acc.n:
                run_stats["configs_resumed"] += 1
            res = _finalize_config(spec, cls, acc, plan, event_sink)
            log(f"{spec.label()}: det={res.detection_rate:.3f} "
                f"sdc={res.sdc_rate:.3f} cov={res.coverage:.3f} "
                f"n={res.trials}/{res.max_trials}"
                + (" (early stop)" if res.early_stopped else "")
                + (f" rec={res.faults_recovered}"
                   if res.faults_recovered else ""))
            results.append(res)
    finally:
        if own_pool is not None:
            own_pool.close()
    return results


# ---------------------------------------------------------------------------
# Per-bit-position accumulator coverage
# ---------------------------------------------------------------------------

ACC_BITS = 32          # the accumulator site is int32


def kernel_workloads() -> List[str]:
    """Workloads with a vmappable accumulator hook (bit-sweepable)."""
    return sorted(n for n, c in CASES.items() if issubclass(c, _KernelCase))


def run_bit_sweep(workload: str, policies: Sequence[Policy],
                  trials_per_bit: int = 8, seed: int = 0,
                  backend: str = "jnp", case=None,
                  plan: stats_mod.SamplingPlan | None = None,
                  ) -> List[BitCoverageRow]:
    """Targeted accumulator sweep: for every int32 bit position, inject
    ``trials_per_bit`` flips at that exact bit (random element each time)
    and classify.  The resulting table separates the two masking regimes —
    low bits the requantization rescale rounds away (``masked``) from high
    bits that corrupt the output — and shows which of those a policy
    detects.  Kernel-shaped workloads only (the sweep vmaps over (bit,
    trial) in one compile, ~``ACC_BITS × trials_per_bit`` trials per
    policy).

    Under an adaptive ``plan`` the sweep runs in trial chunks and stops —
    per policy — at the first chunk boundary where *every* bit position's
    SDC-rate CI half-width is within ``plan.ci_halfwidth``; rows then carry
    the executed (not requested) trial count.  Keys are split by the
    ``trials_per_bit`` cap and sliced per chunk, so adaptive and fixed
    sweeps inject identical faults on their shared prefix.
    """
    cls = CASES.get(workload) if case is None else type(case)
    if cls is None:
        raise KeyError(f"unknown workload {workload!r}; known: {sorted(CASES)}")
    if not issubclass(cls, _KernelCase):
        raise ValueError(
            f"bit sweep is only supported for the kernel workloads "
            f"{kernel_workloads()} (they expose a vmappable accumulator "
            f"hook); got {workload!r}")
    if case is None:
        case = build_case(workload, seed, backend)
    if plan is None:
        plan = stats_mod.SamplingPlan()
    rows: List[BitCoverageRow] = []
    base = jax.random.key(seed)
    for policy in policies:
        if policy not in case.policies:
            continue
        disc = zlib.crc32(
            f"bitsweep/{workload}/{policy.value}/{backend}".encode())
        keys = jax.random.split(jax.random.fold_in(base, disc),
                                ACC_BITS * trials_per_bit)
        keys = keys.reshape(ACC_BITS, trials_per_bit)
        def trial(bit, key):
            # in-program golden: see _KernelCase.run_trials (float cases
            # need both streams compiled together for bit-exact compare)
            golden, _ = case._one(policy, "accumulator", _IDENTITY, key)
            fault = lambda x, k: fi.flip_bit_at(x, k, bit)
            y, det = case._one(policy, "accumulator", fault, key)
            return det, _bitwise_mismatch(y, golden)

        sweep = jax.jit(jax.vmap(jax.vmap(trial, in_axes=(None, 0)),
                                 in_axes=(0, 0)))
        bits = jnp.arange(ACC_BITS)
        det = np.zeros((ACC_BITS, 0), bool)
        mis = np.zeros((ACC_BITS, 0), bool)
        step = min(plan.chunk, trials_per_bit) if plan.adaptive \
            else trials_per_bit
        lo = 0
        while lo < trials_per_bit:
            hi = min(lo + step, trials_per_bit)
            d, m = sweep(bits, keys[:, lo:hi])
            det = np.concatenate([det, np.asarray(d, bool)], axis=1)
            mis = np.concatenate([mis, np.asarray(m, bool)], axis=1)
            lo = hi
            if plan.adaptive and lo < trials_per_bit \
                    and lo >= min(plan.min_trials, trials_per_bit):
                sdc = np.sum(mis & ~det, axis=1)
                if all(stats_mod.halfwidth(plan.sdc_interval(int(k), lo))
                       <= plan.ci_halfwidth for k in sdc):
                    break
        n = det.shape[1]
        for b in range(ACC_BITS):
            counts = classify_counts(det[b], mis[b])
            rows.append(BitCoverageRow(
                workload=workload, policy=policy.value, backend=backend,
                bit=b, trials=n, **counts))
    return rows
