"""Campaign trial execution: workload cases, policy wrapping, classification.

Each workload case exposes one method —

    run_trials(policy, site, fault, keys) -> (detected[n], mismatch[n])

where ``fault(x, key) -> x'`` is a fault-model primitive.  The *golden*
reference for a configuration is the same code path run with an identity
fault, so classification measures exactly the injected fault's effect, never
incidental numeric differences between execution paths.

Injection-site semantics per policy:

  accumulator   fault the int32 matmul/conv accumulator via the ``inject=``
                hook (compute-path SEU — what ABFT's checksum covers)
  weights       fault the stored quantized weights before execution
                (memory SEU — ABFT detects it only with a deploy-time
                checksum; recompute-recovery cannot fix it)
  activations   fault the layer input (upstream data SEU — outside any
                single layer's ABFT contract; TMR still corrects it when
                only one replica's copy is hit)

TMR is evaluated at the campaign level with explicit replica voting
(``redundancy.vote``/``agree``): replica 0 executes with the fault, replicas
1–2 clean, matching spatial TMR where a single event upsets one replica.
DMR is its detect-only half: replica 0 (faulted) vs one clean replica,
disagreement raises the alarm but replica 0's output ships unchanged —
manifested faults classify ``detected_uncorrected`` (covered, because a
failover layer takes over; the ``fleet`` workload closes that loop).

Kernel-shaped cases (qmatmul, qconv2d) are pure JAX all the way through, so
trials are vmapped and jitted in one batch; model/serving cases inject on
the host (pytree surgery) and loop over jitted forwards.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign import faultload as fl
from repro.campaign.report import BitCoverageRow, ConfigResult, classify_counts
from repro.core import abft as abft_mod
from repro.core import fault_injection as fi
from repro.core import redundancy
from repro.core.dependability import (
    Policy, dependable_qconv2d, dependable_qmatmul)
from repro.core.fault_injection import _as_bits

_IDENTITY = lambda x, key: x


def _bitwise_mismatch(a, b) -> jax.Array:
    """() bool — any leaf of pytree ``a`` differs bit-for-bit from ``b``
    (bit-pattern compare: NaN-safe, dtype-uniform)."""
    out = jnp.asarray(False)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        ab, _ = _as_bits(la)
        bb, _ = _as_bits(lb)
        out = out | jnp.any(ab != bb)
    return out


def _tmr_vote(faulty, clean) -> Tuple[jax.Array, jax.Array]:
    """(voted_output, detected) for replicas [faulty, clean, clean]."""
    detected = ~redundancy.agree([faulty, clean])
    voted = redundancy.vote([faulty, clean, clean])
    return voted, detected


def _dmr_check(faulty, clean) -> Tuple[jax.Array, jax.Array]:
    """(replica-0 output, detected) for replicas [faulty, clean] — DMR is
    detect-only, so the faulted replica's output ships unchanged."""
    return faulty, ~redundancy.agree([faulty, clean])


# ---------------------------------------------------------------------------
# Kernel-shaped cases: fully vmappable
# ---------------------------------------------------------------------------


class _KernelCase:
    """Shared trial machinery for the pure-JAX op cases: subclasses build the
    quantized operands in __init__ and implement ``_op`` (the dependable op
    call); site dispatch, TMR voting, and the vmapped trial loop live here.

    ``backend`` selects the execution engine (core/backend.py) every trial
    runs on — the axis that lets one campaign certify the jnp path and the
    Pallas kernel path side by side."""

    sites = ("accumulator", "weights", "activations")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR)

    backend = "jnp"

    def _op(self, policy: Policy, x_q, w_q, inject, w_check):
        raise NotImplementedError

    def _one(self, policy: Policy, site: str, fault, key):
        x_q, w_q, inject = self.x_q, self.w_q, None
        if site == "weights":
            w_q = fault(w_q, key)
        elif site == "activations":
            x_q = fault(x_q, key)
        else:
            inject = lambda acc: fault(acc, key)

        if policy in (Policy.TMR, Policy.DMR) and site != "accumulator":
            # spatial redundancy: the SEU hit one replica's *operand copy*,
            # so the clean replicas and the vote live at the campaign level
            y, _ = self._op(Policy.NONE, x_q, w_q, inject, None)
            y_clean, _ = self._op(Policy.NONE, self.x_q, self.w_q, None, None)
            check = _tmr_vote if policy == Policy.TMR else _dmr_check
            return check(y, y_clean)

        # accumulator faults (and every NONE/ABFT trial) drive the dependable
        # op itself — its stats are the detection verdict, so TMR correction
        # counts and ABFT checksum hits surface exactly as deployed code
        # would report them
        y, st = self._op(policy, x_q, w_q, inject,
                         self.w_check if policy == Policy.ABFT else None)
        if policy == Policy.NONE:
            return y, jnp.asarray(False)
        return y, st["faults_detected"] > 0

    def run_trials(self, policy, site, fault, keys):
        golden, _ = self._one(policy, site, _IDENTITY, keys[0])

        def trial(key):
            y, detected = self._one(policy, site, fault, key)
            return detected, _bitwise_mismatch(y, golden)

        detected, mismatch = jax.jit(jax.vmap(trial))(keys)
        return np.asarray(detected), np.asarray(mismatch)


class QMatmulCase(_KernelCase):
    """int8×int8→int32 matmul + requant (the paper's hot-path primitive)."""

    name = "qmatmul"

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 m: int = 32, k: int = 64, n: int = 48):
        self.backend = backend
        kx, kw, kb = jax.random.split(key, 3)
        self.x_q = jax.random.randint(kx, (m, k), -128, 128).astype(jnp.int8)
        self.w_q = jax.random.randint(kw, (k, n), -127, 128).astype(jnp.int8)
        self.bias = jax.random.randint(kb, (n,), -500, 500).astype(jnp.int32)
        self.x_zp = jnp.int32(3)
        self.out_zp = jnp.int32(0)
        self.scale = jnp.full((n,), 1e-3, jnp.float32)
        # deploy-time checksum from the known-good weights (weight-SEU cover)
        self.w_check = abft_mod.checksum_vector(self.w_q)

    def _op(self, policy, x_q, w_q, inject, w_check):
        return dependable_qmatmul(
            policy, x_q, self.x_zp, w_q, self.bias, self.scale, self.out_zp,
            inject=inject, w_check=w_check, backend=self.backend)


class QConv2dCase(_KernelCase):
    """int8 NHWC conv + requant (the HPDP's Table-1 op, reduced geometry)."""

    name = "qconv2d"

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 h: int = 12, w: int = 12, cin: int = 8, cout: int = 8):
        self.backend = backend
        kx, kw, kb = jax.random.split(key, 3)
        self.x_q = jax.random.randint(kx, (1, h, w, cin), -128, 128).astype(jnp.int8)
        self.w_q = jax.random.randint(kw, (3, 3, cin, cout), -127, 128).astype(jnp.int8)
        self.bias = jax.random.randint(kb, (cout,), -100, 100).astype(jnp.int32)
        self.x_zp = jnp.int32(2)
        self.out_zp = jnp.int32(0)
        self.scale = jnp.full((cout,), 1e-3, jnp.float32)
        self.w_check = abft_mod.conv_checksum_weight(self.w_q)

    def _op(self, policy, x_q, w_q, inject, w_check):
        return dependable_qconv2d(
            policy, x_q, self.x_zp, w_q, self.bias, self.scale, self.out_zp,
            inject=inject, w_check=w_check, backend=self.backend)


# ---------------------------------------------------------------------------
# Model cases: host-side pytree injection + jitted forwards
# ---------------------------------------------------------------------------


class ShipdetCase:
    """The paper's ship-detection CNN (reduced geometry), full-network
    forward under a per-layer dependability policy."""

    name = "shipdet"
    sites = ("accumulator", "weights", "activations")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR)

    def __init__(self, key: jax.Array, backend: str = "jnp"):
        from repro.models import shipdet
        self._shipdet = shipdet
        self.backend = backend
        kp, kx = jax.random.split(key)
        self.specs = shipdet.reduced_specs()
        self.params = shipdet.init_params(self.specs, kp)
        s0 = self.specs[0]
        self.x = jax.random.uniform(kx, (1, s0.h, s0.w, 3))

    def _wq_pytree(self, params) -> List[jax.Array]:
        return [p["qconv"].w_q for p in params]

    def _with_wq(self, wq_leaves) -> list:
        return [{**p, "qconv": p["qconv"]._replace(w_q=wq)}
                for p, wq in zip(self.params, wq_leaves)]

    def run_trials(self, policy, site, fault, keys):
        sd = self._shipdet
        base = Policy.NONE if policy in (Policy.TMR, Policy.DMR) else policy

        def fwd(params, x, inject=None):
            out, st = sd.forward(self.specs, params, x, policy=base,
                                 inject=inject, backend=self.backend)
            return out, st["faults_detected"] > 0

        detected_l, mismatch_l = [], []
        if site == "weights":
            run = jax.jit(lambda p, x: fwd(p, x))
            golden, _ = run(self.params, self.x)
            clean = golden
            for k in keys:
                wq = fl.inject_pytree_with(self._wq_pytree(self.params), k, fault)
                out, det = run(self._with_wq(wq), self.x)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, clean)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, clean)
                detected_l.append(bool(det) if policy != Policy.NONE else False)
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        else:
            if site == "activations":
                def one(key):
                    x = fault(self.x, key)
                    return fwd(self.params, x)

                golden, _ = jax.jit(lambda: fwd(self.params, self.x))()
            else:   # accumulator — mid-layer int32 accumulator hook
                def one(key):
                    return fwd(self.params, self.x,
                               inject=lambda acc: fault(acc, key))

                golden, _ = jax.jit(
                    lambda: fwd(self.params, self.x, inject=lambda a: a))()

            one_j = jax.jit(one)
            clean = golden
            for k in keys:
                out, det = one_j(k)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, clean)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, clean)
                detected_l.append(bool(det) if policy != Policy.NONE else False)
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        return np.asarray(detected_l), np.asarray(mismatch_l)


class TransformerCase:
    """Small transformer LM forward from the config registry (float path —
    no integer checksum exists, so the supported policies are NONE/TMR)."""

    name = "transformer"
    sites = ("weights", "activations")
    policies = (Policy.NONE, Policy.DMR, Policy.TMR)

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m"):
        from repro.configs import registry
        from repro.models import api as model_api
        from repro.models.config import reduced
        self._api = model_api
        kp, kt = jax.random.split(key)
        self.cfg = model_api.with_backend(reduced(registry.get(arch)), backend)
        self.params = model_api.init_params(self.cfg, kp)
        self.tokens = jax.random.randint(kt, (2, 16), 0, self.cfg.vocab_size)

    def run_trials(self, policy, site, fault, keys):
        api = self._api

        def logits_from_params(params):
            return api.forward(self.cfg, params, self.tokens).logits

        def logits_from_embeds(embeds):
            return api.forward(self.cfg, self.params, self.tokens,
                               embeds=embeds).logits

        detected_l, mismatch_l = [], []
        if site == "weights":
            run = jax.jit(logits_from_params)
            golden = run(self.params)
            for k in keys:
                out = run(fl.inject_pytree_with(self.params, k, fault))
                det = jnp.asarray(False)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, golden)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, golden)
                detected_l.append(bool(det))
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        else:   # activations — fault the token embeddings feeding the stack
            embeds = self.params["embed"][self.tokens]

            def one(key):
                return logits_from_embeds(fault(embeds, key))

            one_j = jax.jit(one)
            golden = jax.jit(lambda: logits_from_embeds(embeds))()
            for k in keys:
                out = one_j(k)
                det = jnp.asarray(False)
                if policy == Policy.TMR:
                    out, det = _tmr_vote(out, golden)
                elif policy == Policy.DMR:
                    out, det = _dmr_check(out, golden)
                detected_l.append(bool(det))
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        return np.asarray(detected_l), np.asarray(mismatch_l)


class ServingCase:
    """End-to-end serving drill: SEUs strike the weight memory of a live
    continuous-batching engine; classification compares full generated token
    streams.  Detected faults are rolled into the engine's DependabilityStats
    so the serving layer reports campaign results like any other counter."""

    name = "serving"
    sites = ("weights",)
    policies = (Policy.NONE, Policy.DMR, Policy.TMR)

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m"):
        from repro.configs import registry
        from repro.models import api as model_api
        from repro.models.config import reduced
        from repro.runtime.serving import Engine, Request
        self._Request = Request
        self.cfg = reduced(registry.get(arch))
        self.params = model_api.init_params(self.cfg, key)
        self.engine = Engine(self.cfg, self.params, capacity=2, max_len=64,
                             prefill_pad=8, backend=backend)
        self.prompts = [[5, 9, 2], [3, 1, 4, 1]]

    def _run_engine(self, params) -> Tuple[Tuple[int, ...], ...]:
        self.engine.reset(params=params)
        reqs = [self._Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(self.prompts)]
        for r in reqs:
            self.engine.submit(r)
        self.engine.run()
        return tuple(tuple(r.output) for r in reqs)

    def run_trials(self, policy, site, fault, keys):
        golden = self._run_engine(self.params)
        detected_l, mismatch_l = [], []
        for k in keys:
            out = self._run_engine(fl.inject_pytree_with(self.params, k, fault))
            differs = out != golden
            if policy == Policy.TMR:
                # temporal TMR: clean replicas replay deterministically, so a
                # per-token majority of (faulty, clean, clean) is the clean
                # stream; disagreement is the detection signal.
                detected_l.append(differs)
                mismatch_l.append(False)
                if differs:
                    self.engine.record_dependability({
                        "faults_detected": jnp.int32(1),
                        "checks_run": jnp.int32(1)})
            elif policy == Policy.DMR:
                # detect-only: the pair disagrees but the faulted stream is
                # what shipped — detected_uncorrected until a failover layer
                # (the fleet workload) replays it
                detected_l.append(differs)
                mismatch_l.append(differs)
                if differs:
                    self.engine.record_dependability({
                        "faults_detected": jnp.int32(1),
                        "checks_run": jnp.int32(1)})
            else:
                detected_l.append(False)
                mismatch_l.append(differs)
        return np.asarray(detected_l), np.asarray(mismatch_l)


class FleetCase:
    """Fleet-level end-to-end drill: an SEU strikes ONE replica of a live
    multi-replica serving fleet (src/repro/fleet/) and the campaign judges
    the *released output stream* — the paper's actual system property.

    Sites:
      weights      persistent storage SEU in replica 0's parameters.  The
                   ABFT fleet policy scrubs against deploy-time storage
                   checksums, quarantines, reloads from the golden
                   checkpoint, re-verifies, readmits, and replays recalled
                   requests — trials end ``detected_corrected``.
      accumulator  transient SEU in replica 0's live decode-state (the
                   sampled-token buffer) mid-flight.  DMR pair-serving
                   detects the divergence, scrub-attribution clears the
                   weights, and the replayed request restores the golden
                   stream.  The weight scrub cannot see this site, so
                   ABFT×accumulator is an unsupported combination
                   (``supports``) — the blind spot is the contract
                   boundary, not a bug (see docs/fleet.md).

    Under NONE the fleet releases whatever the corrupted replica produced:
    nonzero SDC, the baseline every dependable policy is judged against.
    One fleet instance is reused across all trials (engines stay compiled);
    ``Fleet.reset`` restores golden params and a fully-healthy fleet.
    """

    name = "fleet"
    sites = ("weights", "accumulator")
    policies = (Policy.NONE, Policy.ABFT, Policy.DMR)

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m"):
        from repro.configs import registry
        from repro.fleet.fleet import Fleet
        from repro.models import api as model_api
        from repro.models.config import reduced
        from repro.runtime.serving import Request
        self._Request = Request
        self.cfg = reduced(registry.get(arch))
        self.params = model_api.init_params(self.cfg, key)
        self.fleet = Fleet(self.cfg, self.params, n_replicas=2,
                           policy=Policy.NONE, capacity=2, max_len=64,
                           prefill_pad=8, scrub_every=3, backend=backend)
        self.prompts = [[5, 9, 2], [3, 1, 4, 1], [2, 7]]

    @staticmethod
    def supports(policy: Policy, site: str) -> bool:
        return not (policy == Policy.ABFT and site == "accumulator")

    def _serve(self, policy: Policy, site: str, fault, key):
        fleet = self.fleet
        fleet.reset(policy=policy)
        reqs = [self._Request(uid=i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(self.prompts)]
        for r in reqs:
            fleet.submit(r)
        victim = fleet.replicas[0]
        if site == "weights":
            victim.engine.params = fl.inject_pytree_with(
                victim.engine.params, key, fault)
        else:   # accumulator: strike live decode state two ticks in
            fleet.tick()
            fleet.tick()
            victim.engine.tokens = fault(victim.engine.tokens, key)
        fleet.run()
        outs = tuple(
            tuple(fleet.released[r.uid].output) if r.uid in fleet.released
            else None
            for r in reqs)
        return outs, fleet.metrics.detections > 0

    def run_trials(self, policy, site, fault, keys):
        golden, _ = self._serve(policy, site, _IDENTITY, keys[0])
        detected_l, mismatch_l = [], []
        for k in keys:
            out, det = self._serve(policy, site, fault, k)
            detected_l.append(bool(det))
            mismatch_l.append(out != golden)
        return np.asarray(detected_l), np.asarray(mismatch_l)


# ---------------------------------------------------------------------------
# Campaign driver
# ---------------------------------------------------------------------------

CASES: Dict[str, type] = {
    "qmatmul": QMatmulCase,
    "qconv2d": QConv2dCase,
    "shipdet": ShipdetCase,
    "transformer": TransformerCase,
    "serving": ServingCase,
    "fleet": FleetCase,
}

SUPPORTED = {name: (cls.sites, cls.policies) for name, cls in CASES.items()}


def build_case(workload: str, seed: int = 0, backend: str = "jnp"):
    if workload not in CASES:
        raise KeyError(f"unknown workload {workload!r}; known: {sorted(CASES)}")
    return CASES[workload](jax.random.key(seed), backend)


def run_campaign(specs: Sequence[fl.CampaignSpec],
                 log: Callable[[str], None] = lambda s: None,
                 cache: Dict[Tuple[str, int, str], object] | None = None,
                 ) -> List[ConfigResult]:
    """Execute every configuration; returns one ConfigResult per spec.

    Deterministic: results depend only on (specs, their seeds).  Workload
    cases are cached per (workload, seed, backend) so all configurations of
    one workload share data, params, and compiled functions; pass ``cache``
    (a dict, populated in place) to reuse the built cases afterwards, e.g.
    for a ``run_bit_sweep`` over the same workloads.
    """
    if cache is None:
        cache = {}
    results: List[ConfigResult] = []
    for spec in specs:
        cache_key = (spec.workload, spec.seed, spec.backend)
        case = cache.get(cache_key)
        if case is None:
            case = build_case(spec.workload, spec.seed, spec.backend)
            cache[cache_key] = case
        supported = (spec.site in case.sites and spec.policy in case.policies)
        if supported and hasattr(case, "supports"):
            supported = case.supports(spec.policy, spec.site)
        if not supported:
            log(f"skip {spec.label()}: unsupported for workload")
            continue
        fault = fl.resolve_fault_model(spec.fault_model)
        keys = fl.trial_keys(spec)
        detected, mismatch = case.run_trials(spec.policy, spec.site,
                                             fault.apply, keys)
        counts = classify_counts(detected, mismatch)
        res = ConfigResult(
            workload=spec.workload, policy=spec.policy.value, site=spec.site,
            fault_model=spec.fault_model, trials=spec.trials,
            backend=spec.backend, **counts)
        log(f"{spec.label()}: det={res.detection_rate:.3f} "
            f"sdc={res.sdc_rate:.3f} cov={res.coverage:.3f}")
        results.append(res)
    return results


# ---------------------------------------------------------------------------
# Per-bit-position accumulator coverage
# ---------------------------------------------------------------------------

ACC_BITS = 32          # the accumulator site is int32


def run_bit_sweep(workload: str, policies: Sequence[Policy],
                  trials_per_bit: int = 8, seed: int = 0,
                  backend: str = "jnp", case=None) -> List[BitCoverageRow]:
    """Targeted accumulator sweep: for every int32 bit position, inject
    ``trials_per_bit`` flips at that exact bit (random element each time)
    and classify.  The resulting table separates the two masking regimes —
    low bits the requantization rescale rounds away (``masked``) from high
    bits that corrupt the output — and shows which of those a policy
    detects.  Kernel-shaped workloads only (the sweep vmaps over (bit,
    trial) in one compile, ~``ACC_BITS × trials_per_bit`` trials per
    policy).
    """
    if case is None:
        case = build_case(workload, seed, backend)
    if not isinstance(case, _KernelCase):
        raise ValueError(f"bit sweep needs a kernel-shaped workload "
                         f"(vmappable accumulator hook); {workload!r} is not")
    rows: List[BitCoverageRow] = []
    base = jax.random.key(seed)
    for policy in policies:
        if policy not in case.policies:
            continue
        disc = zlib.crc32(
            f"bitsweep/{workload}/{policy.value}/{backend}".encode())
        keys = jax.random.split(jax.random.fold_in(base, disc),
                                ACC_BITS * trials_per_bit)
        keys = keys.reshape(ACC_BITS, trials_per_bit)
        golden, _ = case._one(policy, "accumulator", _IDENTITY, keys[0, 0])

        def trial(bit, key):
            fault = lambda x, k: fi.flip_bit_at(x, k, bit)
            y, det = case._one(policy, "accumulator", fault, key)
            return det, _bitwise_mismatch(y, golden)

        det, mis = jax.jit(jax.vmap(jax.vmap(trial, in_axes=(None, 0)),
                                    in_axes=(0, 0)))(
            jnp.arange(ACC_BITS), keys)
        det, mis = np.asarray(det), np.asarray(mis)
        for b in range(ACC_BITS):
            counts = classify_counts(det[b], mis[b])
            rows.append(BitCoverageRow(
                workload=workload, policy=policy.value, backend=backend,
                bit=b, trials=trials_per_bit, **counts))
    return rows
