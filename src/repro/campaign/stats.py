"""Sequential statistical sampling for fault-injection campaigns.

DAVOS-style iterative statistical injection: instead of burning a fixed
``--trials`` per configuration, trials run in chunks and the configuration
stops as soon as its SDC-rate binomial confidence interval is tight enough
to support the verdict.  The math here is deliberately dependency-free
(no scipy in the image):

  * **Wilson score interval** — the default.  Closed-form, well-behaved at
    the boundary rates campaigns live at (SDC = 0/n for a working policy,
    detection = n/n), never degenerates to a zero-width interval the way
    the naive Wald interval does at p̂ ∈ {0, 1}.
  * **Clopper–Pearson** — the exact interval, computed by bisecting the
    binomial CDF (log-space pmf summation, no special functions beyond
    ``math.lgamma``).  Conservative: never *tighter* than Wilson, so a
    CP-stopped campaign never stops earlier than a Wilson-stopped one at
    the same target half-width.

``SamplingPlan`` bundles the stopping rule plus the execution knobs the
adaptive engine needs (chunk sizes, minimum sample, worker count) so one
frozen value pins a campaign's entire execution policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import numpy as np

# two-sided normal quantiles for the confidence levels campaigns use; a
# lookup (not an erfinv approximation) keeps stopping decisions bit-stable
# across platforms
_Z = {
    0.80: 1.2815515655446004,
    0.90: 1.6448536269514722,
    0.95: 1.959963984540054,
    0.98: 2.3263478740408408,
    0.99: 2.5758293035489004,
    0.995: 2.807033768343811,
}

CI_METHODS = ("wilson", "clopper-pearson")


def z_for_confidence(confidence: float) -> float:
    for level, z in _Z.items():
        if abs(confidence - level) < 1e-9:
            return z
    raise ValueError(f"unsupported confidence level {confidence!r}; "
                     f"supported: {sorted(_Z)}")


def wilson_interval(k: int, n: int, confidence: float = 0.95,
                    ) -> Tuple[float, float]:
    """Wilson score interval for ``k`` successes in ``n`` Bernoulli trials."""
    if n <= 0:
        return (0.0, 1.0)
    z = z_for_confidence(confidence)
    p = k / n
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2.0 * n)) / denom
    hw = (z / denom) * math.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n))
    # pin the boundary cases exactly: center ∓ hw leaves float dust at
    # k ∈ {0, n} (≈1e-17), which would make CI columns seed-shaped noise
    lo = 0.0 if k <= 0 else max(0.0, center - hw)
    hi = 1.0 if k >= n else min(1.0, center + hw)
    return (lo, hi)


def _binom_cdf(k: int, n: int, p: float) -> float:
    """P(X <= k) for X ~ Binomial(n, p), via log-space pmf summation."""
    if k >= n:
        return 1.0
    if p <= 0.0:
        return 1.0
    if p >= 1.0:
        return 0.0
    i = np.arange(0, k + 1, dtype=np.int64)
    log_c = np.array([math.lgamma(n + 1) - math.lgamma(int(j) + 1)
                      - math.lgamma(n - int(j) + 1) for j in i])
    log_pmf = log_c + i * math.log(p) + (n - i) * math.log1p(-p)
    m = float(log_pmf.max())
    return float(min(1.0, math.exp(m) * float(np.exp(log_pmf - m).sum())))


def _bisect(f, lo: float, hi: float, iters: int = 80) -> float:
    """Root of monotone ``f`` on [lo, hi] with f(lo) <= 0 <= f(hi)."""
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if f(mid) <= 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def clopper_pearson_interval(k: int, n: int, confidence: float = 0.95,
                             ) -> Tuple[float, float]:
    """Exact (conservative) binomial interval by CDF bisection."""
    if n <= 0:
        return (0.0, 1.0)
    z_for_confidence(confidence)        # validate the level early
    alpha = 1.0 - confidence
    # lower bound: largest p with P(X >= k | p) <= alpha/2
    if k <= 0:
        lo = 0.0
    else:
        # P(X >= k | p) grows with p: negative below the root, as _bisect
        # expects (f(lo) <= 0 <= f(hi))
        lo = _bisect(lambda p: (1.0 - _binom_cdf(k - 1, n, p)) - (alpha / 2.0),
                     0.0, 1.0)
    # upper bound: smallest p with P(X <= k | p) <= alpha/2
    if k >= n:
        hi = 1.0
    else:
        hi = _bisect(lambda p: (alpha / 2.0) - _binom_cdf(k, n, p), 0.0, 1.0)
    return (lo, hi)


def binomial_interval(k: int, n: int, confidence: float = 0.95,
                      method: str = "wilson") -> Tuple[float, float]:
    if method == "wilson":
        return wilson_interval(k, n, confidence)
    if method == "clopper-pearson":
        return clopper_pearson_interval(k, n, confidence)
    raise ValueError(f"unknown CI method {method!r}; known: {CI_METHODS}")


def halfwidth(interval: Tuple[float, float]) -> float:
    lo, hi = interval
    return (hi - lo) / 2.0


def class_intervals(counts: Dict[str, int], trials: int,
                    confidence: float = 0.95, method: str = "wilson",
                    ) -> Dict[str, Tuple[float, float]]:
    """Binomial CI per outcome class (masked / detected_* / sdc)."""
    return {cls: binomial_interval(k, trials, confidence, method)
            for cls, k in counts.items()}


@dataclasses.dataclass(frozen=True)
class SamplingPlan:
    """How a campaign executes its trials.

    ``ci_halfwidth = 0`` is the legacy fixed-budget mode: every configuration
    runs exactly ``spec.trials`` trials.  ``ci_halfwidth > 0`` switches on
    sequential sampling: trials run in chunks and the configuration stops at
    the first chunk boundary where the SDC-rate CI half-width is at most
    ``ci_halfwidth`` (after at least ``min_trials`` trials), with
    ``spec.trials`` as the hard cap.  The stopping decision is evaluated in
    chunk order, so sharded execution — which merely computes chunks
    speculatively on other processes — stops at exactly the same boundary
    and executes exactly the same trial set as a serial run.
    """
    ci_halfwidth: float = 0.0
    confidence: float = 0.95
    ci_method: str = "wilson"
    chunk: int = 25             # host-side cases: trials per scheduling chunk
    kernel_chunk: int = 128     # vmapped cases: trials per compiled batch
    min_trials: int = 25        # adaptive floor before the CI may stop a row
    workers: int = 0            # >0: process-pool sharding for host cases

    def __post_init__(self):
        if self.ci_halfwidth < 0:
            raise ValueError("ci_halfwidth must be >= 0")
        if self.chunk < 1 or self.kernel_chunk < 1:
            raise ValueError("chunk sizes must be >= 1")
        if self.ci_method not in CI_METHODS:
            raise ValueError(f"unknown CI method {self.ci_method!r}; "
                             f"known: {CI_METHODS}")
        z_for_confidence(self.confidence)

    @property
    def adaptive(self) -> bool:
        return self.ci_halfwidth > 0

    def sdc_interval(self, sdc: int, n: int) -> Tuple[float, float]:
        return binomial_interval(sdc, n, self.confidence, self.ci_method)

    def should_stop(self, sdc: int, n: int, cap: int) -> bool:
        """Evaluate the stopping rule after ``n`` merged trials."""
        if n >= cap:
            return True
        if not self.adaptive or n < min(self.min_trials, cap):
            return False
        return halfwidth(self.sdc_interval(sdc, n)) <= self.ci_halfwidth
