"""Crash-consistent campaign journal — resumable runs.

Every configuration appends its trial chunks to a per-config record under
``<out>/journal/``; a killed campaign resumes with ``--resume <dir>``:
completed configurations are skipped outright, half-finished ones continue
from the recorded trial offset with the correct key stream.

Publish discipline is the same as ``train/checkpoint.IncrementalCheckpointer``
manifests: the whole record is rewritten to ``<name>.tmp``, fsynced, then
``os.rename``d over the live file — a crash at any instant leaves either the
previous consistent record or the new one, never a torn file.  Unparseable
records (including a torn ``.tmp`` from a crash mid-write) are ignored and
the configuration simply re-runs.

Resume correctness hinges on one contract: per-trial PRNG keys come from
``faultload.trial_keys``, which splits the config's folded seed into exactly
``spec.trials`` (the cap) keys.  ``jax.random.split`` is *not* prefix-stable
across different counts, so a record is only continued when the stored spec
(seed, cap, fault model, backend, …) matches the requested one bit-for-bit;
any mismatch discards the record and restarts that configuration.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import zlib
from typing import Dict, List, Optional

from repro.campaign.faultload import CampaignSpec
from repro.core.dependability import Policy

JOURNAL_VERSION = 1


def spec_to_doc(spec: CampaignSpec) -> dict:
    d = dataclasses.asdict(spec)
    d["policy"] = spec.policy.value
    return d


def spec_from_doc(d: dict) -> CampaignSpec:
    d = dict(d)
    d["policy"] = Policy(d["policy"])
    return CampaignSpec(**d)


class CampaignJournal:
    """Directory of per-configuration trial records, atomically published."""

    def __init__(self, root):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def path_for(self, spec: CampaignSpec) -> pathlib.Path:
        label = spec.label()
        slug = label.replace("/", "_").replace("@", "_")
        return self.root / f"{zlib.crc32(label.encode()):08x}_{slug}.json"

    # ------------------------------------------------------------- read
    def load(self, spec: CampaignSpec) -> Optional[dict]:
        """The stored record for ``spec``, or None if absent, torn, or
        written by a different spec (changed seed/cap/… ⇒ stale keys)."""
        path = self.path_for(spec)
        try:
            doc = json.loads(path.read_text())
        except (OSError, ValueError):
            return None
        if doc.get("version") != JOURNAL_VERSION:
            return None
        try:
            stored = spec_from_doc(doc["spec"])
        except (KeyError, TypeError, ValueError):
            return None
        if stored != spec:
            return None
        return doc

    # ------------------------------------------------------------ write
    def publish(self, spec: CampaignSpec, chunks: List[dict],
                done: bool) -> pathlib.Path:
        """Atomically rewrite the record: tmp → fsync → rename."""
        path = self.path_for(spec)
        doc = {
            "version": JOURNAL_VERSION,
            "label": spec.label(),
            "spec": spec_to_doc(spec),
            "trials_done": sum(c["hi"] - c["lo"] for c in chunks),
            "done": bool(done),
            "chunks": list(chunks),
        }
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
        return path

    # ---------------------------------------------------------- inspect
    def records(self) -> Dict[str, dict]:
        """Every parseable record in the journal, keyed by config label."""
        out: Dict[str, dict] = {}
        for p in sorted(self.root.glob("*.json")):
            try:
                doc = json.loads(p.read_text())
            except (OSError, ValueError):
                continue
            if doc.get("version") == JOURNAL_VERSION and "label" in doc:
                out[doc["label"]] = doc
        return out
