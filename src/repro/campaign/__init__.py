"""Statistical SEU fault-injection campaign engine.

DAVOS-style dependability assessment for the software-rendered rad-hard
stack: sweep fault models × injection sites × dependability policies ×
workloads, classify every seeded trial, and emit a per-configuration
coverage report.  See docs/dependability.md for how to read one.

The execution layer is adaptive (docs/campaign.md): ``SamplingPlan`` turns
on sequential sampling with early stopping, ``CampaignPool`` shards
host-side workloads across processes with bit-identical results, and
``CampaignJournal`` makes runs crash-resumable.
"""
from repro.campaign.engine import (
    AbortAfter, CampaignInterrupted, CampaignPool, ChunkOutcome, run_config)
from repro.campaign.faultload import (
    FAULT_MODELS, CampaignSpec, expand_grid, resolve_fault_model, trial_keys)
from repro.campaign.journal import CampaignJournal
from repro.campaign.report import (
    BitCoverageRow, ConfigResult, classify_counts, load_report, to_markdown,
    write_report)
from repro.campaign.runner import (
    CASES, build_case, kernel_workloads, run_bit_sweep, run_campaign)
from repro.campaign.stats import (
    SamplingPlan, binomial_interval, clopper_pearson_interval, halfwidth,
    wilson_interval)

__all__ = [
    "FAULT_MODELS", "CampaignSpec", "expand_grid", "resolve_fault_model",
    "trial_keys", "BitCoverageRow", "ConfigResult", "classify_counts",
    "load_report", "to_markdown", "write_report", "CASES", "build_case",
    "kernel_workloads", "run_bit_sweep", "run_campaign",
    "SamplingPlan", "binomial_interval", "clopper_pearson_interval",
    "halfwidth", "wilson_interval", "CampaignJournal", "CampaignPool",
    "CampaignInterrupted", "ChunkOutcome", "AbortAfter", "run_config",
]
