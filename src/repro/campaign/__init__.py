"""Statistical SEU fault-injection campaign engine.

DAVOS-style dependability assessment for the software-rendered rad-hard
stack: sweep fault models × injection sites × dependability policies ×
workloads, classify every seeded trial, and emit a per-configuration
coverage report.  See docs/dependability.md for how to read one.
"""
from repro.campaign.faultload import (
    FAULT_MODELS, CampaignSpec, expand_grid, resolve_fault_model, trial_keys)
from repro.campaign.report import (
    BitCoverageRow, ConfigResult, classify_counts, load_report, to_markdown,
    write_report)
from repro.campaign.runner import (
    CASES, build_case, run_bit_sweep, run_campaign)

__all__ = [
    "FAULT_MODELS", "CampaignSpec", "expand_grid", "resolve_fault_model",
    "trial_keys", "BitCoverageRow", "ConfigResult", "classify_counts",
    "load_report", "to_markdown", "write_report", "CASES", "build_case",
    "run_bit_sweep", "run_campaign",
]
