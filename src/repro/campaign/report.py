"""Campaign result classification + coverage reports (JSON + markdown).

Every trial lands in exactly one DAVOS-style dependability class, derived
from two observables — did the policy raise a detection, and does the final
output differ bit-for-bit from the fault-free golden run:

                      output == golden     output != golden
  no detection        masked               SDC  (silent data corruption)
  detection raised    detected_corrected   detected_uncorrected

Coverage = 1 − SDC rate: the fraction of injected faults that could not
silently corrupt the result (either they never manifested, or the policy
caught them — caught-but-uncorrected faults still trigger recovery at a
higher layer, e.g. checkpoint restore, so they are not silent).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Sequence, Tuple

import numpy as np

CLASSES = ("masked", "detected_corrected", "detected_uncorrected", "sdc")


def classify_counts(detected: np.ndarray, mismatch: np.ndarray) -> Dict[str, int]:
    """Vector classification of a trial batch → per-class counts."""
    detected = np.asarray(detected, bool)
    mismatch = np.asarray(mismatch, bool)
    return {
        "masked": int((~detected & ~mismatch).sum()),
        "detected_corrected": int((detected & ~mismatch).sum()),
        "detected_uncorrected": int((detected & mismatch).sum()),
        "sdc": int((~detected & mismatch).sum()),
    }


@dataclasses.dataclass(frozen=True)
class ConfigResult:
    """One row of the coverage report: a configuration and its trial tallies.

    The recovery columns quantify the restart half of the dependability
    loop: ``faults_recovered`` counts rollback recoveries (CKPT op
    re-executions, engine snapshot restores, fleet incremental restores /
    drains) and the latency columns carry their measured wall-clock cost —
    host-side recoveries only; in-graph rollbacks (kernel workloads) are
    part of the op's own runtime and report latency 0.
    """
    workload: str
    policy: str
    site: str
    fault_model: str
    trials: int
    masked: int
    detected_corrected: int
    detected_uncorrected: int
    sdc: int
    backend: str = "jnp"       # execution backend the trials ran on
    faults_recovered: int = 0  # rollback/restart recoveries across trials
    recovery_ms_mean: float = 0.0
    recovery_ms_max: float = 0.0
    # injection→detection→recovery timelines reconstructed from the
    # structured dependability event log (repro.obs.events): how many
    # strike chains were logged, and the detection-/recovery-latency
    # distributions in the emitting layer's deterministic ticks
    strikes_logged: int = 0
    detections_logged: int = 0
    detection_ticks_mean: float = 0.0
    detection_ticks_max: int = 0
    recovery_ticks_mean: float = 0.0
    recovery_ticks_max: int = 0
    # sequential-sampling columns (adaptive engine): ``trials`` above is the
    # *executed* count; ``max_trials`` the configured cap (0 in legacy
    # reports written before the adaptive engine).  The CI bounds are the
    # binomial interval on the SDC / detection rates at ``ci_confidence``
    # via ``ci_method`` (wilson or clopper-pearson).
    max_trials: int = 0
    early_stopped: bool = False
    ci_method: str = ""
    ci_confidence: float = 0.0
    sdc_ci_lo: float = 0.0
    sdc_ci_hi: float = 0.0
    detection_ci_lo: float = 0.0
    detection_ci_hi: float = 0.0

    @property
    def detection_rate(self) -> float:
        return (self.detected_corrected + self.detected_uncorrected) / max(self.trials, 1)

    @property
    def sdc_rate(self) -> float:
        return self.sdc / max(self.trials, 1)

    @property
    def coverage(self) -> float:
        return 1.0 - self.sdc_rate

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["detection_rate"] = self.detection_rate
        d["sdc_rate"] = self.sdc_rate
        d["coverage"] = self.coverage
        return d

    @staticmethod
    def from_dict(d: dict) -> "ConfigResult":
        fields = {f.name for f in dataclasses.fields(ConfigResult)}
        return ConfigResult(**{k: v for k, v in d.items() if k in fields})


@dataclasses.dataclass(frozen=True)
class BitCoverageRow:
    """Per-bit-position accumulator coverage: ``trials`` flips targeted at
    int32 bit ``bit`` of the accumulator, classified like any campaign
    trial.  Low-bit rows are where requantization masks (the fp32 rescale
    rounds ±2^bit to the same int8); high-bit rows are where only the
    policy stands between the flip and SDC."""
    workload: str
    policy: str
    backend: str
    bit: int
    trials: int
    masked: int
    detected_corrected: int
    detected_uncorrected: int
    sdc: int

    @property
    def detection_rate(self) -> float:
        return (self.detected_corrected + self.detected_uncorrected) / max(self.trials, 1)

    @property
    def masked_rate(self) -> float:
        return self.masked / max(self.trials, 1)

    @property
    def sdc_rate(self) -> float:
        return self.sdc / max(self.trials, 1)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["detection_rate"] = self.detection_rate
        d["masked_rate"] = self.masked_rate
        d["sdc_rate"] = self.sdc_rate
        return d

    @staticmethod
    def from_dict(d: dict) -> "BitCoverageRow":
        fields = {f.name for f in dataclasses.fields(BitCoverageRow)}
        return BitCoverageRow(**{k: v for k, v in d.items() if k in fields})


def to_json_dict(results: Sequence[ConfigResult], meta: dict | None = None,
                 bit_coverage: Sequence[BitCoverageRow] | None = None) -> dict:
    out = {"meta": dict(meta or {}),
           "results": [r.to_dict() for r in results]}
    if bit_coverage:
        out["bit_coverage"] = [r.to_dict() for r in bit_coverage]
    return out


def from_json_dict(d: dict) -> Tuple[dict, List[ConfigResult]]:
    return d.get("meta", {}), [ConfigResult.from_dict(r) for r in d["results"]]


def bit_coverage_from_json_dict(d: dict) -> List[BitCoverageRow]:
    return [BitCoverageRow.from_dict(r) for r in d.get("bit_coverage", [])]


def load_report(path) -> Tuple[dict, List[ConfigResult]]:
    with open(path) as f:
        return from_json_dict(json.load(f))


def to_markdown(results: Sequence[ConfigResult], meta: dict | None = None,
                bit_coverage: Sequence[BitCoverageRow] | None = None) -> str:
    lines = ["# SEU fault-injection campaign report", ""]
    for k, v in (meta or {}).items():
        lines.append(f"- **{k}**: {v}")
    if meta:
        lines.append("")
    lines += [
        "| workload | backend | policy | site | fault model | trials | masked "
        "| det-corr | det-unc | SDC | det. rate | SDC rate | SDC 95% CI "
        "| coverage | recovered | rec. mean ms | det. lat ticks (mean/max) "
        "| rec. lat ticks (mean/max) |",
        "|---|---|---|---|---|---:|---:|---:|---:|---:|---:|---:|---:|---:"
        "|---:|---:|---:|---:|",
    ]
    for r in results:
        rec_ms = f"{r.recovery_ms_mean:.2f}" if r.faults_recovered else "—"
        det_lat = (f"{r.detection_ticks_mean:.1f}/{r.detection_ticks_max}"
                   if r.detections_logged else "—")
        rec_lat = (f"{r.recovery_ticks_mean:.1f}/{r.recovery_ticks_max}"
                   if r.faults_recovered and r.strikes_logged else "—")
        trials = (f"{r.trials}*" if r.early_stopped else f"{r.trials}")
        sdc_ci = (f"[{r.sdc_ci_lo:.3f}, {r.sdc_ci_hi:.3f}]"
                  if r.ci_method else "—")
        lines.append(
            f"| {r.workload} | {r.backend} | {r.policy} | {r.site} "
            f"| {r.fault_model} "
            f"| {trials} | {r.masked} | {r.detected_corrected} "
            f"| {r.detected_uncorrected} | {r.sdc} "
            f"| {r.detection_rate:.3f} | {r.sdc_rate:.3f} | {sdc_ci} "
            f"| {r.coverage:.3f} "
            f"| {r.faults_recovered} | {rec_ms} | {det_lat} | {rec_lat} |")
    if any(r.early_stopped for r in results):
        lines.append("")
        lines.append("\\* stopped early: SDC-rate CI half-width reached the "
                     "requested precision before the trial cap.")
    lines.append("")
    if bit_coverage:
        lines += [
            "## Accumulator bit-position coverage",
            "",
            "Which int32 accumulator bits the requantization rescale masks"
            " (flip never reaches the int8 output) vs. which the policy"
            " detects:",
            "",
            "| workload | backend | policy | bit | trials | masked "
            "| det-corr | det-unc | SDC | masked rate | det. rate |",
            "|---|---|---|---:|---:|---:|---:|---:|---:|---:|---:|",
        ]
        for r in bit_coverage:
            lines.append(
                f"| {r.workload} | {r.backend} | {r.policy} | {r.bit} "
                f"| {r.trials} | {r.masked} | {r.detected_corrected} "
                f"| {r.detected_uncorrected} | {r.sdc} "
                f"| {r.masked_rate:.3f} | {r.detection_rate:.3f} |")
        lines.append("")
    return "\n".join(lines)


def write_report(results: Sequence[ConfigResult], out_dir,
                 meta: dict | None = None,
                 basename: str = "campaign",
                 bit_coverage: Sequence[BitCoverageRow] | None = None,
                 ) -> Tuple[pathlib.Path, pathlib.Path]:
    """Write <out_dir>/<basename>.json and .md; returns both paths."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    jpath = out / f"{basename}.json"
    mpath = out / f"{basename}.md"
    with open(jpath, "w") as f:
        json.dump(to_json_dict(results, meta, bit_coverage), f, indent=2)
    mpath.write_text(to_markdown(results, meta, bit_coverage))
    return jpath, mpath
