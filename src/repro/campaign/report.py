"""Campaign result classification + coverage reports (JSON + markdown).

Every trial lands in exactly one DAVOS-style dependability class, derived
from two observables — did the policy raise a detection, and does the final
output differ bit-for-bit from the fault-free golden run:

                      output == golden     output != golden
  no detection        masked               SDC  (silent data corruption)
  detection raised    detected_corrected   detected_uncorrected

Coverage = 1 − SDC rate: the fraction of injected faults that could not
silently corrupt the result (either they never manifested, or the policy
caught them — caught-but-uncorrected faults still trigger recovery at a
higher layer, e.g. checkpoint restore, so they are not silent).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Sequence, Tuple

import numpy as np

CLASSES = ("masked", "detected_corrected", "detected_uncorrected", "sdc")


def classify_counts(detected: np.ndarray, mismatch: np.ndarray) -> Dict[str, int]:
    """Vector classification of a trial batch → per-class counts."""
    detected = np.asarray(detected, bool)
    mismatch = np.asarray(mismatch, bool)
    return {
        "masked": int((~detected & ~mismatch).sum()),
        "detected_corrected": int((detected & ~mismatch).sum()),
        "detected_uncorrected": int((detected & mismatch).sum()),
        "sdc": int((~detected & mismatch).sum()),
    }


@dataclasses.dataclass(frozen=True)
class ConfigResult:
    """One row of the coverage report: a configuration and its trial tallies."""
    workload: str
    policy: str
    site: str
    fault_model: str
    trials: int
    masked: int
    detected_corrected: int
    detected_uncorrected: int
    sdc: int

    @property
    def detection_rate(self) -> float:
        return (self.detected_corrected + self.detected_uncorrected) / max(self.trials, 1)

    @property
    def sdc_rate(self) -> float:
        return self.sdc / max(self.trials, 1)

    @property
    def coverage(self) -> float:
        return 1.0 - self.sdc_rate

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["detection_rate"] = self.detection_rate
        d["sdc_rate"] = self.sdc_rate
        d["coverage"] = self.coverage
        return d

    @staticmethod
    def from_dict(d: dict) -> "ConfigResult":
        fields = {f.name for f in dataclasses.fields(ConfigResult)}
        return ConfigResult(**{k: v for k, v in d.items() if k in fields})


def to_json_dict(results: Sequence[ConfigResult], meta: dict | None = None) -> dict:
    return {"meta": dict(meta or {}),
            "results": [r.to_dict() for r in results]}


def from_json_dict(d: dict) -> Tuple[dict, List[ConfigResult]]:
    return d.get("meta", {}), [ConfigResult.from_dict(r) for r in d["results"]]


def load_report(path) -> Tuple[dict, List[ConfigResult]]:
    with open(path) as f:
        return from_json_dict(json.load(f))


def to_markdown(results: Sequence[ConfigResult], meta: dict | None = None) -> str:
    lines = ["# SEU fault-injection campaign report", ""]
    for k, v in (meta or {}).items():
        lines.append(f"- **{k}**: {v}")
    if meta:
        lines.append("")
    lines += [
        "| workload | policy | site | fault model | trials | masked "
        "| det-corr | det-unc | SDC | det. rate | SDC rate | coverage |",
        "|---|---|---|---|---:|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for r in results:
        lines.append(
            f"| {r.workload} | {r.policy} | {r.site} | {r.fault_model} "
            f"| {r.trials} | {r.masked} | {r.detected_corrected} "
            f"| {r.detected_uncorrected} | {r.sdc} "
            f"| {r.detection_rate:.3f} | {r.sdc_rate:.3f} | {r.coverage:.3f} |")
    lines.append("")
    return "\n".join(lines)


def write_report(results: Sequence[ConfigResult], out_dir,
                 meta: dict | None = None,
                 basename: str = "campaign") -> Tuple[pathlib.Path, pathlib.Path]:
    """Write <out_dir>/<basename>.json and .md; returns both paths."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    jpath = out / f"{basename}.json"
    mpath = out / f"{basename}.md"
    with open(jpath, "w") as f:
        json.dump(to_json_dict(results, meta), f, indent=2)
    mpath.write_text(to_markdown(results, meta))
    return jpath, mpath
