"""Campaign CLI — run a statistical SEU fault-injection sweep and write a
DAVOS-style coverage report.

    PYTHONPATH=src python -m repro.campaign.cli \
        --workload qmatmul --policies none,abft,tmr --trials 200 --seed 0 \
        --backend pallas

Writes <out>/campaign.json and <out>/campaign.md and prints the coverage
table.  Everything is deterministic in --seed.  ``--backend`` sweeps the
execution-backend axis (jnp | ref | pallas — see docs/backends.md); kernel
workloads additionally get a per-bit-position accumulator coverage table
(``--bit-trials 0`` to skip).
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.campaign import faultload as fl
from repro.campaign import report as report_mod
from repro.campaign import runner
from repro.core.dependability import Policy

DEFAULT_FAULT_MODELS = "single_bitflip,multi_bitflip,stuck_at0,stuck_at1"


def _csv(s: str):
    return [t.strip() for t in s.split(",") if t.strip()]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.campaign.cli",
        description="Statistical SEU fault-injection campaign engine")
    p.add_argument("--workload", default="qmatmul",
                   help=f"comma list or 'all'; known: {sorted(runner.CASES)}")
    p.add_argument("--policies", default="none,abft,dmr,tmr,ckpt",
                   help="comma list of dependability policies")
    p.add_argument("--sites", default="all",
                   help=f"comma list or 'all'; known: {list(fl.SITES)}")
    p.add_argument("--fault-models", default=DEFAULT_FAULT_MODELS,
                   help="comma list (multi_bitflip@<rate> for custom rates)")
    p.add_argument("--trials", type=int, default=200,
                   help="seeded trials per configuration")
    p.add_argument("--backend", "--backends", dest="backend", default="jnp",
                   help="comma list of execution backends (jnp, ref, pallas)")
    p.add_argument("--bit-trials", type=int, default=8,
                   help="per-bit accumulator sweep trials for kernel "
                        "workloads (0 disables the bit-coverage table)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="reports/campaign",
                   help="output directory for campaign.json / campaign.md")
    p.add_argument("--events-out", default=None,
                   help="also write the raw injection→detection→recovery "
                        "timelines (one entry per configuration) as JSON")
    p.add_argument("--quiet", action="store_true")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.trials < 1:
        print("--trials must be >= 1", file=sys.stderr)
        return 2
    log = (lambda s: None) if args.quiet else (lambda s: print(s, flush=True))

    workloads = sorted(runner.CASES) if args.workload == "all" \
        else _csv(args.workload)
    policies = [Policy(p) for p in _csv(args.policies)]
    sites = list(fl.SITES) if args.sites == "all" else _csv(args.sites)
    fault_models = _csv(args.fault_models)
    backends = _csv(args.backend)

    specs = fl.expand_grid(workloads, policies, sites, fault_models,
                           trials=args.trials, seed=args.seed,
                           supported=runner.SUPPORTED, backends=backends)
    if not specs:
        print("no runnable configurations for this sweep", file=sys.stderr)
        return 2

    log(f"campaign: {len(specs)} configurations × {args.trials} trials "
        f"(seed {args.seed}, backends {','.join(backends)})")
    t0 = time.time()
    case_cache = {}
    event_sink = [] if args.events_out else None
    results = runner.run_campaign(specs, log=log, cache=case_cache,
                                  event_sink=event_sink)

    bit_rows = []
    if args.bit_trials > 0 and "accumulator" in sites:
        for be in backends:
            for w in workloads:
                if not isinstance(runner.CASES.get(w), type) or not issubclass(
                        runner.CASES[w], runner._KernelCase):
                    continue
                case_policies = [p for p in policies
                                 if p in runner.CASES[w].policies]
                log(f"bit sweep: {w} [{be}] × "
                    f"{','.join(p.value for p in case_policies)}")
                bit_rows.extend(runner.run_bit_sweep(
                    w, case_policies, trials_per_bit=args.bit_trials,
                    seed=args.seed, backend=be,
                    case=case_cache.get((w, args.seed, be))))
    elapsed = time.time() - t0

    meta = {
        "workloads": ",".join(workloads),
        "policies": ",".join(p.value for p in policies),
        "sites": ",".join(sites),
        "fault_models": ",".join(fault_models),
        "backends": ",".join(backends),
        "trials_per_config": args.trials,
        "bit_trials": args.bit_trials,
        "seed": args.seed,
        "configurations": len(results),
        "elapsed_seconds": round(elapsed, 2),
    }
    jpath, mpath = report_mod.write_report(results, args.out, meta,
                                           bit_coverage=bit_rows)
    if event_sink is not None:
        import json
        import pathlib
        epath = pathlib.Path(args.events_out)
        epath.parent.mkdir(parents=True, exist_ok=True)
        with open(epath, "w") as f:
            json.dump({"meta": meta, "configs": event_sink}, f,
                      indent=2, sort_keys=True)
        log(f"wrote {epath} ({sum(len(e['timelines']) for e in event_sink)} "
            "timelines)")
    print(report_mod.to_markdown(results, meta, bit_coverage=bit_rows))
    print(f"wrote {jpath} and {mpath} ({elapsed:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
