"""Campaign CLI — run a statistical SEU fault-injection sweep and write a
DAVOS-style coverage report.

    PYTHONPATH=src python -m repro.campaign.cli \
        --workload qmatmul --policies none,abft,tmr --trials 200 --seed 0 \
        --backend pallas

Writes <out>/campaign.json and <out>/campaign.md and prints the coverage
table.  Everything is deterministic in --seed.  ``--backend`` sweeps the
execution-backend axis (jnp | ref | pallas — see docs/backends.md); kernel
workloads additionally get a per-bit-position accumulator coverage table
(``--bit-trials 0`` to skip).

Adaptive mode (``--ci-halfwidth 0.05``) runs each configuration in chunks
and stops at the first chunk boundary where the SDC-rate confidence
interval is tighter than the target — ``--trials`` then acts as the hard
cap.  ``--workers N`` fans host-side workloads across a process pool with
bit-identical results; ``--resume <dir>`` continues a killed campaign from
its journal.  See docs/campaign.md.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.campaign import engine as engine_mod
from repro.campaign import faultload as fl
from repro.campaign import journal as journal_mod
from repro.campaign import report as report_mod
from repro.campaign import runner
from repro.campaign import stats as stats_mod
from repro.core.dependability import Policy

DEFAULT_FAULT_MODELS = "single_bitflip,multi_bitflip,stuck_at0,stuck_at1"


def _csv(s: str):
    return [t.strip() for t in s.split(",") if t.strip()]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.campaign.cli",
        description="Statistical SEU fault-injection campaign engine")
    p.add_argument("--workload", default="qmatmul",
                   help=f"comma list or 'all'; known: {sorted(runner.CASES)}")
    p.add_argument("--policies", default="none,abft,dmr,tmr,ckpt",
                   help="comma list of dependability policies")
    p.add_argument("--sites", default="all",
                   help=f"comma list or 'all'; known: {list(fl.SITES)}")
    p.add_argument("--fault-models", default=DEFAULT_FAULT_MODELS,
                   help="comma list (multi_bitflip@<rate> for custom rates, "
                        "mbu_burst@<elems>x<bits> for custom MBU clusters)")
    p.add_argument("--trials", "--max-trials", dest="trials", type=int,
                   default=200,
                   help="seeded trials per configuration; under "
                        "--ci-halfwidth this is the hard cap the sequential "
                        "sampler may stop short of")
    p.add_argument("--backend", "--backends", dest="backend", default="jnp",
                   help="comma list of execution backends (jnp, ref, pallas)")
    p.add_argument("--bit-trials", type=int, default=8,
                   help="per-bit accumulator sweep trials for kernel "
                        "workloads (0 disables the bit-coverage table); "
                        "under --ci-halfwidth this too is a cap")
    p.add_argument("--seed", type=int, default=0)
    # ---- adaptive sequential sampling -----------------------------------
    p.add_argument("--ci-halfwidth", type=float, default=0.0,
                   help="stop a configuration once its SDC-rate CI "
                        "half-width is <= this (0 = fixed budget, run all "
                        "--trials)")
    p.add_argument("--confidence", type=float, default=0.95,
                   help="confidence level for the stopping CI and the "
                        "report's CI columns")
    p.add_argument("--ci-method", choices=("wilson", "clopper-pearson"),
                   default="wilson",
                   help="binomial interval: wilson (closed form) or "
                        "clopper-pearson (exact)")
    p.add_argument("--chunk", type=int, default=25,
                   help="trials per chunk for host-side workloads (the "
                        "stopping rule is checked at chunk boundaries)")
    p.add_argument("--kernel-chunk", type=int, default=100,
                   help="trials per compiled vmap batch for kernel "
                        "workloads (coarser: each chunk is one XLA call)")
    p.add_argument("--min-trials", type=int, default=25,
                   help="never stop a configuration before this many trials")
    # ---- sharding / resume ----------------------------------------------
    p.add_argument("--workers", type=int, default=0,
                   help="shard host-side workloads across N worker "
                        "processes (0 = in-process serial); results are "
                        "bit-identical either way")
    p.add_argument("--resume", default=None, metavar="DIR",
                   help="resume a previous run from DIR (its journal/ "
                        "subdirectory); implies --out DIR")
    p.add_argument("--no-journal", action="store_true",
                   help="skip writing the per-config resume journal")
    p.add_argument("--out", default="reports/campaign",
                   help="output directory for campaign.json / campaign.md")
    p.add_argument("--events-out", default=None,
                   help="also write the raw injection→detection→recovery "
                        "timelines (one entry per configuration) as JSON")
    p.add_argument("--quiet", action="store_true")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.trials < 1:
        print("--trials must be >= 1", file=sys.stderr)
        return 2
    if args.ci_halfwidth < 0:
        print("--ci-halfwidth must be >= 0", file=sys.stderr)
        return 2
    if args.workers < 0:
        print("--workers must be >= 0", file=sys.stderr)
        return 2
    if args.resume:
        args.out = args.resume
    log = (lambda s: None) if args.quiet else (lambda s: print(s, flush=True))

    workloads = sorted(runner.CASES) if args.workload == "all" \
        else _csv(args.workload)
    policies = [Policy(p) for p in _csv(args.policies)]
    sites = list(fl.SITES) if args.sites == "all" else _csv(args.sites)
    fault_models = _csv(args.fault_models)
    backends = _csv(args.backend)

    specs = fl.expand_grid(workloads, policies, sites, fault_models,
                           trials=args.trials, seed=args.seed,
                           supported=runner.SUPPORTED, backends=backends)
    if not specs:
        print("no runnable configurations for this sweep", file=sys.stderr)
        return 2

    plan = stats_mod.SamplingPlan(
        ci_halfwidth=args.ci_halfwidth, confidence=args.confidence,
        ci_method=args.ci_method, chunk=args.chunk,
        kernel_chunk=args.kernel_chunk,
        min_trials=args.min_trials, workers=args.workers)
    journal = None
    if not args.no_journal:
        import pathlib
        journal = journal_mod.CampaignJournal(
            pathlib.Path(args.out) / "journal")

    mode = (f"adaptive (halfwidth {args.ci_halfwidth:g} @ "
            f"{args.confidence:g} {args.ci_method})"
            if plan.adaptive else "fixed budget")
    log(f"campaign: {len(specs)} configurations × ≤{args.trials} trials, "
        f"{mode} (seed {args.seed}, backends {','.join(backends)}"
        + (f", {args.workers} workers" if args.workers else "")
        + (", resuming" if args.resume else "") + ")")
    t0 = time.time()
    case_cache = {}
    event_sink = [] if args.events_out else None
    run_stats: dict = {}
    try:
        results = runner.run_campaign(specs, log=log, cache=case_cache,
                                      event_sink=event_sink, plan=plan,
                                      journal=journal, run_stats=run_stats)
    except engine_mod.CampaignInterrupted as e:
        print(f"campaign interrupted: {e}; resume with --resume {args.out}",
              file=sys.stderr)
        return 3

    bit_rows = []
    if args.bit_trials > 0 and "accumulator" in sites:
        for be in backends:
            for w in workloads:
                if w not in runner.kernel_workloads():
                    continue
                case_policies = [p for p in policies
                                 if p in runner.CASES[w].policies]
                log(f"bit sweep: {w} [{be}] × "
                    f"{','.join(p.value for p in case_policies)}")
                bit_rows.extend(runner.run_bit_sweep(
                    w, case_policies, trials_per_bit=args.bit_trials,
                    seed=args.seed, backend=be,
                    case=case_cache.get((w, args.seed, be)), plan=plan))
    elapsed = time.time() - t0

    meta = {
        "workloads": ",".join(workloads),
        "policies": ",".join(p.value for p in policies),
        "sites": ",".join(sites),
        "fault_models": ",".join(fault_models),
        "backends": ",".join(backends),
        "trials_per_config": args.trials,
        "bit_trials": args.bit_trials,
        "seed": args.seed,
        "configurations": len(results),
        "ci_halfwidth": args.ci_halfwidth,
        "confidence": args.confidence,
        "ci_method": args.ci_method,
        "workers": args.workers,
        "trials_executed": sum(r.trials for r in results),
        "trials_live": run_stats.get("trials_live", 0),
        "trials_resumed": run_stats.get("trials_resumed", 0),
        "configs_resumed": run_stats.get("configs_resumed", 0),
        "elapsed_seconds": round(elapsed, 2),
    }
    jpath, mpath = report_mod.write_report(results, args.out, meta,
                                           bit_coverage=bit_rows)
    if event_sink is not None:
        import json
        import pathlib
        epath = pathlib.Path(args.events_out)
        epath.parent.mkdir(parents=True, exist_ok=True)
        with open(epath, "w") as f:
            json.dump({"meta": meta, "configs": event_sink}, f,
                      indent=2, sort_keys=True)
        log(f"wrote {epath} ({sum(len(e['timelines']) for e in event_sink)} "
            "timelines)")
    print(report_mod.to_markdown(results, meta, bit_coverage=bit_rows))
    print(f"wrote {jpath} and {mpath} ({elapsed:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
