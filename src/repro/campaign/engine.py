"""Adaptive campaign execution engine: chunked trials, sharding, resume.

This is the scheduling layer between the campaign driver (``runner.py``)
and the workload cases.  A configuration's trials no longer run as one
monolithic batch; they run as ordered *chunks* of the deterministic key
stream, which buys three things at once:

  * **sequential sampling** — after each chunk the SDC-rate confidence
    interval is re-evaluated (``stats.SamplingPlan``) and the configuration
    stops at the first chunk boundary where it is tight enough;
  * **sharded execution** — host-side cases (serving, fleet, shipdet,
    transformer) fan chunks across a spawn-based process pool
    (``CampaignPool``): each worker builds the case once from the same
    (workload, seed, backend) triple and runs key *slices* of the same
    stream, so per-trial results are bit-identical to a serial run.
    Speculative chunks computed past the stopping boundary are discarded,
    so adaptive sharded runs execute exactly the serial trial set;
  * **resumable campaigns** — every merged chunk is appended to the
    crash-consistent ``CampaignJournal``; a killed campaign resumes from
    the recorded trial offset with the correct key slice.

Dependability events (``repro.obs.EventLog``) and recovery accounting are
drained per chunk — in the worker when sharded — and shipped back inside
``ChunkOutcome``, so the report's timeline columns are identical whether
the trials ran in-process or across the pool.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import pathlib
from typing import List, Optional, Sequence, Tuple

from repro.campaign import faultload as fl
from repro.campaign.journal import CampaignJournal
from repro.campaign.stats import SamplingPlan
from repro.obs.events import Event


class CampaignInterrupted(RuntimeError):
    """A campaign was aborted mid-run (test hook / simulated kill).  The
    journal already holds every merged chunk, so ``--resume`` continues."""


class AbortAfter:
    """Test hook: raise ``CampaignInterrupted`` after N merged chunks —
    a deterministic stand-in for kill -9 between journal publishes."""

    def __init__(self, chunks: Optional[int]):
        self.remaining = chunks

    def tick(self) -> None:
        if self.remaining is None:
            return
        self.remaining -= 1
        if self.remaining <= 0:
            raise CampaignInterrupted("aborted by AbortAfter test hook")


@dataclasses.dataclass
class ChunkOutcome:
    """Per-trial verdicts plus drained side accounting for keys [lo, hi)."""
    lo: int
    hi: int
    detected: List[bool]
    mismatch: List[bool]
    recovery_count: int = 0
    recovery_seconds: List[float] = dataclasses.field(default_factory=list)
    events: List[Event] = dataclasses.field(default_factory=list)

    def to_doc(self) -> dict:
        return {"lo": self.lo, "hi": self.hi,
                "detected": [int(b) for b in self.detected],
                "mismatch": [int(b) for b in self.mismatch],
                "recovery_count": self.recovery_count,
                "recovery_seconds": list(self.recovery_seconds),
                "events": [dataclasses.asdict(e) for e in self.events]}

    @staticmethod
    def from_doc(d: dict) -> "ChunkOutcome":
        return ChunkOutcome(
            lo=d["lo"], hi=d["hi"],
            detected=[bool(b) for b in d["detected"]],
            mismatch=[bool(b) for b in d["mismatch"]],
            recovery_count=d.get("recovery_count", 0),
            recovery_seconds=list(d.get("recovery_seconds", [])),
            events=[Event(**e) for e in d.get("events", [])])


def run_config_chunk(case, spec: fl.CampaignSpec, lo: int, hi: int,
                     ) -> ChunkOutcome:
    """Run trials [lo, hi) of ``spec`` on ``case`` and drain its accounting.

    The key slice comes from the full ``trial_keys(spec)`` stream (split by
    the cap, then sliced), so any chunking of [0, trials) concatenates to
    the exact serial per-trial stream.
    """
    fault = fl.resolve_fault_model(spec.fault_model)
    keys = fl.trial_keys(spec)[lo:hi]
    detected, mismatch = case.run_trials(spec.policy, spec.site,
                                         fault.apply, keys)
    rec_count, rec_seconds = 0, []
    rlog = getattr(case, "_recovery", None)
    if rlog is not None:
        rec_count, rec_seconds = rlog.drain_raw()
    elog = getattr(case, "events", None)
    events = elog.drain() if elog is not None else []
    return ChunkOutcome(lo=lo, hi=hi,
                        detected=[bool(x) for x in detected],
                        mismatch=[bool(x) for x in mismatch],
                        recovery_count=rec_count,
                        recovery_seconds=rec_seconds,
                        events=events)


# ---------------------------------------------------------------------------
# Process-pool sharding
# ---------------------------------------------------------------------------

_WORKER_CASES: dict = {}


def _pool_init(src_path: str) -> None:
    # workers are compute replicas of the parent: CPU-pinned JAX, the repo's
    # src on the path (spawned interpreters don't inherit sys.path edits)
    if src_path and src_path not in os.sys.path:
        os.sys.path.insert(0, src_path)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax  # noqa: F401 — warm the import before the first task


def _pool_run_chunk(spec: fl.CampaignSpec, lo: int, hi: int) -> ChunkOutcome:
    from repro.campaign import runner
    key = (spec.workload, spec.seed, spec.backend)
    case = _WORKER_CASES.get(key)
    if case is None:
        case = _WORKER_CASES[key] = runner.build_case(*key)
    return run_config_chunk(case, spec, lo, hi)


class CampaignPool:
    """Persistent spawn-based worker pool for host-side trial chunks.

    Spawn (not fork): the parent holds a live XLA runtime whose locks and
    threads do not survive forking.  Each worker pays the jax-import and
    case-build cost once and then serves chunks for the rest of the
    campaign, so per-worker state (compiled engines, golden outputs) is
    reused across configurations of the same workload.
    """

    def __init__(self, workers: int):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        import repro
        # repro is a namespace package (__file__ is None): locate its src
        # root via __path__ so spawned workers can import it
        src = str(pathlib.Path(list(repro.__path__)[0]).resolve().parent)
        ctx = multiprocessing.get_context("spawn")
        self.workers = workers
        self._pool = ctx.Pool(workers, initializer=_pool_init,
                              initargs=(src,))

    def run_chunks(self, spec: fl.CampaignSpec,
                   spans: Sequence[Tuple[int, int]]) -> List[ChunkOutcome]:
        """Dispatch the spans concurrently; return outcomes in span order."""
        handles = [self._pool.apply_async(_pool_run_chunk, (spec, lo, hi))
                   for lo, hi in spans]
        return [h.get() for h in handles]

    def close(self) -> None:
        self._pool.terminate()
        self._pool.join()

    def __enter__(self) -> "CampaignPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Per-configuration adaptive driver
# ---------------------------------------------------------------------------


class ConfigAccumulator:
    """Ordered merge of a configuration's chunk outcomes."""

    def __init__(self, spec: fl.CampaignSpec):
        self.spec = spec
        self.detected: List[bool] = []
        self.mismatch: List[bool] = []
        self.recovery_count = 0
        self.recovery_seconds: List[float] = []
        self.events: List[Event] = []
        self.sdc = 0
        self.resumed_trials = 0     # trials replayed from the journal
        self.early_stopped = False

    @property
    def n(self) -> int:
        return len(self.detected)

    def merge(self, oc: ChunkOutcome) -> None:
        if oc.lo != self.n:
            raise ValueError(f"chunk out of order: have {self.n} trials, "
                             f"got [{oc.lo}, {oc.hi})")
        self.detected.extend(oc.detected)
        self.mismatch.extend(oc.mismatch)
        self.sdc += sum(1 for d, m in zip(oc.detected, oc.mismatch)
                        if m and not d)
        self.recovery_count += oc.recovery_count
        self.recovery_seconds.extend(oc.recovery_seconds)
        self.events.extend(oc.events)


def _spans(start: int, cap: int, chunk: int, lanes: int,
           ) -> List[Tuple[int, int]]:
    """Up to ``lanes`` contiguous chunk spans starting at ``start``."""
    spans = []
    lo = start
    for _ in range(lanes):
        if lo >= cap:
            break
        hi = min(lo + chunk, cap)
        spans.append((lo, hi))
        lo = hi
    return spans


def run_config(spec: fl.CampaignSpec, plan: SamplingPlan, chunk_size: int,
               case=None, pool: Optional[CampaignPool] = None,
               journal: Optional[CampaignJournal] = None,
               abort: Optional[AbortAfter] = None) -> ConfigAccumulator:
    """Execute one configuration under the sampling plan.

    Exactly one of ``case`` (serial, in-process) or ``pool`` (sharded)
    drives the trials.  The stopping rule is evaluated at every chunk
    boundary *in key order*; sharded lanes that ran past the boundary are
    discarded unmerged, so the executed trial set — and therefore every
    count, CI, and timeline column — is identical to a serial run.
    """
    if (case is None) == (pool is None):
        raise ValueError("exactly one of case / pool must be given")
    acc = ConfigAccumulator(spec)
    chunk_docs: List[dict] = []
    if journal is not None:
        rec = journal.load(spec)
        if rec is not None:
            for cd in rec["chunks"]:
                acc.merge(ChunkOutcome.from_doc(cd))
                chunk_docs.append(cd)
            acc.resumed_trials = acc.n
            if rec["done"]:
                acc.early_stopped = plan.adaptive and acc.n < spec.trials
                return acc
    cap = spec.trials
    lanes = pool.workers if pool is not None else 1
    stopped = plan.should_stop(acc.sdc, acc.n, cap) if acc.n else False
    while not stopped:
        spans = _spans(acc.n, cap, chunk_size, lanes)
        if not spans:
            break
        if pool is not None:
            outcomes = pool.run_chunks(spec, spans)
        else:
            outcomes = [run_config_chunk(case, spec, lo, hi)
                        for lo, hi in spans]
        for oc in outcomes:
            acc.merge(oc)
            chunk_docs.append(oc.to_doc())
            if journal is not None:
                journal.publish(spec, chunk_docs, done=False)
            if abort is not None:
                abort.tick()
            if plan.should_stop(acc.sdc, acc.n, cap):
                stopped = True
                break               # later lanes were speculative: discard
    acc.early_stopped = plan.adaptive and acc.n < cap
    if journal is not None:
        journal.publish(spec, chunk_docs, done=True)
    return acc
