"""Faultload generation — which faults strike where, reproducibly.

A *faultload* (DAVOS terminology) is the set of faults a campaign injects:
a fault model (what kind of corruption), an injection site (which tensor in
the execution path), and a deterministic per-trial PRNG key stream.  One
``CampaignSpec`` pins all of it plus the policy under test, so a campaign
row is rerunnable bit-for-bit from (spec, seed) alone.

Fault models map 1:1 onto ``core.fault_injection`` primitives:

  single_bitflip   one SEU: one random bit of one random element XORed
  multi_bitflip    fleet-scale rate model: every bit flips independently
                   (default rate 1e-4; ``multi_bitflip@3e-4`` overrides)
  stuck_at0/1      permanent fault: one random bit forced to 0 / 1
  mbu_burst        multi-bit upset: a seeded cluster of adjacent cells —
                   elems × bits rectangle, default 2×2 (``mbu_burst@4x1``
                   overrides) — per the neutron-irradiation MBU signature
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, List, Sequence

import jax

from repro.core import fault_injection as fi
from repro.core.fault_injection import inject_pytree_with  # noqa: F401 — re-export
from repro.core.dependability import Policy

DEFAULT_MULTI_RATE = 1e-4
DEFAULT_BURST = (2, 2)          # elems × bits: the smallest 2-D MBU cluster

SITES = ("accumulator", "weights", "activations", "kv_cache", "decode_state")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    name: str
    apply: Callable[[jax.Array, jax.Array], jax.Array]   # (x, key) -> x'
    description: str


def _rate_model(rate: float) -> FaultModel:
    return FaultModel(
        f"multi_bitflip@{rate:g}" if rate != DEFAULT_MULTI_RATE else "multi_bitflip",
        lambda x, key: fi.flip_bits_at_rate(x, key, rate),
        f"each bit flips independently with p={rate:g}")


def _burst_model(elems: int, bits: int) -> FaultModel:
    if elems < 1 or bits < 1:
        raise ValueError(f"mbu_burst cluster must be >= 1x1, got "
                         f"{elems}x{bits}")
    name = ("mbu_burst" if (elems, bits) == DEFAULT_BURST
            else f"mbu_burst@{elems}x{bits}")
    return FaultModel(
        name, lambda x, key: fi.flip_burst(x, key, elems, bits),
        f"MBU cluster: {elems} adjacent elements x {bits} adjacent bits "
        "flipped around a seeded anchor")


FAULT_MODELS = {
    "single_bitflip": FaultModel(
        "single_bitflip", fi.flip_one_bit,
        "one random bit of one random element XOR-flipped"),
    "multi_bitflip": _rate_model(DEFAULT_MULTI_RATE),
    "stuck_at0": FaultModel(
        "stuck_at0", lambda x, key: fi.stuck_at(x, key, 0),
        "one random bit forced to 0"),
    "stuck_at1": FaultModel(
        "stuck_at1", lambda x, key: fi.stuck_at(x, key, 1),
        "one random bit forced to 1"),
    "mbu_burst": _burst_model(*DEFAULT_BURST),
}


def resolve_fault_model(name: str) -> FaultModel:
    """Registry lookup; ``multi_bitflip@<rate>`` builds a custom-rate model,
    ``mbu_burst@<elems>x<bits>`` a custom-geometry burst cluster."""
    if name in FAULT_MODELS:
        return FAULT_MODELS[name]
    if name.startswith("multi_bitflip@"):
        return _rate_model(float(name.split("@", 1)[1]))
    if name.startswith("mbu_burst@"):
        try:
            elems, bits = name.split("@", 1)[1].split("x", 1)
            return _burst_model(int(elems), int(bits))
        except ValueError as e:
            raise KeyError(f"bad mbu_burst geometry in {name!r}; expected "
                           "mbu_burst@<elems>x<bits>, e.g. mbu_burst@4x1") \
                from e
    raise KeyError(f"unknown fault model {name!r}; known: "
                   f"{sorted(FAULT_MODELS)}, multi_bitflip@<rate>, "
                   "or mbu_burst@<elems>x<bits>")


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One campaign configuration = one row of the coverage report."""
    workload: str
    policy: Policy
    site: str
    fault_model: str
    trials: int
    seed: int = 0
    backend: str = "jnp"        # execution backend (core/backend.py registry)

    def label(self) -> str:
        base = (f"{self.workload}/{self.policy.value}/{self.site}/"
                f"{self.fault_model}")
        # the default backend keeps its historical label so existing seeded
        # campaigns (and their key streams, below) replay bit-for-bit
        return base if self.backend == "jnp" else f"{base}/{self.backend}"


def trial_keys(spec: CampaignSpec) -> jax.Array:
    """Deterministic per-trial key stream: the campaign seed folded with a
    stable hash of the configuration, so every row draws independent faults
    while the whole campaign replays exactly from one integer seed."""
    base = jax.random.key(spec.seed)
    disc = zlib.crc32(spec.label().encode())
    return jax.random.split(jax.random.fold_in(base, disc), spec.trials)


def expand_grid(
    workloads: Sequence[str],
    policies: Sequence[Policy],
    sites: Sequence[str],
    fault_models: Sequence[str],
    trials: int,
    seed: int = 0,
    supported: dict | None = None,
    backends: Sequence[str] = ("jnp",),
) -> List[CampaignSpec]:
    """Cartesian sweep, filtered to combinations the workload supports.

    ``supported`` maps workload -> (sites, policies); unsupported combos are
    dropped (e.g. ABFT on the float transformer has no checksum to check).
    ``backends`` adds the execution-backend axis (validated against the
    registry) so one sweep certifies e.g. jnp *and* pallas side by side.
    """
    from repro.core import backend as backend_mod
    for be in backends:
        backend_mod.get_backend(be)                  # fail fast on typos
    specs = []
    for w in workloads:
        if supported is not None and w not in supported:
            raise KeyError(f"unknown workload {w!r}; known: {sorted(supported)}")
        ok_sites, ok_policies = (supported or {}).get(w, (SITES, tuple(Policy)))
        for be in backends:
            for p in policies:
                if p not in ok_policies:
                    continue
                for s in sites:
                    if s not in ok_sites:
                        continue
                    for fm in fault_models:
                        resolve_fault_model(fm)      # fail fast on typos
                        specs.append(
                            CampaignSpec(w, p, s, fm, trials, seed, backend=be))
    return specs
