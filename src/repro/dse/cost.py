"""Measured cost oracle for the selective-hardening DSE.

The throughput axis of the Pareto search is *measured*, not modeled: every
(site × policy) combination in a search space is microbenchmarked at the
shapes the real workload executes — the transformer FFN sites on the
engine's own multi-step scanned decode window (mapped config, argmax
decode step), the shipdet conv
layers through ``dependable_qconv2d``, and the engine-level scrub machinery
(storage-checksum verify, decode-state checksum) that the engine pays on
its pump cadence.  The result is one machine-readable JSON document
(``measure(...)`` → ``CostModel.to_doc``) that the search consumes as its
cost objective and the committed reports quote verbatim — the same numbers
``benchmarks/campaign_bench.py`` prints for its kernel-scale table
(``policy_overhead`` section of ``BENCH_campaign.json``) at campaign
shapes.

``CostModel.predict(space, genome)`` combines the measurements
analytically into an estimated cost per decode step (serving) or per
forward (shipdet):

    serving:  Σ_site (ms[site][gene] − ms[site][none])   # mapped decode-step Δ
              + storage-verify ms ÷ cadence(weights gene)
              + state-scrub ms by derived mode (detect: one checksum per
                pump; rollback: checksum + snapshot bookkeeping, ≈ 2×)
    shipdet:  Σ_layer ms[layer][gene]

Costs are CPU wall-clock — relative ordering is the signal (the same
caveat every bench in this repo carries); the certified end-to-end ratio
comes from ``benchmarks/serving_bench --policy-map``, not from this model.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dependability import Policy


def _time_jit(f, *args, reps: int = 20) -> float:
    """Median-free best-effort ms/op: compile, then time ``reps`` calls."""
    out = f(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps * 1e3


def _serving_site_shapes(cfg, batch: int):
    """(M, K, N) per FFN matmul site at decode-batch geometry."""
    return {"ffn.wg": (batch, cfg.d_model, cfg.d_ff),
            "ffn.wi": (batch, cfg.d_model, cfg.d_ff),
            "ffn.wd": (batch, cfg.d_ff, cfg.d_model)}


def measure_serving(cfg, *, batch: int = 8, reps: int = 30,
                    backend: Optional[str] = None, seed: int = 0,
                    n_steps: int = 4, rounds: int = 4) -> dict:
    """ms per decode step for every (FFN site × policy) plus the engine
    scrub costs, at the given config's geometry.

    FFN site costs are measured on the *real decode window* — the engine's
    jitted ``multi_step``-deep ``lax.scan`` over argmax decode steps with a
    single-site PolicyMap baked into the config — not on an isolated
    matmul: inside the scanned decode graph the policies price differently
    than standalone (in-graph CKPT's re-execution branch costs ~nothing on
    an isolated op but a few percent per step here), and the isolated-op
    deltas drown in timer noise.  All variants are timed in *interleaved
    rounds* (round-robin, per-variant min) so CPU frequency drift over the
    measurement run cancels out of the deltas.  The stored per-site numbers
    are whole-step ms; the predictor uses the delta over the unmapped
    step."""
    from repro.core import abft as abft_mod
    from repro.core.policy_map import PolicyMap, PolicyRule
    from repro.models import api as model_api
    from repro.runtime.dataflow import _decode_window_fn
    rng = np.random.default_rng(seed)
    cfg = model_api.with_backend(cfg, backend)
    params = model_api.init_params(cfg, jax.random.key(seed))
    max_len = 96
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch,)),
                         jnp.int32)
    rem = jnp.full((batch,), 64, jnp.int32)
    pos = jnp.full((batch,), 8, jnp.int32)
    act = jnp.ones((batch,), bool)

    def window_for(policy_map):
        mcfg = model_api.with_policy_map(cfg, policy_map)

        def _step(p, tok, cache):
            logits, cache = model_api.decode_step(mcfg, p, tok, cache)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        win = _decode_window_fn(jax.jit(_step), n_steps, eos_id=-1,
                                max_len=max_len)
        cache = model_api.init_cache(mcfg, batch, max_len)
        args = (params, tokens, cache, rem, pos, act)
        out = win(*args)    # compile + warm
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
        return win, args

    variants: Dict[tuple, tuple] = {("__base__", "none"): window_for(None)}
    for site in _serving_site_shapes(cfg, batch):
        for pol in Policy:
            if pol is Policy.NONE:
                continue
            pm = PolicyMap(rules=(PolicyRule(site, pol),),
                           default=Policy.NONE)
            variants[(site, pol.value)] = window_for(pm)

    best: Dict[tuple, float] = {k: float("inf") for k in variants}
    for _ in range(rounds):
        for key, (win, args) in variants.items():
            t0 = time.perf_counter()
            for _ in range(reps):
                out = win(*args)
            jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
            ms = (time.perf_counter() - t0) / reps / n_steps * 1e3
            best[key] = min(best[key], ms)

    base_ms = best[("__base__", "none")]
    sites: Dict[str, dict] = {}
    for site, (m, k, n) in _serving_site_shapes(cfg, batch).items():
        per_policy = {"none": round(base_ms, 5)}
        for pol in Policy:
            if pol is Policy.NONE:
                continue
            per_policy[pol.value] = round(best[(site, pol.value)], 5)
        sites[site] = {"shape_mkn": [m, k, n], "ms": per_policy}

    # engine scrub costs on the real parameter pytree: one storage verify
    # (the weights-site scrub the engine pays per cadence tick) and one
    # storage checksum (the baseline/bless cost, paid per deploy)
    params = model_api.init_params(cfg, jax.random.key(seed))
    checks = jax.jit(abft_mod.storage_checksums)(params)
    verify = jax.jit(abft_mod.verify_storage)
    scrub = {
        "storage_verify_ms": round(
            _time_jit(lambda: verify(params, checks), reps=reps), 5),
        "storage_checksum_ms": round(
            _time_jit(jax.jit(abft_mod.storage_checksums), params,
                      reps=reps), 5),
    }
    return {"arch": cfg.name, "batch": batch, "n_layers": cfg.n_layers,
            "sites": sites, "scrub": scrub}


def measure_shipdet(*, reps: int = 10, backend: Optional[str] = None,
                    seed: int = 0, reduced: bool = True) -> dict:
    """ms per call for every (conv layer × policy) of the ship detector."""
    from repro.core.dependability import dependable_qconv2d
    from repro.models import shipdet
    from repro.core import quant
    specs = shipdet.reduced_specs() if reduced else shipdet.network_specs()
    params = shipdet.init_params(specs, jax.random.key(seed))
    rng = np.random.default_rng(seed)
    layers: Dict[str, dict] = {}
    for s, p in zip(specs, params):
        x_q = jnp.asarray(rng.integers(-127, 128, (1, s.h, s.w, s.cin)),
                          jnp.int8)
        bias_i32 = jnp.round(
            p["qconv"].bias_f / (p["in_scale"] * p["qconv"].w_scale)
        ).astype(jnp.int32)
        rq = quant.requant_scale(p["in_scale"], p["qconv"].w_scale,
                                 p["out_scale"])
        per_policy = {}
        for pol in Policy:
            f = jax.jit(lambda x, w, p_=pol, zp=p["in_zp"], b=bias_i32,
                        r=rq, oz=p["out_zp"], st=(s.stride, s.stride),
                        be=backend:
                        dependable_qconv2d(p_, x, zp, w, b, r, oz,
                                           stride=st, padding="SAME",
                                           backend=be)[0])
            per_policy[pol.value] = round(
                _time_jit(f, x_q, p["qconv"].w_q, reps=reps), 5)
        layers[s.name] = {"macs": s.macs, "ms": per_policy}
    return {"reduced": reduced, "layers": layers}


def measure(*, arch: str = "smollm-135m", batch: int = 8, reps: int = 30,
            backend: Optional[str] = None, seed: int = 0,
            spaces=("serving", "shipdet")) -> "CostModel":
    """The full oracle: measure every space's site table; returns the
    CostModel (call ``.save(path)`` for the JSON artifact)."""
    import dataclasses as _dc
    from repro.configs import registry
    from repro.models.config import reduced as reduced_cfg
    doc: dict = {"meta": {"arch": arch, "batch": batch, "reps": reps,
                          "backend": backend or "jnp", "seed": seed}}
    if "serving" in spaces:
        cfg = _dc.replace(reduced_cfg(registry.get(arch)), quant="w8a8_ffn")
        doc["serving"] = measure_serving(cfg, batch=batch, reps=reps,
                                         backend=backend, seed=seed)
    if "shipdet" in spaces:
        doc["shipdet"] = measure_shipdet(reps=max(reps // 3, 3),
                                         backend=backend, seed=seed)
    return CostModel(doc)


@dataclasses.dataclass
class CostModel:
    """Measured (site × policy) → ms table + analytic genome predictor."""

    doc: dict

    # cadence assumptions mirrored from Engine(policy_map=...) defaults:
    # ABFT storage scrub runs every pump, CKPT amortizes over the snapshot
    # cadence (snapshot_every defaults near this in the serving cases)
    CKPT_SCRUB_CADENCE = 8

    def to_doc(self) -> dict:
        return self.doc

    def save(self, path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.doc, indent=2, sort_keys=True) + "\n")
        return p

    @classmethod
    def load(cls, path) -> "CostModel":
        return cls(json.loads(pathlib.Path(path).read_text()))

    def predict_serving(self, genes: Dict[str, str]) -> float:
        """Estimated dependability cost per decode step, ms.  FFN site
        entries are whole-decode-step measurements (see
        ``measure_serving``); what a gene *costs* is its delta over the
        unmapped step."""
        sv = self.doc["serving"]
        total = 0.0
        for site, entry in sv["sites"].items():
            ms = entry["ms"]
            total += max(ms[genes.get(site, "none")] - ms["none"], 0.0)
        storage = genes.get("weights", "none")
        if storage == "abft":
            total += sv["scrub"]["storage_verify_ms"]
        elif storage == "ckpt":
            total += sv["scrub"]["storage_verify_ms"] / self.CKPT_SCRUB_CADENCE
        # transient-state scrub: the engine derives ONE mode from the
        # kv_cache/decode_state genes (PolicyMap.scrub_mode — the stronger
        # ask wins), so the charge is per-mode, not per-site:
        #   detect (any abft/dmr)   — one state checksum per pump
        #   rollback (any ckpt/tmr) — checksum + snapshot bookkeeping per
        #       pump, measured end-to-end at roughly twice the detect cost
        #       (serving_bench --policy-map; a rollback-mode map gives back
        #       everything the amortized storage scrub saved)
        transient = {genes.get("kv_cache", "none"),
                     genes.get("decode_state", "none")}
        if transient & {"ckpt", "tmr"}:
            total += (sv["scrub"]["storage_verify_ms"]
                      + sv["scrub"]["storage_checksum_ms"])
        elif transient & {"abft", "dmr"}:
            total += sv["scrub"]["storage_verify_ms"]
        return total

    def predict_shipdet(self, genes: Dict[str, str]) -> float:
        """Estimated forward cost, ms (full network, mapped policies)."""
        layers = self.doc["shipdet"]["layers"]
        return sum(entry["ms"][genes.get(name, "none")]
                   for name, entry in layers.items())

    def predict(self, space_name: str, genes: Dict[str, str]) -> float:
        if space_name == "serving":
            return self.predict_serving(genes)
        if space_name == "shipdet":
            return self.predict_shipdet(genes)
        raise KeyError(f"no cost table for space {space_name!r}")
