"""Selective-hardening design-space exploration (docs/dse.md).

Per-layer policy maps (``repro.core.policy_map``) define the design
space; this package searches it: a measured cost oracle (``cost``),
campaign-backed fitness with exact per-site memoization (``fitness``),
an NSGA-lite Pareto loop (``search``), and the committed artifacts
(``report``, ``cli``) — the paper's "SDC = 0 at minimum overhead"
criterion made an executable decision rule.
"""
from repro.dse.space import SERVING_SPACE, SearchSpace, get_space
from repro.dse.cost import CostModel, measure
from repro.dse.fitness import Evaluator, MapServingCase, MapShipdetCase
from repro.dse.search import (
    Candidate, SearchResult, dominates, non_dominated_sort, pick_best,
    search)

__all__ = [
    "SERVING_SPACE", "SearchSpace", "get_space",
    "CostModel", "measure",
    "Evaluator", "MapServingCase", "MapShipdetCase",
    "Candidate", "SearchResult", "dominates", "non_dominated_sort",
    "pick_best", "search",
]
