"""Genome encoding for the selective-hardening design-space exploration.

A genome is a tuple of policy names, one gene per *site* of a
:class:`SearchSpace`, in declared site order.  ``to_policy_map`` renders it
as the :class:`~repro.core.policy_map.PolicyMap` the rest of the system
executes; ``from_policy_map``/``digest``/``to_doc`` give the search loop a
canonical, journal-stable identity per design point.

Two spaces ship:

``serving``
    The streaming engine (W8A8 FFN transformer).  Genes: the three dense
    FFN matmul sites (``ffn.wg``/``ffn.wi``/``ffn.wd`` — uniform across the
    scanned layer stack, see core/policy_map.py) and the three engine state
    sites (``weights``, ``kv_cache``, ``decode_state``).  Two policies are
    pruned from the FFN genes rather than left for the search to
    rediscover as degenerate every run:

    * **DMR** — inside a ``lax.scan`` its detect-only alarm has no surface
      to escape through, so it buys 2× cost for zero usable coverage;
    * **TMR** — XLA CSE collapses the clean replicas of an in-graph NMR op
      into one computation (the measured cost oracle shows TMR ≈ NONE on
      this backend), so the *compiled serving graph* carries no actual
      redundancy: certifying "SDC = 0 with TMR" from injection campaigns
      — whose ``inject`` hook forces the replicas apart — would claim
      coverage the deployed binary does not have.  Replicated serving
      belongs at fleet level (physically separate replicas, fleet/).

    TMR is likewise excluded from the state genes (the engine's state
    machinery implements scrub/rollback, not replicated serving).

``shipdet``
    The paper's ship-detection CNN: one gene per conv layer (true
    per-layer granularity — the Python layer loop), all five policies
    available in-op per layer.
"""
from __future__ import annotations

import dataclasses
import json
import random
import zlib
from typing import Dict, Optional, Tuple

from repro.core.dependability import Policy
from repro.core.policy_map import PolicyMap, PolicyRule

Genome = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Named, ordered (site → allowed policies) table."""

    name: str
    sites: Tuple[Tuple[str, Tuple[str, ...]], ...]
    # which campaign injection sites the fitness oracle strikes, and how the
    # struck site maps onto the genome (identity for engine state sites)
    campaign_sites: Tuple[str, ...]

    @property
    def site_names(self) -> Tuple[str, ...]:
        return tuple(s for s, _ in self.sites)

    def size(self) -> int:
        n = 1
        for _, choices in self.sites:
            n *= len(choices)
        return n

    # -- genome constructors ----------------------------------------------

    def uniform_genome(self, policy) -> Genome:
        """Every site gets ``policy`` where allowed, else the strongest
        available fallback (ordering by the site's choice list)."""
        name = policy.value if isinstance(policy, Policy) else str(policy)
        genes = []
        for _, choices in self.sites:
            genes.append(name if name in choices else choices[-1])
        return tuple(genes)

    def random_genome(self, rng: random.Random) -> Genome:
        return tuple(rng.choice(choices) for _, choices in self.sites)

    def validate(self, genome: Genome) -> Genome:
        if len(genome) != len(self.sites):
            raise ValueError(f"genome length {len(genome)} != "
                             f"{len(self.sites)} sites of {self.name!r}")
        for gene, (site, choices) in zip(genome, self.sites):
            if gene not in choices:
                raise ValueError(f"{gene!r} not allowed at {site!r} "
                                 f"(choices: {choices})")
        return tuple(genome)

    # -- genetic operators (plain ``random.Random`` — deterministic) -------

    def mutate(self, genome: Genome, rng: random.Random,
               rate: float) -> Genome:
        genes = list(genome)
        for i, (_, choices) in enumerate(self.sites):
            if rng.random() < rate:
                genes[i] = rng.choice(choices)
        return tuple(genes)

    def crossover(self, a: Genome, b: Genome, rng: random.Random) -> Genome:
        return tuple(ga if rng.random() < 0.5 else gb
                     for ga, gb in zip(a, b))

    # -- rendition ---------------------------------------------------------

    def to_policy_map(self, genome: Genome) -> PolicyMap:
        self.validate(genome)
        rules = tuple(PolicyRule(site, Policy(gene))
                      for gene, (site, _) in zip(genome, self.sites))
        return PolicyMap(rules=rules, default=Policy.NONE)

    def from_policy_map(self, pm: PolicyMap) -> Genome:
        return self.validate(tuple(pm.policy_for(site)
                                   .value for site in self.site_names))

    def genes(self, genome: Genome) -> Dict[str, str]:
        return dict(zip(self.site_names, genome))

    def to_doc(self, genome: Genome) -> dict:
        return {"space": self.name, "genes": self.genes(genome)}

    def from_doc(self, doc: dict) -> Genome:
        genes = doc["genes"]
        return self.validate(tuple(genes[s] for s in self.site_names))

    def digest(self, genome: Genome) -> str:
        """Short stable identity of a design point — keys the in-memory
        fitness cache and the search journal records."""
        blob = json.dumps(self.to_doc(genome), sort_keys=True)
        return f"{zlib.crc32(blob.encode()):08x}"


_FFN_CHOICES = ("none", "abft", "ckpt")     # no DMR/TMR: see module doc
_STATE_CHOICES = ("none", "abft", "ckpt")

SERVING_SPACE = SearchSpace(
    name="serving",
    sites=(
        ("ffn.wg", _FFN_CHOICES),
        ("ffn.wi", _FFN_CHOICES),
        ("ffn.wd", _FFN_CHOICES),
        ("weights", _STATE_CHOICES),
        ("kv_cache", _STATE_CHOICES),
        ("decode_state", _STATE_CHOICES),
    ),
    campaign_sites=("weights", "kv_cache", "decode_state"),
)


def _shipdet_space() -> SearchSpace:
    from repro.models import shipdet
    choices = ("none", "abft", "dmr", "tmr", "ckpt")
    return SearchSpace(
        name="shipdet",
        sites=tuple((s.name, choices) for s in shipdet.network_specs()),
        campaign_sites=("accumulator", "weights"),
    )


_SPACES: Dict[str, Optional[SearchSpace]] = {"serving": SERVING_SPACE,
                                             "shipdet": None}


def get_space(name: str) -> SearchSpace:
    if name not in _SPACES:
        raise KeyError(f"unknown search space {name!r}; "
                       f"known: {sorted(_SPACES)}")
    if _SPACES[name] is None:       # lazy: shipdet imports the model module
        _SPACES[name] = _shipdet_space()
    return _SPACES[name]
