"""Selective-hardening DSE CLI: measure → search → certify.

    # 1. microbenchmark every (site × policy) into the cost oracle
    PYTHONPATH=src python -m repro.dse.cli measure --out reports/dse

    # 2. Pareto-search policy-map genomes (campaign-backed fitness,
    #    resumable via the journal under <out>/journal)
    PYTHONPATH=src python -m repro.dse.cli search --space serving \
        --generations 6 --population 12 --trials 60 --ci-halfwidth 0.08 \
        --out reports/dse

    # 3. re-certify the selected map at full budget and write BENCH_dse.json
    PYTHONPATH=src python -m repro.dse.cli certify --trials 150 \
        --out reports/dse --bench-out BENCH_dse.json

``certify``'s exit code is the gate CI relies on: 0 only when the map's
certification campaigns observe SDC = 0 **and** its predicted cost is
below the uniform-ABFT corner — the "minimum overhead at SDC = 0" claim,
checked, not asserted.  The end-to-end throughput ratio comes from
``benchmarks/serving_bench --policy-map reports/dse/best_map.json``; pass
its summary via ``--serving-bench`` to fold the measured speedup into
BENCH_dse.json.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _add_common(p):
    p.add_argument("--out", default="reports/dse",
                   help="artifact directory (cost model, frontier, journal)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--backend", default="jnp")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.dse.cli",
        description="Selective hardening DSE: per-layer policy maps, "
                    "measured cost oracle, Pareto search (docs/dse.md)")
    sub = p.add_subparsers(dest="cmd", required=True)

    m = sub.add_parser("measure", help="microbenchmark the cost oracle")
    _add_common(m)
    m.add_argument("--arch", default="smollm-135m")
    m.add_argument("--batch", type=int, default=8,
                   help="decode batch for the FFN site shapes")
    m.add_argument("--reps", type=int, default=30)
    m.add_argument("--spaces", default="serving,shipdet",
                   help="comma list of spaces to measure")

    s = sub.add_parser("search", help="NSGA-lite Pareto search")
    _add_common(s)
    s.add_argument("--space", default="serving",
                   choices=("serving", "shipdet"))
    s.add_argument("--arch", default="smollm-135m")
    s.add_argument("--generations", type=int, default=8)
    s.add_argument("--population", type=int, default=16)
    s.add_argument("--mutation-rate", type=float, default=0.25)
    s.add_argument("--trials", type=int, default=60,
                   help="per-site campaign trial cap during search")
    s.add_argument("--ci-halfwidth", type=float, default=0.08,
                   help="adaptive early-stop CI half-width for search "
                        "campaigns (0 = fixed budget)")
    s.add_argument("--fault-model", default="single_bitflip")
    s.add_argument("--cost-model", default=None,
                   help="cost oracle JSON (default <out>/cost_model.json; "
                        "measured on the fly if absent)")
    s.add_argument("--no-journal", action="store_true",
                   help="skip the crash-consistent campaign journal")

    c = sub.add_parser("certify", help="re-certify a map at full budget")
    _add_common(c)
    c.add_argument("--space", default="serving",
                   choices=("serving", "shipdet"))
    c.add_argument("--arch", default="smollm-135m")
    c.add_argument("--map", default=None,
                   help="PolicyMap JSON to certify "
                        "(default <out>/best_map.json)")
    c.add_argument("--trials", type=int, default=150)
    c.add_argument("--ci-halfwidth", type=float, default=0.0,
                   help="0 = fixed budget (tightest committed CI)")
    c.add_argument("--fault-model", default="single_bitflip")
    c.add_argument("--cost-model", default=None)
    c.add_argument("--serving-bench", default=None,
                   help="BENCH_serving.json from `serving_bench "
                        "--policy-map` — folds the measured end-to-end "
                        "speedup into BENCH_dse.json")
    c.add_argument("--bench-out", default=None,
                   help="write the BENCH_dse.json summary here")
    c.add_argument("--allow-sdc", action="store_true",
                   help="exit 0 even if certification observes SDC > 0")
    return p


def _cost_model(args, out: pathlib.Path, log):
    from repro.dse.cost import CostModel, measure
    path = pathlib.Path(args.cost_model) if args.cost_model \
        else out / "cost_model.json"
    if path.exists():
        return CostModel.load(path), path
    log(f"cost model {path} absent - measuring (reduced reps) ...")
    cm = measure(arch=args.arch, reps=10, backend=args.backend,
                 seed=args.seed, spaces=(args.space,))
    cm.save(path)
    return cm, path


def cmd_measure(args, log) -> int:
    from repro.dse.cost import measure
    spaces = tuple(s.strip() for s in args.spaces.split(",") if s.strip())
    cm = measure(arch=args.arch, batch=args.batch, reps=args.reps,
                 backend=args.backend, seed=args.seed, spaces=spaces)
    path = cm.save(pathlib.Path(args.out) / "cost_model.json")
    log(f"wrote {path}")
    for space in spaces:
        for uniform in ("none", "abft", "tmr", "ckpt"):
            try:
                from repro.dse.space import get_space
                sp = get_space(space)
                genes = sp.genes(sp.uniform_genome(uniform))
                log(f"  {space}: uniform {uniform:5s} -> "
                    f"{cm.predict(space, genes):.4f} ms")
            except KeyError:
                pass
    return 0


def cmd_search(args, log) -> int:
    from repro.campaign.journal import CampaignJournal
    from repro.campaign.stats import SamplingPlan
    from repro.dse import report as report_mod
    from repro.dse.fitness import Evaluator
    from repro.dse.search import search
    from repro.dse.space import get_space
    out = pathlib.Path(args.out)
    space = get_space(args.space)
    cm, cm_path = _cost_model(args, out, log)
    journal = None if args.no_journal else CampaignJournal(out / "journal")
    plan = SamplingPlan(ci_halfwidth=args.ci_halfwidth,
                        chunk=max(args.trials // 3, 10),
                        min_trials=min(20, args.trials))
    ev = Evaluator(space, cm, seed=args.seed, backend=args.backend,
                   arch=args.arch, fault_model=args.fault_model,
                   trials=args.trials, plan=plan, journal=journal, log=log)
    log(f"searching {args.space} space ({space.size()} designs) ...")
    result = search(space, ev, generations=args.generations,
                    population=args.population, seed=args.seed,
                    mutation_rate=args.mutation_rate, log=log)
    meta = {"seed": args.seed, "arch": args.arch, "backend": args.backend,
            "fault_model": args.fault_model, "trials": args.trials,
            "ci_halfwidth": args.ci_halfwidth,
            "population": args.population,
            "cost_model": str(cm_path),
            "campaigns_run": ev.campaigns_run}
    report_mod.write_pareto(out, space, result, meta=meta)
    log(f"wrote {out / 'pareto.json'}, {out / 'pareto.md'}"
        + ("" if result.best is None else f", {out / 'best_map.json'}"))
    if result.best is None:
        log("search produced no candidates")
        return 1
    b = result.best.fitness
    log(f"best: {b.genes}  sdc_max={b.sdc_max:g} "
        f"cost={b.cost_ms:.4f}ms det={b.detection_ticks:.2f} ticks")
    return 0


def cmd_certify(args, log) -> int:
    from repro.campaign.stats import SamplingPlan
    from repro.core.policy_map import as_policy_map
    from repro.dse import report as report_mod
    from repro.dse.fitness import Evaluator
    from repro.dse.space import get_space
    out = pathlib.Path(args.out)
    space = get_space(args.space)
    map_path = pathlib.Path(args.map) if args.map else out / "best_map.json"
    pm = as_policy_map(str(map_path))
    genome = space.from_policy_map(pm)
    cm, _ = _cost_model(args, out, log)
    genes = space.genes(genome)
    cost_ms = cm.predict(args.space, genes)
    uniform_abft = cm.predict(
        args.space, space.genes(space.uniform_genome("abft")))
    plan = SamplingPlan(ci_halfwidth=args.ci_halfwidth,
                        chunk=max(args.trials // 3, 10))
    ev = Evaluator(space, cm, seed=args.seed, backend=args.backend,
                   arch=args.arch, fault_model=args.fault_model,
                   trials=args.trials, plan=plan, journal=None, log=log)
    log(f"certifying {map_path} on the {args.space} space "
        f"({args.trials} trials/site, mapped engine) ...")
    rows = ev.certify(genome, trials=args.trials, plan=plan)

    pareto_doc = None
    ppath = out / "pareto.json"
    if ppath.exists():
        pareto_doc = json.loads(ppath.read_text())
    serving = None
    if args.serving_bench:
        sb = json.loads(pathlib.Path(args.serving_bench).read_text())
        pm_sec = sb.get("policy_map") or {}
        runs = pm_sec.get("runs", {})
        serving = {
            "source": args.serving_bench,
            "policy_map_speedup": sb.get("policy_map_speedup"),
            "bit_identical": pm_sec.get("bit_identical"),
            "mapped_tokens_per_s": runs.get("mapped", {})
            .get("tokens_per_s"),
            "uniform_abft_tokens_per_s": runs.get("uniform_abft", {})
            .get("tokens_per_s"),
        }
    doc = report_mod.bench_doc(
        space_name=args.space, map_doc=pm.to_doc(), certify_rows=rows,
        cost={"best_ms": round(cost_ms, 5),
              "uniform_abft_ms": round(uniform_abft, 5),
              "vs_uniform_abft": round(cost_ms / uniform_abft, 4)
              if uniform_abft else None},
        pareto_doc=pareto_doc, serving=serving)
    sdc = doc["certify"]["sdc_max"]
    log(f"certified: sdc_max={sdc} over {doc['certify']['trials']} trials, "
        f"cost {cost_ms:.4f} ms vs uniform-abft {uniform_abft:.4f} ms "
        f"({doc['cost']['vs_uniform_abft']}x)")
    if args.bench_out:
        bpath = pathlib.Path(args.bench_out)
        bpath.parent.mkdir(parents=True, exist_ok=True)
        bpath.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        log(f"wrote {bpath}")

    ok = True
    if sdc > 0 and not args.allow_sdc:
        print(f"certification FAILED: observed SDC rate {sdc:g} > 0",
              file=sys.stderr)
        ok = False
    if uniform_abft and cost_ms >= uniform_abft and not space.genes(
            genome) == space.genes(space.uniform_genome("abft")):
        print(f"certification FAILED: map costs {cost_ms:.4f} ms, not "
              f"below uniform ABFT ({uniform_abft:.4f} ms)",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    log = lambda s: print(s, flush=True)                  # noqa: E731
    if args.cmd == "measure":
        return cmd_measure(args, log)
    if args.cmd == "search":
        return cmd_search(args, log)
    return cmd_certify(args, log)


if __name__ == "__main__":
    raise SystemExit(main())
