"""NSGA-lite Pareto search over policy-map genomes.

A deliberately small, dependency-free genetic loop in the DAVOS
``Evolutionary_DSE`` shape: non-dominated sorting + crowding-distance
ranking (NSGA-II's selection pressure), binary tournaments, uniform
crossover, per-gene mutation — all driven by one ``random.Random(seed)``
so a search replays bit-for-bit.  Every genome ever evaluated lands in an
archive; the reported frontier is the archive's first non-dominated front
(so nothing good is lost to generational drift), and the *decision* —
``pick_best`` — is the paper's criterion stated directly: the cheapest
design whose campaign evidence is consistent with SDC = 0.

The evaluator memoizes per-(site, policy) campaigns (see fitness.py), so
generations after the first are nearly free for the serving space: the
search explores the combinatorial space while the campaign budget stays
bounded by the number of distinct site policies.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.dse.fitness import Fitness


@dataclasses.dataclass
class Candidate:
    genome: tuple
    digest: str
    fitness: Fitness

    @property
    def objectives(self) -> Tuple[float, ...]:
        return self.fitness.objectives

    def to_doc(self) -> dict:
        return {"digest": self.digest, **self.fitness.to_doc()}


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a Pareto-dominates b (all objectives minimized)."""
    return all(x <= y for x, y in zip(a, b)) and \
        any(x < y for x, y in zip(a, b))


def non_dominated_sort(cands: Sequence[Candidate]) -> List[List[int]]:
    """Indices grouped into fronts, best first (NSGA-II fast sort)."""
    n = len(cands)
    dominated_by: List[List[int]] = [[] for _ in range(n)]
    dom_count = [0] * n
    for i in range(n):
        for j in range(i + 1, n):
            if dominates(cands[i].objectives, cands[j].objectives):
                dominated_by[i].append(j)
                dom_count[j] += 1
            elif dominates(cands[j].objectives, cands[i].objectives):
                dominated_by[j].append(i)
                dom_count[i] += 1
    fronts: List[List[int]] = []
    current = [i for i in range(n) if dom_count[i] == 0]
    while current:
        fronts.append(current)
        nxt = []
        for i in current:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        current = nxt
    return fronts


def crowding_distance(cands: Sequence[Candidate],
                      front: Sequence[int]) -> Dict[int, float]:
    """Per-index crowding distance within one front (bigger = lonelier)."""
    dist = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: float("inf") for i in front}
    n_obj = len(cands[front[0]].objectives)
    for m in range(n_obj):
        order = sorted(front, key=lambda i: cands[i].objectives[m])
        lo = cands[order[0]].objectives[m]
        hi = cands[order[-1]].objectives[m]
        dist[order[0]] = dist[order[-1]] = float("inf")
        if hi <= lo:
            continue
        for k in range(1, len(order) - 1):
            gap = (cands[order[k + 1]].objectives[m]
                   - cands[order[k - 1]].objectives[m])
            dist[order[k]] += gap / (hi - lo)
    return dist


def _rank(cands: Sequence[Candidate]) -> Dict[int, Tuple[int, float]]:
    """index -> (front number, -crowding) — lexicographic NSGA-II rank."""
    ranks: Dict[int, Tuple[int, float]] = {}
    for f_no, front in enumerate(non_dominated_sort(cands)):
        dist = crowding_distance(cands, front)
        for i in front:
            ranks[i] = (f_no, -dist[i])
    return ranks


@dataclasses.dataclass
class SearchResult:
    archive: List[Candidate]          # every distinct genome evaluated
    front: List[Candidate]            # archive's first non-dominated front
    best: Optional[Candidate]         # pick_best over the archive
    generations: int
    evaluations: int                  # distinct genomes evaluated
    history: List[dict]               # per-generation progress rows

    def to_doc(self) -> dict:
        return {
            "generations": self.generations,
            "evaluations": self.evaluations,
            "history": self.history,
            "front": [c.to_doc() for c in self.front],
            "best": self.best.to_doc() if self.best else None,
            "archive_size": len(self.archive),
        }


def pick_best(cands: Sequence[Candidate],
              sdc_budget: float = 0.0) -> Optional[Candidate]:
    """The certified decision rule: cheapest candidate whose observed SDC
    rate is within budget (0 by default — every injected fault masked,
    detected, or healed).  Cost ties break toward *structural coverage*
    (fewest unprotected sites): at search trial budgets an unprotected
    site with every flip masked is statistically indistinguishable from a
    protected one, but only the protected design survives the 150-trial
    certification gate reliably — prefer detects-everything over
    not-caught-yet whenever it costs nothing.  Remaining ties break by
    detection latency then digest.  Falls back to the lowest-SDC
    candidate when nothing is feasible."""
    if not cands:
        return None
    feasible = [c for c in cands if c.fitness.sdc_max <= sdc_budget]
    if feasible:
        return min(feasible, key=lambda c: (c.fitness.cost_ms,
                                            c.fitness.uncovered,
                                            c.fitness.detection_ticks,
                                            c.digest))
    return min(cands, key=lambda c: (c.fitness.sdc_max, c.fitness.cost_ms,
                                     c.digest))


def search(space, evaluator, *, generations: int = 8, population: int = 16,
           seed: int = 0, mutation_rate: float = 0.25,
           log=lambda s: None) -> SearchResult:
    """Run the genetic loop; deterministic in (space, evaluator, args)."""
    rng = random.Random(seed)
    archive: Dict[str, Candidate] = {}

    def admit(genome) -> Candidate:
        digest = space.digest(genome)
        if digest not in archive:
            archive[digest] = Candidate(genome=tuple(genome), digest=digest,
                                        fitness=evaluator.evaluate(genome))
        return archive[digest]

    # seed population: the uniform corner maps (the designs selective
    # hardening must beat) plus random fill
    pop: List[Candidate] = []
    for uniform in ("none", "abft", "ckpt"):
        pop.append(admit(space.uniform_genome(uniform)))
    while len(pop) < population:
        pop.append(admit(space.random_genome(rng)))

    history: List[dict] = []
    for gen in range(generations):
        ranks = _rank(pop)

        def tournament() -> Candidate:
            i, j = rng.randrange(len(pop)), rng.randrange(len(pop))
            return pop[i] if ranks[i] <= ranks[j] else pop[j]

        children = []
        while len(children) < population:
            child = space.crossover(tournament().genome,
                                    tournament().genome, rng)
            child = space.mutate(child, rng, mutation_rate)
            children.append(admit(child))

        merged = list({c.digest: c for c in pop + children}.values())
        m_ranks = _rank(merged)
        order = sorted(range(len(merged)), key=lambda i: m_ranks[i])
        pop = [merged[i] for i in order[:population]]

        front0 = [pop[i] for i in non_dominated_sort(pop)[0]]
        best = pick_best(list(archive.values()))
        history.append({
            "generation": gen,
            "evaluated": len(archive),
            "front_size": len(front0),
            "best_cost_ms": best.fitness.cost_ms,
            "best_sdc_max": best.fitness.sdc_max,
        })
        log(f"gen {gen}: archive={len(archive)} front={len(front0)} "
            f"best_cost={best.fitness.cost_ms:.4f}ms "
            f"best_sdc={best.fitness.sdc_max:.3f}")

    # memetic polish: coordinate descent on the incumbent best.  Fitness
    # memoization makes every probe a cache hit on the campaign side, so
    # this closes the last-gene gaps a small-population genetic loop tends
    # to leave (e.g. one FFN site stuck on a costlier-but-safe policy)
    # without any extra injection budget.
    incumbent = pick_best(list(archive.values()))
    improved = incumbent is not None
    while improved:
        improved = False
        for idx, (_, choices) in enumerate(space.sites):
            for choice in choices:
                if choice == incumbent.genome[idx]:
                    continue
                probe = admit(incumbent.genome[:idx] + (choice,)
                              + incumbent.genome[idx + 1:])
                if pick_best([incumbent, probe]) is probe:
                    incumbent, improved = probe, True
    if incumbent is not None:
        log(f"polish: best_cost={incumbent.fitness.cost_ms:.4f}ms "
            f"best_sdc={incumbent.fitness.sdc_max:.3f} "
            f"archive={len(archive)}")

    all_c = list(archive.values())
    front_idx = non_dominated_sort(all_c)[0] if all_c else []
    front = sorted((all_c[i] for i in front_idx),
                   key=lambda c: c.objectives)
    return SearchResult(archive=all_c, front=front, best=pick_best(all_c),
                        generations=generations, evaluations=len(all_c),
                        history=history)
