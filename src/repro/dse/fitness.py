"""Fitness oracle for the selective-hardening DSE.

A design point (genome → :class:`~repro.core.policy_map.PolicyMap`) is
scored on three minimized objectives:

  1. **SDC upper bound** — the worst per-site ``sdc_ci_hi`` from adaptive
     fault-injection campaigns (the same engine, stopping rule, and journal
     as ``repro.campaign``): nothing in the frontier is a modeled number.
  2. **Cost** — the measured cost oracle's prediction for the genome
     (``repro.dse.cost.CostModel``), built from per-site microbenchmarks.
  3. **Detection latency** — mean detection ticks across the covered
     sites' reconstructed event timelines (how long a fault lives before
     an alarm), the recovery axis the paper's checkpoint spacing trades.

The serving space exploits a structural decomposition: campaign outcomes
at one injection site depend only on (site, that site's effective policy)
— never on the other genes.  In-graph FFN policies are bit-identical on
clean data (exact integer math), so they cannot change what a *state*
strike does to the token stream; and the engine's scrub machinery never
looks at FFN genes.  The evaluator therefore memoizes one campaign per
(site, policy) pair — the whole genetic search touches at most
``Σ_site |choices(site)|`` campaigns (≤ 21 for the serving space) no
matter how many genomes it visits, and the journal makes even those
resumable across runs.  FFN genes are scored by the kernel-level
accumulator campaign (``qmatmul`` workload) at the policy the gene names:
the compute-path coverage axis the serving campaign's state sites do not
strike.

The shipdet space has true per-layer structure (a strike lands in one
layer; the map decides that layer's fate), so it is evaluated per genome
(memoized by digest) through :class:`MapShipdetCase` — per-trial random
strike layers, the mapped forward, deploy-time checks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.campaign import engine as engine_mod
from repro.campaign import faultload as fl
from repro.campaign import stats as stats_mod
from repro.campaign.report import ConfigResult
from repro.campaign.runner import (
    ServingCase, ShipdetCase, _bitwise_mismatch, _finalize_config, build_case)
from repro.core.dependability import Policy
from repro.core.policy_map import PolicyMap

FFN_SITES = ("ffn.wg", "ffn.wi", "ffn.wd")


class MapServingCase(ServingCase):
    """ServingCase on the W8A8 FFN path with an optional baked-in policy
    map — the engine the DSE certifies its best map on.  With
    ``policy_map=None`` the engine runs the same quantized forward with no
    in-graph policies: bit-identical to any mapped engine on clean data,
    which is what makes the evaluator's per-(site, policy) memoization
    exact rather than approximate."""

    name = "serving_map"

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 arch: str = "smollm-135m", policy_map: PolicyMap = None):
        self._pm = policy_map
        super().__init__(key, backend, arch)

    def _customize_cfg(self, cfg):
        cfg = dataclasses.replace(cfg, quant="w8a8_ffn")
        if self._pm is not None:
            from repro.models import api as model_api
            cfg = model_api.with_policy_map(cfg, self._pm)
        return cfg


class MapShipdetCase(ShipdetCase):
    """ShipdetCase driven by a per-layer policy map instead of one uniform
    policy.  The ``policy`` argument of ``run_trials`` is ignored (specs
    carry ``Policy.NONE`` as a placeholder); coverage comes from what the
    map assigns to the layer each trial happens to strike:

    ``accumulator``
        the strike layer is drawn per trial from the trial key (uniform
        over layers), and the int32 accumulator of exactly that layer is
        faulted — so a genome's detection rate is the fault-weighted mix
        of its layers' in-op policies.
    ``weights``
        host pytree surgery over the per-layer ``w_q`` leaves (uniform
        over weight *elements*, so big layers absorb proportionally more
        strikes); ABFT layers detect against the deploy-time checksums,
        CKPT layers roll back to the shipped golden weights, DMR/TMR
        layers replicate *compute*, not storage — a weight-memory SEU
        corrupts every replica identically and sails through (the map
        search discovers this, rather than being told).
    """

    name = "shipdet_map"

    def __init__(self, key: jax.Array, backend: str = "jnp",
                 policy_map: PolicyMap = None):
        super().__init__(key, backend)
        self.policy_map = policy_map or PolicyMap.uniform(Policy.NONE)

    def _fwd(self, params, x, inject=None, layer=None):
        out, st = self._shipdet.forward(
            self.specs, params, x, policy_map=self.policy_map,
            inject=inject, inject_layer=layer, backend=self.backend,
            w_checks=self.w_checks, golden_wq=self.golden_wq)
        return out, st["faults_detected"] > 0

    def run_trials(self, policy, site, fault, keys):
        detected_l, mismatch_l = [], []
        if site == "weights":
            run = jax.jit(lambda p, x: self._fwd(p, x))
            golden, _ = run(self.params, self.x)
            for k in keys:
                wq = fl.inject_pytree_with(
                    self._wq_pytree(self.params), k, fault)
                out, det = run(self._with_wq(wq), self.x)
                detected_l.append(bool(det))
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        elif site == "accumulator":
            golden, _ = jax.jit(lambda: self._fwd(self.params, self.x))()
            n_layers = len(self.specs)
            jitted: Dict[int, object] = {}
            for k in keys:
                layer = int(jax.random.randint(
                    jax.random.fold_in(k, 0x10ad), (), 0, n_layers))
                if layer not in jitted:
                    jitted[layer] = jax.jit(
                        lambda key, L=layer: self._fwd(
                            self.params, self.x,
                            inject=lambda acc: fault(acc, key), layer=L))
                out, det = jitted[layer](k)
                detected_l.append(bool(det))
                mismatch_l.append(bool(_bitwise_mismatch(out, golden)))
        else:
            raise ValueError(f"unsupported mapped shipdet site {site!r}")
        return np.asarray(detected_l), np.asarray(mismatch_l)


@dataclasses.dataclass
class Fitness:
    """One genome's evaluated objectives + the evidence behind them."""

    genes: Dict[str, str]
    objectives: Tuple[float, float, float]   # (sdc_ci_hi, cost_ms, det_ticks)
    sdc_max: float                            # worst observed per-site rate
    cost_ms: float
    detection_ticks: float
    trials: int
    site_rows: Dict[str, dict]               # site -> ConfigResult doc
    # sites left at "none": structural coverage gap.  A lucky small-trial
    # campaign makes an unprotected site *statistically* indistinguishable
    # from a protected one (0 SDC observed at both); the tie-break in
    # pick_best prefers the design that detects every injected fault over
    # the one that merely hasn't been caught yet.
    uncovered: int = 0

    def to_doc(self) -> dict:
        return {"genes": self.genes, "objectives": list(self.objectives),
                "sdc_max": self.sdc_max, "cost_ms": self.cost_ms,
                "detection_ticks": self.detection_ticks,
                "trials": self.trials, "uncovered": self.uncovered,
                "site_rows": self.site_rows}


class Evaluator:
    """Campaign-backed fitness with per-(site, policy) memoization.

    Every campaign row is produced by ``engine_mod.run_config`` under the
    given :class:`~repro.campaign.stats.SamplingPlan` (early-stopped CIs)
    and, when a journal is given, is crash-consistent and reusable across
    search runs — re-running the same search resumes every row from disk.
    """

    def __init__(self, space, cost_model, *, seed: int = 0,
                 backend: str = "jnp", arch: str = "smollm-135m",
                 fault_model: str = "single_bitflip", trials: int = 60,
                 plan: Optional[stats_mod.SamplingPlan] = None,
                 journal=None, log=lambda s: None):
        self.space = space
        self.cost_model = cost_model
        self.seed = seed
        self.backend = backend
        self.arch = arch
        self.fault_model = fault_model
        self.trials = trials
        self.plan = plan or stats_mod.SamplingPlan(
            ci_halfwidth=0.08, chunk=20, min_trials=20)
        self.journal = journal
        self.log = log
        self._rows: Dict[Tuple[str, str, str], ConfigResult] = {}
        self._cases: Dict[str, object] = {}
        self._genomes: Dict[str, Fitness] = {}
        self.campaigns_run = 0

    # -- campaign plumbing -------------------------------------------------

    def _run(self, spec: fl.CampaignSpec, case) -> ConfigResult:
        acc = engine_mod.run_config(spec, self.plan, self.plan.chunk,
                                    case=case, journal=self.journal)
        self.campaigns_run += 1
        res = _finalize_config(spec, type(case), acc, self.plan, None)
        self.log(f"  campaign {spec.label()}: sdc={res.sdc_rate:.3f} "
                 f"(ci_hi={res.sdc_ci_hi:.3f}) det={res.detection_rate:.3f} "
                 f"n={res.trials}")
        return res

    def _serving_row(self, site: str, gene: str) -> ConfigResult:
        key = ("serving_map", site, gene)
        if key not in self._rows:
            case = self._cases.get("serving_map")
            if case is None:
                case = MapServingCase(jax.random.key(self.seed),
                                      self.backend, self.arch)
                self._cases["serving_map"] = case
            spec = fl.CampaignSpec("serving_map", Policy(gene), site,
                                   self.fault_model, self.trials,
                                   self.seed, self.backend)
            self._rows[key] = self._run(spec, case)
        return self._rows[key]

    def _kernel_row(self, gene: str) -> ConfigResult:
        key = ("qmatmul", "accumulator", gene)
        if key not in self._rows:
            case = self._cases.get("qmatmul")
            if case is None:
                case = build_case("qmatmul", self.seed, self.backend)
                self._cases["qmatmul"] = case
            spec = fl.CampaignSpec("qmatmul", Policy(gene), "accumulator",
                                   self.fault_model, self.trials,
                                   self.seed, self.backend)
            self._rows[key] = self._run(spec, case)
        return self._rows[key]

    def _shipdet_rows(self, genome) -> Dict[str, ConfigResult]:
        digest = self.space.digest(genome)
        rows = {}
        for site in self.space.campaign_sites:
            key = (f"shipdet_map:{digest}", site, "map")
            if key not in self._rows:
                case_key = f"shipdet_map:{digest}"
                case = self._cases.get(case_key)
                if case is None:
                    case = MapShipdetCase(
                        jax.random.key(self.seed), self.backend,
                        policy_map=self.space.to_policy_map(genome))
                    # one live mapped case at a time (compiled per genome)
                    self._cases = {k: v for k, v in self._cases.items()
                                   if not k.startswith("shipdet_map:")}
                    self._cases[case_key] = case
                # the digest rides in the workload field so the journal
                # (and the trial key stream) key on the *map*, not just
                # the (site, placeholder-policy) pair
                spec = fl.CampaignSpec(f"shipdet_map:{digest}", Policy.NONE,
                                       site, self.fault_model, self.trials,
                                       self.seed, self.backend)
                self._rows[key] = self._run(spec, case)
            rows[site] = self._rows[key]
        return rows

    # -- public API --------------------------------------------------------

    def evaluate(self, genome) -> Fitness:
        digest = self.space.digest(genome)
        if digest in self._genomes:
            return self._genomes[digest]
        genes = self.space.genes(genome)
        rows: Dict[str, ConfigResult] = {}
        if self.space.name == "serving":
            for site in self.space.campaign_sites:
                rows[site] = self._serving_row(site, genes[site])
            for site in FFN_SITES:
                rows[site] = self._kernel_row(genes[site])
        elif self.space.name == "shipdet":
            rows = self._shipdet_rows(genome)
        else:
            raise KeyError(f"no fitness oracle for space "
                           f"{self.space.name!r}")

        sdc_max = max(r.sdc_rate for r in rows.values())
        sdc_hi = max(r.sdc_ci_hi for r in rows.values())
        cost_ms = float(self.cost_model.predict(self.space.name, genes))
        det = [r.detection_ticks_mean for r in rows.values()
               if r.detections_logged]
        det_ticks = float(np.mean(det)) if det else 0.0
        fit = Fitness(
            genes=genes,
            objectives=(round(sdc_hi, 6), round(cost_ms, 5),
                        round(det_ticks, 4)),
            sdc_max=sdc_max, cost_ms=cost_ms, detection_ticks=det_ticks,
            trials=sum(r.trials for r in rows.values()),
            site_rows={s: dataclasses.asdict(r) for s, r in rows.items()},
            uncovered=sum(1 for g in genes.values() if g == "none"))
        self._genomes[digest] = fit
        return fit

    def certify(self, genome, *, trials: int,
                plan: Optional[stats_mod.SamplingPlan] = None,
                ) -> Dict[str, dict]:
        """Re-evaluate a single map at certification budget — running the
        *actual mapped engine/network* (not the memoized decomposition), so
        the committed verdict exercises exactly what deployment executes."""
        plan = plan or stats_mod.SamplingPlan()
        pm = self.space.to_policy_map(genome)
        digest = self.space.digest(genome)
        rows: Dict[str, dict] = {}
        if self.space.name == "serving":
            case = MapServingCase(jax.random.key(self.seed), self.backend,
                                  self.arch, policy_map=pm)
            genes = self.space.genes(genome)
            for site in self.space.campaign_sites:
                spec = fl.CampaignSpec(f"certify_map:{digest}",
                                       Policy(genes[site]), site,
                                       self.fault_model, trials,
                                       self.seed, self.backend)
                acc = engine_mod.run_config(spec, plan, plan.chunk,
                                            case=case, journal=self.journal)
                res = _finalize_config(spec, type(case), acc, plan, None)
                self.log(f"  certify {spec.label()}: sdc={res.sdc_rate:.4f} "
                         f"(ci_hi={res.sdc_ci_hi:.4f}) n={res.trials}")
                rows[site] = dataclasses.asdict(res)
        else:
            case = MapShipdetCase(jax.random.key(self.seed), self.backend,
                                  policy_map=pm)
            for site in self.space.campaign_sites:
                spec = fl.CampaignSpec(f"certify_map:{digest}", Policy.NONE,
                                       site, self.fault_model, trials,
                                       self.seed, self.backend)
                acc = engine_mod.run_config(spec, plan, plan.chunk,
                                            case=case, journal=self.journal)
                res = _finalize_config(spec, type(case), acc, plan, None)
                self.log(f"  certify {spec.label()}: sdc={res.sdc_rate:.4f} "
                         f"(ci_hi={res.sdc_ci_hi:.4f}) n={res.trials}")
                rows[site] = dataclasses.asdict(res)
        return rows
