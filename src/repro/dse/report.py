"""Committed artifacts of a DSE run: frontier JSON + human-readable
markdown + the serving policy map + the BENCH_dse summary.

Everything here is a pure renderer over :class:`~repro.dse.search.
SearchResult` docs and campaign rows — no measurement happens in this
module, so the committed reports are exactly what the search saw.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional


def _dump(path: pathlib.Path, doc: dict) -> pathlib.Path:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path


def write_pareto(out_dir, space, result, *, meta: dict) -> dict:
    """Write pareto.json + pareto.md + best_map.json; returns the doc."""
    out = pathlib.Path(out_dir)
    doc = {"report": "dse_pareto", "space": space.name, "meta": meta,
           **result.to_doc()}
    _dump(out / "pareto.json", doc)
    if result.best is not None:
        pm = space.to_policy_map(result.best.genome)
        _dump(out / "best_map.json", pm.to_doc())
    (out / "pareto.md").write_text(render_markdown(space, result, meta))
    return doc


def render_markdown(space, result, meta: dict) -> str:
    lines = [
        f"# Selective-hardening Pareto frontier — `{space.name}` space",
        "",
        f"Search: {result.generations} generations, "
        f"{result.evaluations} distinct genomes evaluated "
        f"(space size {space.size()}), seed {meta.get('seed', '?')}, "
        f"fault model `{meta.get('fault_model', '?')}`.",
        "",
        "Objectives (all minimized): worst per-site SDC-rate CI upper "
        "bound, measured dependability cost (ms, cost-oracle prediction "
        "from per-site microbenchmarks), mean detection latency (ticks).",
        "",
        "## Non-dominated front",
        "",
        "| # | " + " | ".join(space.site_names)
        + " | sdc_ci_hi | cost_ms | det_ticks |",
        "|---" * (len(space.site_names) + 4) + "|",
    ]
    for i, c in enumerate(result.front):
        genes = [c.fitness.genes[s] for s in space.site_names]
        o = c.objectives
        lines.append(f"| {i} | " + " | ".join(genes)
                     + f" | {o[0]:.4f} | {o[1]:.4f} | {o[2]:.2f} |")
    best = result.best
    lines += ["", "## Selected design (pick_best)", ""]
    if best is None:
        lines.append("no candidate evaluated")
    else:
        lines += [
            f"- digest `{best.digest}`; observed SDC max "
            f"{best.fitness.sdc_max:g} over {best.fitness.trials} trials; "
            f"predicted cost {best.fitness.cost_ms:.4f} ms; detection "
            f"latency {best.fitness.detection_ticks:.2f} ticks",
            "- genes: " + ", ".join(
                f"`{s}={best.fitness.genes[s]}`" for s in space.site_names),
            "",
            "The decision rule is the paper's: cheapest design whose "
            "campaign evidence is consistent with SDC = 0.  The committed "
            "`best_map.json` is this genome rendered as a PolicyMap "
            "(`repro.fleet.cli --policy-map`, `Engine(policy_map=...)`).",
        ]
    lines.append("")
    return "\n".join(lines)


def bench_doc(*, space_name: str, map_doc: dict, certify_rows: dict,
              cost: dict, pareto_doc: Optional[dict] = None,
              serving: Optional[dict] = None) -> dict:
    """Assemble the BENCH_dse.json summary: search provenance, the best
    map's certification campaign rows, its predicted cost vs the uniform
    corners, and (when ``benchmarks/serving_bench --policy-map`` ran) the
    end-to-end mapped-vs-uniform-ABFT throughput ratio."""
    doc = {
        "bench": "dse",
        "space": space_name,
        "policy_map": map_doc,
        "cost": cost,
        "certify": {
            "rows": certify_rows,
            "sdc_max": max((r["sdc"] for r in certify_rows.values()),
                           default=0),
            "sdc_ci_hi_max": max((r["sdc_ci_hi"]
                                  for r in certify_rows.values()),
                                 default=0.0),
            "trials": sum(r["trials"] for r in certify_rows.values()),
        },
    }
    if pareto_doc is not None:
        doc["search"] = {
            "generations": pareto_doc.get("generations"),
            "evaluations": pareto_doc.get("evaluations"),
            "front_size": len(pareto_doc.get("front", [])),
            "meta": pareto_doc.get("meta", {}),
        }
    if serving is not None:
        doc["serving"] = serving
    return doc
