"""Streaming dataflow executor — the Klepsydra-style staged serving pipeline.

The paper's runtime gets its throughput from a *dataflow-oriented, lock-free
streaming* structure: compute is decomposed into stages connected by bounded
queues, and data moves through the stages continuously instead of being
batch-synchronized.  This module is that structure for the serving path:

    submit ─▶ [admit] ─▶ [prefill] ─▶ [decode] ─▶ [certify] ─▶ [release]
                 │           │            │            │            │
              admission   per-req      slotted     release      finished
              control     prefill      batch,      gate (hook)  stream
                          (unpadded    continuous
                          recurrent)   batching

  * Every arrow is a bounded single-producer/single-consumer ``Channel`` —
    the same queue primitive ``data/pipeline.prefetch`` streams host batches
    through (one shared implementation, two drivers).
  * The **decode** stage does continuous batching: requests join free slots
    of the fixed-capacity KV-cache/recurrent-state batch and leave it
    mid-flight, with no re-padding and no drain barrier (slot state is data,
    not structure, so the jitted step never recompiles).
  * The **certify** stage is the release gate.  Engines run it pass-through;
    a fleet installs its certify-before-release hook here, so withholding a
    finished request until its replica proves clean is a *pipeline stage*,
    not an inline call buried in a monolithic step loop.
  * SEU injection is per-stage: ``StreamingExecutor.strike`` routes a fault
    to the stage that owns the site (decode owns ``kv_cache`` and
    ``decode_state``, the parameter store owns ``weights``), which is how
    the campaign engine drills the pipeline.

Two drivers share the stage/queue primitives:

  * the **cooperative driver** (``StreamingExecutor.step``) pumps the stages
    in topological order on the caller's thread.  It takes no locks and its
    schedule is a pure function of the submission order, so decode streams
    — and therefore fleet failover replays — are bit-exact, the property
    every dependability campaign certifies.
  * the **threaded driver** (``ThreadedSource``) runs a producer stage on a
    daemon thread blocking on its outbox — the host-boundary streaming mode
    (data prefetch overlapping device compute).

Device-fault recovery (snapshot/rollback, decode-state scrubbing) lives at
the executor level because a consistent restore spans admit bookkeeping and
decode state together; see docs/streaming.md and docs/recovery.md.

Observability (``repro.obs``) threads through the pipeline as a pure
observer — all three hooks default off and cost nothing when absent:

  * ``tracer=``    a ``SpanTracer``: per-request spans for every stage
    residency (admit → prefill → decode → certify) plus release instants
    and per-pump queue-depth / slot-occupancy counter tracks, keyed on the
    executor's deterministic **tick clock** (one tick per cooperative pump
    cycle).  Exports Chrome ``trace_event`` JSON; byte-identical across
    same-seed runs.
  * ``event_log=`` an ``EventLog``: typed dependability events (strike /
    detection / rollback) with fault provenance, the substrate campaign
    reports reconstruct injection→detection→recovery timelines from.
  * ``metrics=``   a ``Registry``: streaming counters/gauges/histograms
    (released requests, release-latency ticks, queue depths) — bounded
    memory regardless of run length.

See docs/observability.md.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abft
from repro.core.dependability import DependabilityStats
from repro.models import api as model_api
from repro.models.config import ArchConfig

# decode-state checksums: the storage-scrub identity applied to the live
# KV cache / recurrent state + token buffer; jitted once per cache structure
_state_checksums = jax.jit(abft.storage_checksums)


@jax.jit
def _splice_slot(batch_cache, one_cache, tokens, slot, first_tok, n):
    """Join-time slot splice, fused into one compiled call: write the
    prefilled request's cache rows and first token into ``slot`` of the live
    batch.  Module-level jit so every executor (and every fleet replica)
    shares one compile cache entry per cache structure."""
    cache = model_api.cache_write_slot(batch_cache, one_cache, slot, n)
    return cache, tokens.at[slot].set(first_tok)


def _checks_equal(a, b) -> bool:
    """Host verdict: does every leaf checksum match?"""
    return all(bool(x) for x in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda p, q: p == q, a, b)))


# Multi-step decode windows: one jitted N-step scan per (decode fn, N,
# eos, max_len).  Keyed on the decode fn *object* (a strong reference is
# kept so ids cannot be recycled), which is how fleet replicas / benchmark
# reps sharing a ``compiled`` pair also share one window compilation.
_DECODE_WINDOW_CACHE: dict = {}


def _decode_window_fn(decode_fn, n_steps: int, eos_id: int, max_len: int):
    """Build (or fetch) the jitted N-step decode window.

    The scan carries (tokens, cache, remaining, pos, active-mask) on device
    and emits per-step (next-token, finished-mask) — join/EOS/max-len
    accounting is evaluated in device-side masks, so the host reads back
    once per window instead of once per step.  Every slot steps every
    inner step (slot rows are independent and a later join splices whole
    rows), which is exactly the per-step engine's behavior for slots that
    finished but have not been re-filled yet.
    """
    key = (id(decode_fn), n_steps, eos_id, max_len)
    hit = _DECODE_WINDOW_CACHE.get(key)
    if hit is not None:
        return hit[1]

    def _window(params, tokens, cache, remaining, pos, active):
        def body(carry, _):
            tokens, cache, remaining, pos, active = carry
            nxt, cache = decode_fn(params, tokens, cache)
            remaining = jnp.where(active, remaining - 1, remaining)
            pos = jnp.where(active, pos + 1, pos)
            finished = active & ((remaining <= 0) | (nxt == eos_id)
                                 | (pos >= max_len - 1))
            return ((nxt, cache, remaining, pos, active & ~finished),
                    (nxt, finished))
        carry, emitted = jax.lax.scan(
            body, (tokens, cache, remaining, pos, active),
            None, length=n_steps)
        return carry, emitted

    fn = jax.jit(_window)
    _DECODE_WINDOW_CACHE[key] = (decode_fn, fn)
    return fn


# ---------------------------------------------------------------------------
# Queue/stage primitives (shared with data/pipeline.prefetch)
# ---------------------------------------------------------------------------


class Closed(Exception):
    """Raised by blocking Channel ops once the channel is closed."""


class Channel:
    """Bounded single-producer/single-consumer queue between two stages.

    Two APIs over one deque:

      * cooperative — ``try_put``/``try_get`` never block and take no locks
        (single-thread pipeline pumping; deque ops are atomic under the
        interpreter), so the deterministic driver is lock-free on its hot
        path;
      * streaming — ``put``/``get`` block on capacity/emptiness and wake on
        ``close()`` (the threaded host-boundary driver).

    ``capacity=0`` means unbounded (terminal channels that are drained every
    pump cycle).
    """

    _EMPTY = object()

    def __init__(self, capacity: int = 0, name: str = ""):
        self.capacity = int(capacity)
        self.name = name
        self.items: deque = deque()
        self._closed = False
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)

    # ---------------------------------------------------------- cooperative
    def full(self) -> bool:
        return self.capacity > 0 and len(self.items) >= self.capacity

    def try_put(self, item) -> bool:
        if self.full():
            return False
        self.items.append(item)
        return True

    def try_get(self):
        """Next item or ``Channel.EMPTY`` — non-blocking."""
        if not self.items:
            return self._EMPTY
        return self.items.popleft()

    @classmethod
    def is_empty_token(cls, item) -> bool:
        return item is cls._EMPTY

    def drain(self) -> list:
        out = list(self.items)
        self.items.clear()
        return out

    def __len__(self) -> int:
        return len(self.items)

    def __iter__(self):
        return iter(self.items)

    # ------------------------------------------------------------ streaming
    def put(self, item):
        with self._not_full:
            while self.full() and not self._closed:
                self._not_full.wait()
            if self._closed:
                raise Closed(self.name)
            self.items.append(item)
            self._not_empty.notify()

    def get(self):
        with self._not_empty:
            while not self.items and not self._closed:
                self._not_empty.wait()
            if not self.items:
                raise Closed(self.name)
            item = self.items.popleft()
            self._not_full.notify()
            return item

    def close(self):
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()


class Stage:
    """One pipeline stage: pull from ``inbox``, push to ``outbox``.

    ``pump()`` moves as much work as channel capacity allows and returns
    whether any progress was made; drivers decide *when* to pump (the
    cooperative driver in topological order, a threaded driver in a loop).
    """

    name = "stage"

    def pump(self) -> bool:
        raise NotImplementedError


class SourceStage(Stage):
    """Producer stage: pushes ``produce(i)`` for i = start, start+1, … into
    its outbox — the generalization of the hand-rolled prefetch thread."""

    name = "source"

    def __init__(self, produce: Callable[[int], Any], outbox: Channel,
                 start: int = 0):
        self.produce = produce
        self.outbox = outbox
        self._i = start
        self._pending = Channel._EMPTY   # produced but not yet enqueued

    def pump(self) -> bool:
        moved = False
        while True:
            if Channel.is_empty_token(self._pending):
                self._pending = self.produce(self._i)
                self._i += 1
            if not self.outbox.try_put(self._pending):
                return moved
            self._pending = Channel._EMPTY
            moved = True

    def pump_blocking(self):
        """Streaming-driver variant: block on outbox space (raises Closed)."""
        if Channel.is_empty_token(self._pending):
            self._pending = self.produce(self._i)
            self._i += 1
        self.outbox.put(self._pending)
        self._pending = Channel._EMPTY


class ThreadedSource:
    """Drive a ``SourceStage`` on a daemon thread — the streaming driver for
    host-side stages (batch synthesis overlapping device compute).  The
    consumer reads the stage's outbox; ``close()`` unblocks the producer and
    joins the thread."""

    def __init__(self, stage: SourceStage):
        self.stage = stage
        self._thread = threading.Thread(
            target=self._run, name=f"stage-{stage.name}", daemon=True)

    def start(self) -> "ThreadedSource":
        self._thread.start()
        return self

    def _run(self):
        try:
            while True:
                self.stage.pump_blocking()
        except Closed:
            pass

    def close(self):
        self.stage.outbox.close()
        self._thread.join(timeout=5.0)


# ---------------------------------------------------------------------------
# Pipeline payloads
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    # filled by the pipeline
    output: Optional[List[int]] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0
    # deterministic tick-clock counterparts of the wall timestamps (filled
    # only when the executor has observability attached; -1 = not stamped)
    submitted_tick: int = -1
    finished_tick: int = -1

    # ------------------------------------------------- transport (wire form)
    def to_doc(self) -> dict:
        """JSON-safe wire form for the fleet's process-isolation transport.
        Token ids are coerced to plain ints (device readbacks may be numpy
        scalars) so the frame header serializes with the stdlib encoder."""
        return {
            "uid": int(self.uid),
            "prompt": [int(t) for t in self.prompt],
            "max_new_tokens": int(self.max_new_tokens),
            "output": (None if self.output is None
                       else [int(t) for t in self.output]),
            "submitted_at": float(self.submitted_at),
            "finished_at": float(self.finished_at),
            "submitted_tick": int(self.submitted_tick),
            "finished_tick": int(self.finished_tick),
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "Request":
        return cls(
            uid=int(doc["uid"]),
            prompt=[int(t) for t in doc["prompt"]],
            max_new_tokens=int(doc.get("max_new_tokens", 16)),
            output=(None if doc.get("output") is None
                    else [int(t) for t in doc["output"]]),
            submitted_at=float(doc.get("submitted_at", 0.0)),
            finished_at=float(doc.get("finished_at", 0.0)),
            submitted_tick=int(doc.get("submitted_tick", -1)),
            finished_tick=int(doc.get("finished_tick", -1)),
        )

    def sync_from_doc(self, doc: dict) -> "Request":
        """Fold a wire copy's pipeline-filled fields back into this (the
        canonical, parent-side) object — the certify upcall path."""
        self.output = (None if doc.get("output") is None
                       else [int(t) for t in doc["output"]])
        self.finished_at = float(doc.get("finished_at", 0.0))
        self.finished_tick = int(doc.get("finished_tick", -1))
        return self


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    replays: int = 0
    faults_detected: int = 0

    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)


@dataclasses.dataclass
class _Prefilled:
    """A request that cleared the prefill stage: its single-request cache,
    first sampled token, and true (unpadded) prompt length."""
    req: Request
    cache: Any
    first_token: int
    prompt_len: int


# ---------------------------------------------------------------------------
# Stages of the serving pipeline
# ---------------------------------------------------------------------------


class AdmitStage(Stage):
    """Submission queue → prefill inbox, gated on slot reservations.

    A request is admitted only when the decode batch will have a free slot
    for it once prefilled: reservable = free slots − requests already in
    flight through the prefill stage.  FIFO order is preserved — admission
    order is what makes replay deterministic.

    ``drain_barrier=True`` degrades admission to pad-and-step static
    batching: a new group is admitted only once the decode batch has fully
    drained, so a freed slot idles until the group's longest request
    finishes.  This is the monolith-equivalent scheduling baseline the
    serving benchmark prices continuous batching against — never what a
    production engine should run."""

    name = "admit"

    def __init__(self, inbox: Channel, outbox: Channel,
                 prefill: "PrefillStage", decode: "DecodeStage",
                 drain_barrier: bool = False):
        self.inbox = inbox
        self.outbox = outbox
        self.prefill = prefill
        self.decode = decode
        self.drain_barrier = drain_barrier

    def reservable(self) -> int:
        if self.drain_barrier and self.decode.active:
            return 0                   # barrier: wait for a full drain
        in_prefill = len(self.outbox) + len(self.prefill.outbox)
        return self.decode.n_free() - in_prefill

    def pump(self) -> bool:
        moved = False
        tr = self.decode.ex.tracer
        while (self.inbox.items and self.reservable() > 0
               and not self.outbox.full()):
            req = self.inbox.items.popleft()
            self.outbox.try_put(req)
            if tr is not None:
                tr.close_span(req.uid, "admit")
                tr.open_span(req.uid, "prefill", prompt_len=len(req.prompt))
            moved = True
        return moved


class PrefillStage(Stage):
    """Per-request prefill: prompt → (single-request cache, first token).

    Attention caches mask past each row's length, so right-padding prompts
    to a bucket is free and bounds compile count; recurrent state integrates
    every token it sees, so state families prefill the exact prompt (one
    compile per distinct length instead of per bucket)."""

    name = "prefill"

    def __init__(self, ex: "StreamingExecutor", inbox: Channel,
                 outbox: Channel):
        self.ex = ex
        self.inbox = inbox
        self.outbox = outbox

    def _prefill_one(self, req: Request) -> _Prefilled:
        ex = self.ex
        # reserve cache rows for the token budget, but never truncate the
        # prompt to nothing: a budget >= max_len used to slice to an empty
        # prompt and crash the whole engine (losing every in-flight request);
        # generation is truncated at the cache edge by the decode-stage
        # max_len guard instead
        prompt = req.prompt[: max(1, ex.max_len - req.max_new_tokens)]
        if ex.cfg.recurrent is not None:
            pad = len(prompt)
        else:
            pad = -(-len(prompt) // ex.prefill_pad) * ex.prefill_pad
        toks = jnp.asarray([prompt + [0] * (pad - len(prompt))], jnp.int32)
        logits, cache1 = ex._prefill(ex.params, toks)
        nxt = int(jnp.argmax(logits[0, len(prompt) - 1]))
        return _Prefilled(req, cache1, nxt, len(prompt))

    def pump(self) -> bool:
        moved = False
        while not self.outbox.full():
            req = self.inbox.try_get()
            if Channel.is_empty_token(req):
                break
            self.outbox.try_put(self._prefill_one(req))
            moved = True
        return moved


class DecodeStage(Stage):
    """The continuous-batching core: owns the slotted decode batch.

    State is one fixed-capacity KV-cache/recurrent-state pytree plus the
    per-slot token buffer and bookkeeping vectors.  ``join()`` splices
    prefilled requests into free slot rows (``models/common.cache_write_slot``
    — no re-padding, no drain of in-flight slots); ``decode_once()`` steps
    the whole batch and emits finished requests downstream.  Each pump is
    join + at most one step, so requests enter and leave the batch while
    their neighbors keep decoding."""

    name = "decode"

    def __init__(self, ex: "StreamingExecutor", inbox: Channel,
                 outbox: Channel):
        self.ex = ex
        self.inbox = inbox
        self.outbox = outbox
        self.reset_state()

    def reset_state(self):
        ex = self.ex
        self.cache = model_api.init_cache(ex.cfg, ex.capacity, ex.max_len)
        self.tokens = jnp.zeros((ex.capacity,), jnp.int32)
        self.slot_pos = np.zeros(ex.capacity, np.int32)
        self.slot_remaining = np.zeros(ex.capacity, np.int32)
        self.active: dict = {}                    # slot -> Request
        # finished requests the (bounded) outbox refused: held here and
        # re-offered every pump — backpressure must never *drop* a request
        self._pending: deque = deque()

    def n_free(self) -> int:
        return self.ex.capacity - len(self.active)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.ex.capacity) if s not in self.active]

    def _emit(self, req: Request) -> None:
        """Hand a finished request downstream, FIFO: anything already held
        goes first, and a full outbox parks the request instead of losing
        it (the unchecked ``try_put`` drop bug)."""
        ex = self.ex
        req.finished_tick = ex.tick
        if ex.tracer is not None:
            ex.tracer.close_span(req.uid, "decode",
                                 tokens=len(req.output or ()))
            ex.tracer.open_span(req.uid, "certify")
        self._pending.append(req)
        self.flush_pending()

    def flush_pending(self) -> bool:
        moved = False
        while self._pending and self.outbox.try_put(self._pending[0]):
            self._pending.popleft()
            moved = True
        return moved

    def join(self) -> bool:
        """Splice prefilled requests into free slots (continuous batching).
        Requests whose prompt already produced their only token finish at
        admission and go straight downstream."""
        ex = self.ex
        moved = self.flush_pending()
        for slot in self.free_slots():
            item = self.inbox.try_get()
            if Channel.is_empty_token(item):
                break
            req, n = item.req, item.prompt_len
            ex._since_snapshot.append(req)
            if ex.tracer is not None:
                ex.tracer.close_span(req.uid, "prefill")
                ex.tracer.open_span(req.uid, "decode", slot=slot,
                                    prompt_len=n)
            self.cache, self.tokens = _splice_slot(
                self.cache, item.cache, self.tokens,
                jnp.int32(slot), jnp.int32(item.first_token), jnp.int32(n))
            self.slot_pos[slot] = n
            # the prefill itself produced the first new token
            self.slot_remaining[slot] = req.max_new_tokens - 1
            req.output = [item.first_token]
            self.active[slot] = req
            moved = True
            # finish at admission: budget exhausted by the prefill token, or
            # the prefill token itself is EOS (burning the whole budget on a
            # request that already terminated would waste its slot)
            if self.slot_remaining[slot] <= 0 or item.first_token == ex.eos_id:
                req.finished_at = time.time()
                del self.active[slot]
                self._emit(req)
        return moved

    def decode_once(self) -> bool:
        """One decode step for every active slot; finished requests are
        emitted to the certify stage."""
        ex = self.ex
        if not self.active:
            return False
        nxt, self.cache = ex._decode(ex.params, self.tokens, self.cache)
        self.tokens = nxt
        ex.stats.steps += 1
        nxt_host = np.asarray(nxt)
        done_slots = []
        for slot, req in list(self.active.items()):
            req.output.append(int(nxt_host[slot]))
            self.slot_pos[slot] += 1
            self.slot_remaining[slot] -= 1
            ex.stats.tokens_out += 1
            if (self.slot_remaining[slot] <= 0
                    or int(nxt_host[slot]) == ex.eos_id
                    or self.slot_pos[slot] >= ex.max_len - 1):
                req.finished_at = time.time()
                done_slots.append(slot)
        for slot in done_slots:
            self._emit(self.active.pop(slot))
        return True

    def decode_window(self) -> bool:
        """Multi-step dispatch: one jitted ``multi_step``-deep scan over the
        slot batch, then a single host readback of the per-step token /
        finished-mask trajectory.  Host bookkeeping replays the window from
        the device masks — token streams are bit-identical to per-step
        decoding because slots are independent and joins (which only happen
        between windows) splice whole slot rows."""
        ex = self.ex
        if not self.active:
            return False
        window = _decode_window_fn(ex._decode, ex.multi_step, ex.eos_id,
                                   ex.max_len)
        active_mask = np.zeros(ex.capacity, bool)
        active_mask[list(self.active)] = True
        (tokens, cache, _, _, _), (nxt_all, fin_all) = window(
            ex.params, self.tokens, self.cache,
            jnp.asarray(self.slot_remaining), jnp.asarray(self.slot_pos),
            jnp.asarray(active_mask))
        self.tokens, self.cache = tokens, cache
        nxt_host = np.asarray(nxt_all)            # (N, capacity)
        fin_host = np.asarray(fin_all)
        for i in range(ex.multi_step):
            if not self.active:
                break                  # trailing idle steps are not counted
            ex.stats.steps += 1
            done_slots = []
            for slot, req in list(self.active.items()):
                req.output.append(int(nxt_host[i, slot]))
                self.slot_pos[slot] += 1
                self.slot_remaining[slot] -= 1
                ex.stats.tokens_out += 1
                if fin_host[i, slot]:
                    req.finished_at = time.time()
                    done_slots.append(slot)
            for slot in done_slots:
                self._emit(self.active.pop(slot))
        return True

    def decode_any(self) -> bool:
        """Per-step or windowed decode, per the executor's ``multi_step``."""
        if self.ex.multi_step > 1:
            return self.decode_window()
        return self.decode_once()

    def pump(self) -> bool:
        joined = self.join()
        return self.decode_any() or joined


class CertifyStage(Stage):
    """The release gate.  ``hook(req) -> bool`` decides whether a finished
    request flows on to release (True) or is withheld — the hook's owner
    (e.g. a fleet running certify-before-release weight scrubs) takes
    custody of withheld requests and settles them out of band.  No hook
    means trivially certified (a bare engine trusts its own scrubs)."""

    name = "certify"

    def __init__(self, ex: "StreamingExecutor", inbox: Channel,
                 outbox: Channel):
        self.ex = ex
        self.inbox = inbox
        self.outbox = outbox
        # certified requests a full release channel refused — retried every
        # pump rather than silently dropped
        self._pending: deque = deque()

    def _forward(self, req: Request) -> None:
        if self._pending or not self.outbox.try_put(req):
            self._pending.append(req)

    def pump(self) -> bool:
        moved = False
        while self._pending and self.outbox.try_put(self._pending[0]):
            self._pending.popleft()
            moved = True
        tr = self.ex.tracer
        while True:
            req = self.inbox.try_get()
            if Channel.is_empty_token(req):
                return moved
            moved = True
            hook = self.ex.certify
            if hook is None or hook(req):
                if tr is not None:
                    tr.close_span(req.uid, "certify", certified=True)
                self._forward(req)
            elif tr is not None:
                # withheld: the hook's owner (fleet) takes custody and
                # settles out of band — close the span with the verdict
                # rather than leaving it dangling forever
                tr.close_span(req.uid, "certify", certified=False,
                              withheld=True)


class ReleaseStage(Stage):
    """Terminal stage: certified requests accumulate here until the caller
    collects them (``StreamingExecutor.step`` drains once per pump cycle)."""

    name = "release"

    def __init__(self, inbox: Channel):
        self.inbox = inbox

    def pump(self) -> bool:
        return False                               # terminal — nothing to move

    def collect(self) -> List[Request]:
        return self.inbox.drain()


# ---------------------------------------------------------------------------
# The executor: stages + cooperative driver + fault tolerance
# ---------------------------------------------------------------------------


class StreamingExecutor:
    """Staged streaming executor with a deterministic cooperative driver.

    One ``step()`` pumps every stage once in topological order — the
    synchronous-dataflow schedule.  Because stage order and channel order
    are fixed, the token streams are a pure function of submission order:
    the bit-exact-replay property fleets and campaigns certify.

    Fault tolerance spans the stages:

      * every ``snapshot_every`` steps the decode-stage state plus admission
        bookkeeping is snapshotted (checksummed, so a struck snapshot is
        refused at restore);
      * ``state_scrub`` runs the decode-state checksum scrub as a pipeline
        guard before the decode stage consumes its state ("detect" raises
        events for a supervisor, "rollback" restores the verified snapshot
        in place);
      * ``strike(site, fault, key)`` is the per-stage SEU injection surface
        for campaigns.
    """

    def __init__(self, cfg: ArchConfig, params, capacity: int = 8,
                 max_len: int = 512, prefill_pad: int = 64,
                 snapshot_every: int = 32, eos_id: int = -1,
                 compiled=None, state_scrub: str = "off",
                 storage_scrub: str = "off", storage_scrub_every: int = 1,
                 certify: Optional[Callable[[Request], bool]] = None,
                 drain_barrier: bool = False, multi_step: int = 1,
                 tracer=None, event_log=None, metrics=None):
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_pad = prefill_pad
        self.eos_id = eos_id
        self.snapshot_every = snapshot_every
        self.certify = certify
        if multi_step < 1:
            raise ValueError(f"multi_step must be >= 1, got {multi_step}")
        # N=1: per-step decode (host readback every step, joins between
        # every step).  N>1: jitted N-step windows with device-side finish
        # masks — same token streams, 1/N host syncs, joins at window edges.
        self.multi_step = multi_step
        self.stats = EngineStats()

        # observability — pure observers, all optional (see repro.obs).
        # tick is the deterministic pump-cycle clock spans/events key on;
        # it advances once per step() and never rolls back.
        self.tick = 0
        self.tracer = tracer
        self.event_log = event_log
        self.metrics = metrics
        if metrics is not None:
            self._m_submitted = metrics.counter(
                "engine_requests_submitted_total", "requests submitted")
            self._m_released = metrics.counter(
                "engine_requests_released_total",
                "requests that cleared the release stage")
            self._m_tokens = metrics.counter(
                "engine_tokens_out_total", "decoded tokens")
            self._m_steps = metrics.counter(
                "engine_decode_steps_total", "decode steps executed")
            self._m_latency = metrics.histogram(
                "engine_release_latency_ticks",
                "submit-to-release latency in pump ticks",
                buckets=tuple(float(2 ** i) for i in range(14)))
            self._m_qdepth = metrics.gauge(
                "engine_queue_depth", "requests queued before decode")
            self._m_slots = metrics.gauge(
                "engine_active_slots", "occupied decode slots")
            self._mm_steps = 0          # last stats.steps folded into counters
            self._mm_tokens = 0

        if compiled is not None:
            # replica fleets share one jitted (decode, prefill) pair so N
            # executors over the same config compile once, not N times
            self._decode, self._prefill = compiled
        else:
            def _step(p, t, c):
                logits, c = model_api.decode_step(cfg, p, t, c)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), c

            self._decode = jax.jit(_step)
            self._prefill = jax.jit(
                lambda p, t, c=None: model_api.prefill(cfg, p, t, max_len))

        # channels: submission is unbounded (admission control is a policy
        # above the engine); prefill channels are slot-bounded; certify/
        # release are drained every cycle
        self.submit_ch = Channel(0, "submit")
        self._admit_ch = Channel(capacity, "admitted")
        self._prefill_ch = Channel(capacity, "prefilled")
        self._certify_ch = Channel(0, "finished")
        self._release_ch = Channel(0, "certified")

        self.prefill = PrefillStage(self, self._admit_ch, self._prefill_ch)
        self.decode = DecodeStage(self, self._prefill_ch, self._certify_ch)
        self.admit = AdmitStage(self.submit_ch, self._admit_ch,
                                self.prefill, self.decode,
                                drain_barrier=drain_barrier)
        self.certifier = CertifyStage(self, self._certify_ch,
                                      self._release_ch)
        self.release = ReleaseStage(self._release_ch)
        self.stages: List[Stage] = [self.admit, self.prefill, self.decode,
                                    self.certifier, self.release]

        self._snapshot = None
        self._snapshot_step = 0
        self._since_snapshot: List[Request] = []   # admitted after snapshot
        self.dependability = DependabilityStats.zero()

        # decode-state scrubbing: "off" | "detect" | "rollback"
        if state_scrub not in ("off", "detect", "rollback"):
            raise ValueError(f"state_scrub must be off|detect|rollback, "
                             f"got {state_scrub!r}")
        self.state_scrub = state_scrub
        self._expected_check = None        # checksums after last mutation
        self.state_events: List[dict] = []  # drained by fleets / campaigns

        # in-serve weight-storage scrubbing: verify the live parameters
        # against construction-time storage checksums on a tick cadence.
        #   "off"       no storage scrub (a fleet/deploy layer may own it)
        #   "detect"    alarm-only — run at every-pump cadence so detection
        #               latency is bounded (the corrupted stream still
        #               ships; detect-only coverage is only as good as how
        #               fast it raises the alarm)
        #   "rollback"  restore the golden (construction-time) parameters —
        #               healing is retroactive, so the cadence can be
        #               amortized (``storage_scrub_every`` ticks per verify)
        # The baseline is blessed at construction and deliberately NOT
        # refreshed by ``reset(params=)`` — a reset handing over corrupted
        # params must still be caught.  Intentional weight swaps (rolling
        # deploys) call ``refresh_storage_baseline()``.
        if storage_scrub not in ("off", "detect", "rollback"):
            raise ValueError(f"storage_scrub must be off|detect|rollback, "
                             f"got {storage_scrub!r}")
        self.storage_scrub = storage_scrub
        self.storage_scrub_every = max(1, int(storage_scrub_every))
        self._storage_checks = None
        self._golden_params = None
        self._verify_storage = None
        self._storage_alarmed = False
        if storage_scrub != "off":
            self.refresh_storage_baseline()

    @property
    def compiled(self):
        """The jitted (decode, prefill) pair, shareable with same-config
        executors via the ``compiled=`` constructor argument."""
        return (self._decode, self._prefill)

    def reset(self, params=None):
        """Return run state (channels, slots, cache, per-run stats) to
        fresh, optionally with new (same-shaped) params.  Lifetime
        dependability counters survive resets — a campaign accumulates
        verdicts across many reset+run trials — and compiled functions are
        kept (params are traced arguments, so swapping them is free)."""
        if params is not None:
            self.params = params
        for ch in (self.submit_ch, self._admit_ch, self._prefill_ch,
                   self._certify_ch, self._release_ch):
            ch.items.clear()
        self.decode.reset_state()
        self.certifier._pending.clear()
        self.stats = EngineStats()
        if self.metrics is not None:
            self._mm_steps = 0
            self._mm_tokens = 0
        self._snapshot = None
        self._snapshot_step = 0
        self._since_snapshot = []
        self._expected_check = None
        self.state_events = []
        self._storage_alarmed = False

    # ------------------------------------------------------- dependability
    def _device_state(self) -> dict:
        """The device-resident decode-stage state the scrub covers (host-side
        slot bookkeeping lives in ECC'd host memory in the deployment this
        models, so it is outside the SEU threat surface)."""
        return {"cache": self.decode.cache, "tokens": self.decode.tokens}

    def _refresh_state_check(self):
        """Re-checksum after a legitimate mutation — the running 'expected'
        fingerprint every later scrub compares against."""
        if self.state_scrub != "off":
            self._expected_check = _state_checksums(self._device_state())

    def scrub_decode_state(self) -> bool:
        """Verify the live decode state against the post-mutation checksum;
        True == clean.  A mismatch means an SEU struck the KV cache /
        recurrent state or the token buffer *between* pump cycles — the
        transient site no weight scrub can see."""
        if self._expected_check is None:
            return True
        fresh = _state_checksums(self._device_state())
        clean = _checks_equal(fresh, self._expected_check)
        # emit_events=False: _scrub_and_recover emits the (site-attributed)
        # detection event itself — one detection, one event
        self.record_dependability({
            "faults_detected": jnp.int32(0 if clean else 1),
            "checks_run": jnp.int32(1)}, emit_events=False)
        return clean

    def _scrub_and_recover(self):
        """The pre-decode scrub guard: detect, and under ``rollback`` restore
        the last verified snapshot (checkpoint/restart at decode
        granularity).  Appends one event per detection so fleets/campaigns
        can account recoveries and measure recovery latency."""
        if self.scrub_decode_state():
            return
        event = {"step": self.stats.steps, "recovered": False,
                 "seconds": 0.0, "steps_replayed": 0}
        if self.tracer is not None:
            self.tracer.instant("scrub_detection", site="decode_state")
        if self.event_log is not None:
            self.event_log.emit("detection", tick=self.tick,
                                site="decode_state",
                                detail={"check": "state_scrub"})
        if self.state_scrub == "rollback" and self._snapshot is not None:
            t0 = time.perf_counter()
            try:
                event["steps_replayed"] = self.restore_snapshot()
                event["recovered"] = True
                event["seconds"] = time.perf_counter() - t0
                self.record_dependability({"faults_recovered": jnp.int32(1)})
                if self.tracer is not None:
                    self.tracer.instant(
                        "rollback", steps_replayed=event["steps_replayed"])
                if self.event_log is not None:
                    self.event_log.emit(
                        "rollback", tick=self.tick, site="decode_state",
                        seconds=event["seconds"],
                        detail={"steps_replayed": event["steps_replayed"]})
            except RuntimeError:
                # snapshot itself failed verification — leave recovered
                # False; the supervisor's drain+replay is the fallback
                pass
        if not event["recovered"]:
            # accept the corrupted fingerprint as the new baseline so one
            # strike raises one alarm, not one per remaining step
            self._refresh_state_check()
        self.state_events.append(event)

    def refresh_storage_baseline(self):
        """Bless the *current* parameters as the golden storage state:
        recompute the deploy-time checksums and retain the params as the
        rollback target.  Called at construction and by intentional weight
        swaps (rolling deploys); never implicitly by ``reset``."""
        from repro.core import abft as abft_mod
        if self._verify_storage is None:
            self._verify_storage = jax.jit(abft_mod.verify_storage)
            self._storage_checksums = jax.jit(abft_mod.storage_checksums)
        self._golden_params = self.params
        self._storage_checks = self._storage_checksums(self.params)
        self._storage_alarmed = False

    def scrub_storage(self) -> bool:
        """Verify live parameters against the golden storage checksums;
        True == clean.  Counts one check (and the detection, if any) into
        the dependability rollup."""
        if self._storage_checks is None:
            return True
        ok = self._verify_storage(self.params, self._storage_checks)
        clean = all(bool(x) for x in jax.tree_util.tree_leaves(ok))
        self.record_dependability({
            "faults_detected": jnp.int32(0 if clean else 1),
            "checks_run": jnp.int32(1)}, emit_events=False)
        return clean

    def _storage_scrub_and_recover(self):
        """The in-serve storage scrub: detect a weight-memory SEU against
        the golden checksums; under ``rollback`` restore the golden
        parameters in place (retroactively heals every read since the
        strike would have been re-issued from clean storage — decode state
        repairs ride the decode-state scrub/snapshot machinery)."""
        if self._storage_alarmed or self.scrub_storage():
            return
        event = {"step": self.stats.steps, "site": "weights",
                 "recovered": False, "seconds": 0.0, "steps_replayed": 0}
        if self.tracer is not None:
            self.tracer.instant("scrub_detection", site="weights")
        if self.event_log is not None:
            self.event_log.emit("detection", tick=self.tick, site="weights",
                                detail={"check": "storage_scrub"})
        if self.storage_scrub == "rollback":
            t0 = time.perf_counter()
            self.params = self._golden_params
            event["recovered"] = True
            event["seconds"] = time.perf_counter() - t0
            self.record_dependability({"faults_recovered": jnp.int32(1)})
            if self.tracer is not None:
                self.tracer.instant("rollback", site="weights")
            if self.event_log is not None:
                self.event_log.emit(
                    "rollback", tick=self.tick, site="weights",
                    seconds=event["seconds"],
                    detail={"action": "golden_restore"})
        else:
            # detect-only: one strike raises one alarm — the baseline stays
            # golden (storage semantics), so latch instead of re-blessing;
            # reset()/refresh_storage_baseline() clear the latch
            self._storage_alarmed = True
        self.state_events.append(event)

    def drain_state_events(self) -> List[dict]:
        ev, self.state_events = self.state_events, []
        return ev

    def record_dependability(self, stats: dict, emit_events: bool = True):
        """Fold a DependabilityStats pytree (from dependable ops or a
        campaign's detection verdicts) into the executor-lifetime counters.
        With an event log attached, positive detection counts from
        core/dependability checks also surface as ``detection`` events
        (``emit_events=False`` for callers that emit their own)."""
        self.dependability = DependabilityStats.merge(self.dependability, stats)
        if emit_events and self.event_log is not None \
                and isinstance(stats, dict):
            detected = int(stats.get("faults_detected", 0))
            if detected > 0:
                self.event_log.emit(
                    "detection", tick=self.tick,
                    detail={"check": "dependability", "count": detected})

    # ------------------------------------------------- per-stage injection
    def strike(self, site: str, fault, key) -> None:
        """Campaign hook: inject an SEU into the state the named stage owns.

        ``kv_cache`` / ``decode_state`` strike the decode stage's cache and
        token buffer; ``weights`` strikes the parameter store every stage
        reads.  Routing faults by stage (instead of reaching into a
        monolith) is what lets a campaign attribute coverage per stage.
        """
        from repro.core.fault_injection import inject_pytree_with
        if site == "kv_cache":
            self.decode.cache = inject_pytree_with(self.decode.cache, key,
                                                   fault)
        elif site == "decode_state":
            self.decode.tokens = fault(self.decode.tokens, key)
        elif site == "weights":
            self.params = inject_pytree_with(self.params, key, fault)
        else:
            raise ValueError(
                f"no stage owns fault site {site!r} "
                f"(known: kv_cache, decode_state, weights)")
        fault_name = getattr(fault, "name", getattr(fault, "__name__", ""))
        if self.tracer is not None:
            self.tracer.instant("strike", site=site, fault=fault_name)
        if self.event_log is not None:
            self.event_log.emit("strike", tick=self.tick, site=site,
                                fault=fault_name)

    # ------------------------------------------------------------- driving
    def submit(self, req: Request):
        req.submitted_at = time.time()
        req.submitted_tick = self.tick
        self.submit_ch.items.append(req)
        if self.tracer is not None:
            self.tracer.open_span(req.uid, "admit",
                                  prompt_len=len(req.prompt),
                                  max_new_tokens=req.max_new_tokens)
        if self.metrics is not None:
            self._m_submitted.inc()

    def cancel(self, uid: int) -> bool:
        """Evict a request from any stage it occupies (deadline/abort path).
        Slot cache rows go stale but are overwritten by the next join's
        prefill.  Also purged from snapshot bookkeeping so a later
        ``restore_snapshot`` cannot resurrect cancelled work.  Returns True
        if the request was found live in the pipeline."""
        if self.tracer is not None:
            for stage in ("admit", "prefill", "decode", "certify"):
                self.tracer.cancel_span(uid, stage)
        self._since_snapshot = [r for r in self._since_snapshot
                                if r.uid != uid]
        if self._snapshot is not None:
            for slot, r in list(self._snapshot["active"].items()):
                if r.uid == uid:
                    del self._snapshot["active"][slot]
                    del self._snapshot["outputs"][slot]
        for ch in (self.submit_ch, self._admit_ch):
            for i, r in enumerate(ch.items):
                if r.uid == uid:
                    del ch.items[i]
                    return True
        for i, item in enumerate(self._prefill_ch.items):
            if item.req.uid == uid:
                del self._prefill_ch.items[i]
                return True
        for slot, r in list(self.decode.active.items()):
            if r.uid == uid:
                del self.decode.active[slot]
                self.decode.slot_remaining[slot] = 0
                return True
        for held in (self.decode._pending, self.certifier._pending):
            for r in list(held):
                if r.uid == uid:
                    held.remove(r)
                    return True
        for ch in (self._certify_ch, self._release_ch):
            for i, r in enumerate(ch.items):
                if r.uid == uid:
                    del ch.items[i]
                    return True
        return False

    def step(self) -> List[Request]:
        """One cooperative pump cycle: admit → prefill → decode-join →
        snapshot cadence → decode step → certify → release.  Returns the
        requests that cleared the release stage this cycle (certify-hook
        holds excluded)."""
        self.tick += 1
        if self.tracer is not None:
            self.tracer.tick_to(self.tick)
        # scrub BEFORE this cycle consumes decode state (and before a join
        # mutates it): anything that changed since the last legitimate
        # mutation is an SEU, and under "rollback" we restart from the
        # last verified snapshot instead of decoding from corrupted state
        if self.state_scrub != "off" and self.decode.active:
            self._scrub_and_recover()
        # storage scrub on its own cadence, before any stage reads weights
        # this cycle: detect mode runs every pump (bounded detection
        # latency), rollback mode amortizes over storage_scrub_every ticks
        if self.storage_scrub != "off" \
                and self.tick % self.storage_scrub_every == 0:
            self._storage_scrub_and_recover()
        self.admit.pump()
        self.prefill.pump()
        self.decode.join()
        if self.decode.active:
            # cadence by steps-since-snapshot (≡ steps % snapshot_every for
            # per-step decode; windowed decode advances steps by up to N per
            # pump, which a bare modulo check would skip over)
            if (self._snapshot is None
                    or self.stats.steps - self._snapshot_step
                    >= self.snapshot_every):
                self._take_snapshot()
            self.decode.decode_any()
        self._refresh_state_check()
        # certify/release pump AFTER the decode state is settled: a certify
        # hook may re-enter the executor (fleet recalls, resets, replays)
        self.certifier.pump()
        self.release.pump()
        released = self.release.collect()
        if self.tracer is not None:
            for req in released:
                self.tracer.instant("release", stage="release", uid=req.uid,
                                    tokens=len(req.output or ()))
            self.tracer.counter(
                "queue_depth", submit=len(self.submit_ch),
                admitted=len(self._admit_ch),
                prefilled=len(self._prefill_ch),
                parked=len(self.decode._pending)
                + len(self.certifier._pending))
            self.tracer.counter("slots", active=len(self.decode.active),
                                capacity=self.capacity)
        if self.metrics is not None:
            self._m_released.inc(len(released))
            self._m_steps.inc(self.stats.steps - self._mm_steps)
            self._m_tokens.inc(self.stats.tokens_out - self._mm_tokens)
            self._mm_steps = self.stats.steps
            self._mm_tokens = self.stats.tokens_out
            self._m_qdepth.set(len(self.submit_ch) + len(self._admit_ch)
                               + len(self._prefill_ch))
            self._m_slots.set(len(self.decode.active))
            for req in released:
                if req.submitted_tick >= 0:
                    self._m_latency.observe(self.tick - req.submitted_tick)
        return released

    def busy(self) -> bool:
        """Work anywhere in the pipeline before the release stage?
        Includes requests parked behind a full downstream channel — they
        still need pump cycles to flush."""
        return bool(self.submit_ch.items or self._admit_ch.items
                    or self._prefill_ch.items or self.decode.active
                    or self.decode._pending or self.certifier._pending)

    def in_flight(self) -> List[Request]:
        """Every request the pipeline currently owns, in deterministic
        stage-then-slot order (failover drains replay in this order).
        Requests held behind a full channel come after the decode slots —
        they are finished, downstream of decode, not yet released."""
        return (list(self.submit_ch) + list(self._admit_ch)
                + [item.req for item in self._prefill_ch]
                + [self.decode.active[s] for s in sorted(self.decode.active)]
                + list(self.decode._pending) + list(self.certifier._pending))

    def pending_count(self) -> int:
        """How many requests the pipeline owns — O(1) (router cost metric;
        ``in_flight()`` materializes the list, this just counts it)."""
        return (len(self.submit_ch) + len(self._admit_ch)
                + len(self._prefill_ch) + len(self.decode.active)
                + len(self.decode._pending) + len(self.certifier._pending))

    def run(self, max_steps: int = 10_000) -> EngineStats:
        """Drain the pipeline."""
        while self.busy() and self.stats.steps < max_steps:
            self.step()
        return self.stats

    # ----------------------------------------------------- fault tolerance
    def _take_snapshot(self):
        d = self.decode
        self._snapshot = {
            "cache": d.cache,
            "tokens": d.tokens,
            "slot_pos": d.slot_pos.copy(),
            "slot_remaining": d.slot_remaining.copy(),
            "active": dict(d.active),
            "outputs": {s: list(r.output) for s, r in d.active.items()},
            "steps": self.stats.steps,
            "tokens_out": self.stats.tokens_out,
            # golden-snapshot integrity: checksummed at capture so a later
            # restore can refuse a snapshot that was itself struck
            "check": (_state_checksums(
                {"cache": d.cache, "tokens": d.tokens})
                if self.state_scrub != "off" else None),
        }
        self._snapshot_step = self.stats.steps
        self._since_snapshot = []

    def restore_snapshot(self) -> int:
        """Roll back to the last snapshot (device-fault recovery path).

        The snapshot round-trips the *whole* decode state: cache, token
        buffer, per-slot bookkeeping, active-set membership, request outputs
        and the step/token counters — so ``tokens_per_step()`` and token
        accounting stay exact across a replay, and requests that finished or
        were admitted after the snapshot are correctly re-decoded / requeued.
        ``replays`` and ``faults_detected`` are lifetime counters and are
        never rolled back.

        Returns the number of steps replayed (lost work bound =
        snapshot_every).
        """
        if self._snapshot is None:
            raise RuntimeError("no snapshot taken yet")
        snap = self._snapshot
        if snap["check"] is not None:
            fresh = _state_checksums(
                {"cache": snap["cache"], "tokens": snap["tokens"]})
            if not _checks_equal(fresh, snap["check"]):
                raise RuntimeError(
                    "snapshot failed checksum verification (SEU struck the "
                    "golden snapshot itself) — refusing to restore; escalate "
                    "to drain + failover")
        d = self.decode
        d.cache = snap["cache"]
        d.tokens = snap["tokens"]
        d.slot_pos = snap["slot_pos"].copy()
        d.slot_remaining = snap["slot_remaining"].copy()
        # active set as of the snapshot: resurrects requests that finished
        # after it (their post-snapshot tokens are suspect) and drops ones
        # admitted after it (requeued below; the cache rollback erased their
        # prefill rows)
        d.active = dict(snap["active"])
        # a request that finished after the snapshot may still be parked
        # behind a full channel; its resurrected copy re-decodes, so the
        # parked (suspect) copy must not also flush downstream
        resurrected = {r.uid for r in d.active.values()}
        d._pending = deque(r for r in d._pending
                           if r.uid not in resurrected)
        tr = self.tracer
        for s, req in d.active.items():
            req.output = list(snap["outputs"][s])
            req.finished_at = 0.0
            req.finished_tick = -1
            if tr is not None:
                # resurrected: back in decode; a suspect copy may have
                # already closed its decode span and opened certify —
                # re-open decode (restart) and drop the stale certify span
                tr.cancel_span(req.uid, "certify")
                tr.open_span(req.uid, "decode", slot=s, replayed=True)
        for req in reversed(self._since_snapshot):
            req.output = None
            req.finished_at = 0.0
            req.finished_tick = -1
            self.submit_ch.items.appendleft(req)
            if tr is not None:
                # requeued from scratch: whatever stage it reached is void
                for stage in ("prefill", "decode", "certify"):
                    tr.cancel_span(req.uid, stage)
                tr.open_span(req.uid, "admit", requeued=True)
        self._since_snapshot = []
        lost = self.stats.steps - snap["steps"]
        self.stats.steps = snap["steps"]
        self.stats.tokens_out = snap["tokens_out"]
        self.stats.replays += 1
        self._refresh_state_check()
        return lost
