"""Cluster orchestration — the RTG4 analogue at fleet scale.

In the paper, the RTG4 "acts as the main orchestrator for HPDP operations":
it dispatches work to the co-processor, watches execution, and decides where
outputs flow next.  At 1000-node scale the same role is a control plane that

  * tracks worker health via **heartbeats** (here: wall-clock step reports),
  * flags **stragglers** (step time > k × running median) and dispatches
    backup work (speculative re-execution — the classic MapReduce remedy),
  * drives **elastic restart**: when a worker is lost, choose the largest
    healthy mesh that the workload still fits, and hand the training driver
    a (new_mesh, restore_step) plan; checkpoint/restore does the rest.

The implementation is deliberately runnable single-process (simulated
workers driven by tests/examples) while keeping the exact decision logic a
real fleet controller needs — the policy is the contribution, the transport
(gRPC vs in-process calls) is not.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class WorkerState:
    uid: int
    last_heartbeat: float = 0.0
    last_step: int = -1
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True
    straggler: bool = False


@dataclasses.dataclass
class ElasticPlan:
    """What the training driver should do after a failure."""
    new_world_size: int
    new_mesh_shape: Tuple[int, ...]
    restore_step: int
    reason: str


class Orchestrator:
    def __init__(self, n_workers: int, heartbeat_timeout: float = 10.0,
                 straggler_factor: float = 3.0, min_history: int = 4):
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(uid=i) for i in range(n_workers)}
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.min_history = min_history
        self.events: List[str] = []

    # ------------------------------------------------------------ reporting
    def heartbeat(self, uid: int, step: int, step_time: float,
                  now: Optional[float] = None):
        w = self.workers[uid]
        w.last_heartbeat = now if now is not None else time.time()
        w.last_step = step
        w.step_times.append(step_time)
        if len(w.step_times) > 64:
            w.step_times = w.step_times[-64:]

    # ------------------------------------------------------------- policies
    def check_health(self, now: Optional[float] = None) -> List[int]:
        """Mark workers dead on heartbeat timeout; returns newly-dead uids."""
        now = now if now is not None else time.time()
        dead = []
        for w in self.workers.values():
            if w.alive and now - w.last_heartbeat > self.heartbeat_timeout:
                w.alive = False
                dead.append(w.uid)
                self.events.append(f"worker {w.uid} declared dead at {now:.1f}")
        return dead

    def detect_stragglers(self) -> List[int]:
        """Step time > factor × cluster median ⇒ straggler.

        The remedy at fleet scale is backup-task dispatch: the returned uids'
        current shards are re-queued on healthy spares; first finisher wins
        (determinism is preserved because both compute the same reduction).
        """
        times = [w.step_times[-1] for w in self.workers.values()
                 if w.alive and len(w.step_times) >= self.min_history]
        if len(times) < 2:
            return []
        med = statistics.median(times)
        out = []
        for w in self.workers.values():
            if not w.alive or len(w.step_times) < self.min_history:
                continue
            w.straggler = w.step_times[-1] > self.straggler_factor * med
            if w.straggler:
                out.append(w.uid)
                self.events.append(
                    f"worker {w.uid} straggling "
                    f"({w.step_times[-1]:.3f}s vs median {med:.3f}s)")
        return out

    def alive_count(self) -> int:
        return sum(w.alive for w in self.workers.values())

    # ---------------------------------------------------------- elasticity
    def elastic_plan(self, checkpointed_step: int,
                     model_axis: int = 16) -> ElasticPlan:
        """Largest (data × model_axis) mesh that fits the survivors.

        Keeps the model axis intact (TP degree is a property of the
        checkpointed layout; changing it is a reshard, which restore()
        supports but costs more) and shrinks the data axis to the largest
        power-of-two that fits.
        """
        alive = self.alive_count()
        data_axis = max(1, 2 ** int(math.log2(max(alive // model_axis, 1))))
        world = data_axis * model_axis
        return ElasticPlan(
            new_world_size=world,
            new_mesh_shape=(data_axis, model_axis),
            restore_step=checkpointed_step,
            reason=f"{alive}/{len(self.workers)} workers alive → "
                   f"mesh ({data_axis}, {model_axis})",
        )

    def progress(self) -> Dict[str, float]:
        steps = [w.last_step for w in self.workers.values() if w.alive]
        return {
            "min_step": min(steps) if steps else -1,
            "max_step": max(steps) if steps else -1,
            "alive": self.alive_count(),
        }
