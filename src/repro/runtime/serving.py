"""Batched serving engine — a thin facade over the streaming dataflow
executor (``runtime/dataflow.py``).

Historically this module held a 450-line monolithic ``Engine.step()`` that
admitted, prefilled, decoded, scrubbed and released in one blocking pass.
The paper's runtime is the opposite shape — a dataflow-oriented, lock-free
streaming pipeline (Klepsydra on the HPDP) — and the implementation now
matches: admit → prefill → decode → certify → release are explicit stages
connected by bounded SPSC channels, with continuous batching in the decode
stage and certification as the release gate.  See ``dataflow.py`` for the
pipeline itself and docs/streaming.md for the semantics.

``Engine`` keeps the public surface every caller already speaks —
``submit``/``step``/``run``/``snapshot``/``restore_snapshot``, the
``DependabilityStats`` rollup and the drained ``state_events`` — and adds
the per-stage surfaces the pipeline makes possible:

  * ``certify=`` installs a release-gate hook (the fleet's
    certify-before-release runs *in the certify stage*, not in fleet code
    wrapped around the engine);
  * ``strike(site, fault, key)`` injects an SEU into the stage that owns
    the site (decode owns ``kv_cache``/``decode_state``, the parameter
    store owns ``weights``) — the campaign engine's per-stage drill surface.

Single-process implementation (CPU or one TPU slice) with the same
state-machine a multi-host engine needs; the cooperative stage schedule is
deliberately deterministic so replay-after-fault is bit-exact.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.dependability import Policy
from repro.models import api as model_api
from repro.models.config import ArchConfig
from repro.runtime.dataflow import (     # noqa: F401 — public re-exports
    Channel, EngineStats, Request, StreamingExecutor)


class Engine:
    """Fixed-capacity continuous-batching engine over the staged executor.

    capacity: decode batch width (slots).  Each slot is free or holds one
    request.  Prefill runs per-request (right-padded to ``prefill_pad``
    buckets to bound compile count); decode steps the whole batch while
    requests join and leave mid-flight.
    """

    def __init__(self, cfg: ArchConfig, params, capacity: int = 8,
                 max_len: int = 512, prefill_pad: int = 64,
                 snapshot_every: int = 32, eos_id: int = -1,
                 compiled=None, backend: Optional[str] = None,
                 policy_map=None, state_scrub: str = "off",
                 storage_scrub: Optional[str] = None,
                 storage_scrub_every: Optional[int] = None,
                 certify: Optional[Callable[[Request], bool]] = None,
                 drain_barrier: bool = False, multi_step: int = 1,
                 tracer=None, event_log=None, metrics=None):
        # engine-level execution-backend override for the quantized hot
        # paths (core/backend registry); baked into cfg so the jitted
        # decode/prefill pair and any compiled-pair sharing stay consistent
        cfg = model_api.with_backend(cfg, backend)
        # policy_map= is the engine's selective-hardening surface
        # (core/policy_map.py; PolicyMap | JSON doc/text/path).  The map is
        # baked into cfg — the jitted decode/prefill pair executes the
        # mapped ``ffn.*`` policies in-graph — and the engine derives its
        # scrub schedule from the state sites unless the caller pinned one:
        #   kv_cache/decode_state policies -> state_scrub (PolicyMap.
        #       scrub_mode: CKPT⇒rollback, ABFT⇒detect)
        #   weights policy -> storage_scrub: ABFT⇒detect at every-pump
        #       cadence (detection latency is the product), CKPT⇒rollback
        #       amortized over snapshot_every ticks (golden restore heals
        #       retroactively)
        cfg = model_api.with_policy_map(cfg, policy_map)
        if policy_map is not None:
            pm = cfg.policy_map
            if state_scrub == "off":
                state_scrub = pm.scrub_mode()
            if storage_scrub is None:
                storage_scrub = {Policy.ABFT: "detect",
                                 Policy.CKPT: "rollback"}.get(
                    pm.storage_policy(), "off")
        if storage_scrub is None:
            storage_scrub = "off"
        if storage_scrub_every is None:
            storage_scrub_every = 1 if storage_scrub == "detect" \
                else snapshot_every
        self._ex = StreamingExecutor(
            cfg, params, capacity=capacity, max_len=max_len,
            prefill_pad=prefill_pad, snapshot_every=snapshot_every,
            eos_id=eos_id, compiled=compiled, state_scrub=state_scrub,
            storage_scrub=storage_scrub,
            storage_scrub_every=storage_scrub_every,
            certify=certify, drain_barrier=drain_barrier,
            multi_step=multi_step, tracer=tracer, event_log=event_log,
            metrics=metrics)

    # ------------------------------------------------------------- pipeline
    @property
    def executor(self) -> StreamingExecutor:
        """The staged pipeline this engine fronts (stages, channels,
        per-stage injection)."""
        return self._ex

    @property
    def cfg(self):
        return self._ex.cfg

    @property
    def compiled(self):
        """The jitted (decode, prefill) pair, shareable with same-config
        engines via the ``compiled=`` constructor argument."""
        return self._ex.compiled

    # --------------------------------------------------- state pass-through
    # Mutable run state lives in the stages; these properties keep the
    # monolith-era surface (fleet, campaigns, tests) working unchanged.
    @property
    def params(self):
        return self._ex.params

    @params.setter
    def params(self, value):
        self._ex.params = value

    @property
    def capacity(self):
        return self._ex.capacity

    @property
    def max_len(self):
        return self._ex.max_len

    @property
    def prefill_pad(self):
        return self._ex.prefill_pad

    @property
    def snapshot_every(self):
        return self._ex.snapshot_every

    @property
    def eos_id(self):
        return self._ex.eos_id

    @property
    def multi_step(self):
        """Decode steps per jitted dispatch window (1 = per-step)."""
        return self._ex.multi_step

    @property
    def queue(self):
        """The submission channel's deque (admit-stage inbox)."""
        return self._ex.submit_ch.items

    @property
    def active(self):
        """slot -> Request mapping of the decode stage's live batch."""
        return self._ex.decode.active

    @property
    def slot_pos(self):
        return self._ex.decode.slot_pos

    @property
    def slot_remaining(self):
        return self._ex.decode.slot_remaining

    @property
    def cache(self):
        return self._ex.decode.cache

    @cache.setter
    def cache(self, value):
        self._ex.decode.cache = value

    @property
    def tokens(self):
        return self._ex.decode.tokens

    @tokens.setter
    def tokens(self, value):
        self._ex.decode.tokens = value

    @property
    def stats(self) -> EngineStats:
        return self._ex.stats

    @property
    def certify(self):
        return self._ex.certify

    @certify.setter
    def certify(self, hook):
        self._ex.certify = hook

    @property
    def state_scrub(self) -> str:
        return self._ex.state_scrub

    @state_scrub.setter
    def state_scrub(self, mode: str):
        if mode not in ("off", "detect", "rollback"):
            raise ValueError(f"state_scrub must be off|detect|rollback, "
                             f"got {mode!r}")
        self._ex.state_scrub = mode

    @property
    def policy_map(self):
        """The per-site dependability assignment baked into the config
        (None for the legacy single-policy engine)."""
        return self._ex.cfg.policy_map

    @property
    def storage_scrub(self) -> str:
        return self._ex.storage_scrub

    @property
    def storage_scrub_every(self) -> int:
        return self._ex.storage_scrub_every

    @property
    def state_events(self):
        return self._ex.state_events

    # ------------------------------------------------------- observability
    @property
    def tick(self) -> int:
        """The executor's deterministic pump-cycle clock."""
        return self._ex.tick

    @property
    def tracer(self):
        return self._ex.tracer

    @tracer.setter
    def tracer(self, value):
        self._ex.tracer = value

    @property
    def event_log(self):
        return self._ex.event_log

    @event_log.setter
    def event_log(self, value):
        self._ex.event_log = value

    @property
    def metrics(self):
        return self._ex.metrics

    @property
    def dependability(self):
        return self._ex.dependability

    @property
    def _snapshot(self):
        return self._ex._snapshot

    @_snapshot.setter
    def _snapshot(self, value):
        self._ex._snapshot = value

    # ------------------------------------------------------------ lifecycle
    def reset(self, params=None):
        """Return the engine's run state (channels, slots, cache, per-run
        stats) to fresh, optionally with new (same-shaped) params.  Lifetime
        dependability counters survive resets; compiled fns are kept."""
        self._ex.reset(params=params)

    def submit(self, req: Request):
        self._ex.submit(req)

    def cancel(self, uid: int) -> bool:
        """Evict a request from whichever stage holds it (deadline/abort
        path); True if it was found live anywhere in the pipeline."""
        return self._ex.cancel(uid)

    def step(self) -> List[Request]:
        """One cooperative pump of every stage; returns requests that
        cleared the release stage this cycle."""
        return self._ex.step()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        """Drain the pipeline."""
        return self._ex.run(max_steps=max_steps)

    # ------------------------------------------------------- dependability
    def scrub_decode_state(self) -> bool:
        return self._ex.scrub_decode_state()

    def scrub_storage(self) -> bool:
        """Verify live params against the golden storage checksums
        (True == clean); no-op True when storage scrubbing is off."""
        return self._ex.scrub_storage()

    def refresh_storage_baseline(self):
        """Re-bless the current params as golden (rolling-deploy hook)."""
        self._ex.refresh_storage_baseline()

    def drain_state_events(self) -> List[dict]:
        return self._ex.drain_state_events()

    def record_dependability(self, stats: dict):
        self._ex.record_dependability(stats)

    def strike(self, site: str, fault, key) -> None:
        """Per-stage SEU injection (campaign drill surface)."""
        self._ex.strike(site, fault, key)

    def dependability_report(self) -> dict:
        """Host-side dependability summary: detection counters + the
        replay/snapshot state a campaign needs to judge recovery cost."""
        from repro.core.dependability import DependabilityStats
        ex = self._ex
        out = DependabilityStats.to_host(ex.dependability)
        out.update(steps=ex.stats.steps, replays=ex.stats.replays,
                   tokens_out=ex.stats.tokens_out,
                   snapshot_every=ex.snapshot_every,
                   state_scrub=ex.state_scrub,
                   storage_scrub=ex.storage_scrub,
                   state_events_pending=len(ex.state_events))
        return out

    # ----------------------------------------------------- fault tolerance
    def restore_snapshot(self) -> int:
        """Roll back to the last (checksum-verified) snapshot; returns the
        number of steps replayed."""
        return self._ex.restore_snapshot()
