"""Batched serving engine — the Klepsydra-AI-runtime analogue.

The paper's runtime traits, mapped to a TPU serving engine:

  * **lock-free streaming execution** → a continuous-batching decode loop:
    one jitted ``decode_step`` over a fixed-capacity batch; requests slot in
    and out of the batch without recompilation (slot state is data, not
    structure).
  * **"no hardware-specific coding once configured"** → the engine is built
    from the same family-dispatching model API as training; any
    ``--arch`` serves through it unchanged.
  * **orchestration instructions** (payload computer → RTG4 → HPDP) →
    ``Request``/``Engine.submit`` → scheduler → device step.
  * **dependability hooks**: an optional dependability policy re-executes /
    checksums each step (core.dependability), and every N steps the engine
    snapshots decode state so a device fault replays at most N tokens.
  * **decode-state scrubbing** (docs/recovery.md): the transient state a
    weight scrub can never see — the KV cache / recurrent state and the
    sampled-token buffer — carries a running mod-2^32 checksum, refreshed
    after every legitimate mutation and re-verified before the next step
    consumes it.  ``state_scrub="rollback"`` turns detection into
    checkpoint/restart: the engine rolls back to its last (checksum-
    verified) snapshot and replays, bounding lost work at
    ``snapshot_every`` steps; ``"detect"`` only raises the alarm so a
    fleet supervisor can drain + fail over instead.

Single-process implementation (CPU or one TPU slice) with the same
state-machine a multi-host engine needs; the scheduler is deliberately
deterministic so replay-after-fault is bit-exact.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abft
from repro.core.dependability import DependabilityStats
from repro.models import api as model_api
from repro.models.config import ArchConfig

# decode-state checksums: the storage-scrub identity applied to the live
# KV cache / recurrent state + token buffer; jitted once per cache structure
_state_checksums = jax.jit(abft.storage_checksums)


def _checks_equal(a, b) -> bool:
    """Host verdict: does every leaf checksum match?"""
    return all(bool(x) for x in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda p, q: p == q, a, b)))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    # filled by the engine
    output: Optional[List[int]] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    replays: int = 0
    faults_detected: int = 0

    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)


class Engine:
    """Fixed-capacity continuous-batching engine.

    capacity: decode batch width (slots).  Each slot is free or holds one
    request.  Prefill runs per-request (right-padded to ``prefill_pad``
    buckets to bound compile count); decode steps the whole batch.
    """

    def __init__(self, cfg: ArchConfig, params, capacity: int = 8,
                 max_len: int = 512, prefill_pad: int = 64,
                 snapshot_every: int = 32, eos_id: int = -1,
                 compiled=None, backend: Optional[str] = None,
                 state_scrub: str = "off"):
        # engine-level execution-backend override for the quantized hot
        # paths (core/backend registry); baked into cfg so the jitted
        # decode/prefill pair and any compiled-pair sharing stay consistent
        cfg = model_api.with_backend(cfg, backend)
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_pad = prefill_pad
        self.eos_id = eos_id
        self.snapshot_every = snapshot_every

        self.queue: deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.slot_pos = np.zeros(capacity, np.int32)  # current length per slot
        self.slot_remaining = np.zeros(capacity, np.int32)
        self.stats = EngineStats()

        # one KV cache for the whole batch; slots index rows
        self.cache = model_api.init_cache(cfg, capacity, max_len)
        self.tokens = jnp.zeros((capacity,), jnp.int32)

        if compiled is not None:
            # replica fleets share one jitted (decode, prefill) pair so N
            # engines over the same config compile once, not N times
            self._decode, self._prefill = compiled
        else:
            def _step(p, t, c):
                logits, c = model_api.decode_step(cfg, p, t, c)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), c

            self._decode = jax.jit(_step)
            self._prefill = jax.jit(
                lambda p, t, c=None: model_api.prefill(cfg, p, t, max_len),
                static_argnums=())
        self._snapshot = None
        self._snapshot_step = 0
        self._since_snapshot: List[Request] = []   # admitted after snapshot
        self.dependability = DependabilityStats.zero()

        # decode-state scrubbing: "off" | "detect" | "rollback"
        #   detect   — checksum-verify before each step; mismatches are
        #              recorded as events for a supervisor to act on
        #   rollback — additionally restore the last verified snapshot and
        #              replay (engine-local checkpoint/restart)
        if state_scrub not in ("off", "detect", "rollback"):
            raise ValueError(f"state_scrub must be off|detect|rollback, "
                             f"got {state_scrub!r}")
        self.state_scrub = state_scrub
        self._expected_check = None        # checksums after last mutation
        self.state_events: List[dict] = []  # drained by fleets / campaigns

    @property
    def compiled(self):
        """The jitted (decode, prefill) pair, shareable with same-config
        engines via the ``compiled=`` constructor argument."""
        return (self._decode, self._prefill)

    def reset(self, params=None):
        """Return the engine's run state (queue, slots, cache, per-run stats)
        to fresh, optionally with new (same-shaped) params.  Lifetime
        dependability counters (``self.dependability``) survive resets — a
        campaign accumulates verdicts across many reset+run trials.
        Campaigns reuse one engine across trials so the jitted prefill/decode
        stay compiled; swapping params is free because they are traced
        arguments, not constants."""
        if params is not None:
            self.params = params
        self.queue.clear()
        self.active.clear()
        self.slot_pos[:] = 0
        self.slot_remaining[:] = 0
        self.stats = EngineStats()
        self.cache = model_api.init_cache(self.cfg, self.capacity, self.max_len)
        self.tokens = jnp.zeros((self.capacity,), jnp.int32)
        self._snapshot = None
        self._snapshot_step = 0
        self._since_snapshot = []
        self._expected_check = None
        self.state_events = []

    # ------------------------------------------------------- dependability
    def _device_state(self) -> dict:
        """The device-resident decode state the scrub covers (the host-side
        slot bookkeeping lives in ECC'd host memory in the deployment this
        models, so it is outside the SEU threat surface)."""
        return {"cache": self.cache, "tokens": self.tokens}

    def _refresh_state_check(self):
        """Re-checksum after a legitimate mutation — the running 'expected'
        fingerprint every later scrub compares against."""
        if self.state_scrub != "off":
            self._expected_check = _state_checksums(self._device_state())

    def scrub_decode_state(self) -> bool:
        """Verify the live decode state against the post-mutation checksum;
        True == clean.  A mismatch means an SEU struck the KV cache /
        recurrent state or the token buffer *between* engine steps — the
        transient site no weight scrub can see."""
        if self._expected_check is None:
            return True
        fresh = _state_checksums(self._device_state())
        clean = _checks_equal(fresh, self._expected_check)
        self.record_dependability({
            "faults_detected": jnp.int32(0 if clean else 1),
            "checks_run": jnp.int32(1)})
        return clean

    def _scrub_and_recover(self):
        """The per-step scrub: detect, and under ``rollback`` restore the
        last verified snapshot (checkpoint/restart at decode granularity).
        Appends one event per detection so fleets/campaigns can account
        recoveries and measure recovery latency."""
        if self.scrub_decode_state():
            return
        event = {"step": self.stats.steps, "recovered": False,
                 "seconds": 0.0, "steps_replayed": 0}
        if self.state_scrub == "rollback" and self._snapshot is not None:
            t0 = time.perf_counter()
            try:
                event["steps_replayed"] = self.restore_snapshot()
                event["recovered"] = True
                event["seconds"] = time.perf_counter() - t0
                self.record_dependability({"faults_recovered": jnp.int32(1)})
            except RuntimeError:
                # snapshot itself failed verification — leave recovered
                # False; the supervisor's drain+replay is the fallback
                pass
        if not event["recovered"]:
            # accept the corrupted fingerprint as the new baseline so one
            # strike raises one alarm, not one per remaining step
            self._refresh_state_check()
        self.state_events.append(event)

    def drain_state_events(self) -> List[dict]:
        ev, self.state_events = self.state_events, []
        return ev

    def record_dependability(self, stats: dict):
        """Fold a DependabilityStats pytree (from dependable ops or a
        campaign's detection verdicts) into the engine-lifetime counters."""
        self.dependability = DependabilityStats.merge(self.dependability, stats)

    def dependability_report(self) -> dict:
        """Host-side dependability summary: detection counters + the
        replay/snapshot state a campaign needs to judge recovery cost."""
        out = DependabilityStats.to_host(self.dependability)
        out.update(steps=self.stats.steps, replays=self.stats.replays,
                   tokens_out=self.stats.tokens_out,
                   snapshot_every=self.snapshot_every,
                   state_scrub=self.state_scrub,
                   state_events_pending=len(self.state_events))
        return out

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.capacity) if s not in self.active]

    def cancel(self, uid: int) -> bool:
        """Evict a request from the queue or its slot (deadline/abort path).
        The slot's cache rows go stale but are overwritten by the next
        admission's prefill.  Also purged from snapshot bookkeeping so a
        later ``restore_snapshot`` cannot resurrect cancelled work.
        Returns True if the request was found live (queued or decoding)."""
        self._since_snapshot = [r for r in self._since_snapshot
                                if r.uid != uid]
        if self._snapshot is not None:
            for slot, r in list(self._snapshot["active"].items()):
                if r.uid == uid:
                    del self._snapshot["active"][slot]
                    del self._snapshot["outputs"][slot]
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                return True
        for slot, r in list(self.active.items()):
            if r.uid == uid:
                del self.active[slot]
                self.slot_remaining[slot] = 0
                return True
        return False

    def _admit(self) -> List[Request]:
        """Prefill queued requests into free slots (continuous batching).
        Returns requests that finished during admission (prompt already
        produced their only token)."""
        finished: List[Request] = []
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            self._since_snapshot.append(req)
            prompt = req.prompt[: self.max_len - req.max_new_tokens]
            # attention caches mask past each row's length, so right-padding
            # to a bucket is free; recurrent state integrates every token it
            # sees, so state families must prefill the exact prompt (one
            # compile per distinct length instead of per bucket)
            if self.cfg.recurrent is not None:
                pad = len(prompt)
            else:
                pad = -(-len(prompt) // self.prefill_pad) * self.prefill_pad
            toks = jnp.asarray(
                [prompt + [0] * (pad - len(prompt))], jnp.int32)
            logits, cache1 = self._prefill(self.params, toks)
            # write this request's prefix rows into the batch cache
            self.cache = _cache_write_slot(
                self.cfg, self.cache, cache1, slot, len(prompt), self.max_len)
            nxt = int(jnp.argmax(logits[0, len(prompt) - 1]))
            self.tokens = self.tokens.at[slot].set(nxt)
            self.slot_pos[slot] = len(prompt)
            # the prefill itself produced the first new token
            self.slot_remaining[slot] = req.max_new_tokens - 1
            req.output = [nxt]
            self.active[slot] = req
            if self.slot_remaining[slot] <= 0:
                req.finished_at = time.time()
                del self.active[slot]
                finished.append(req)
        return finished

    # ----------------------------------------------------------------- steps
    def step(self) -> List[Request]:
        """One decode step for every active slot; returns requests that
        finished this step (admission-time finishes included)."""
        # scrub BEFORE this step consumes the state (and before admission
        # mutates it): anything that changed since the last legitimate
        # mutation is an SEU, and under "rollback" we restart from the
        # last verified snapshot instead of decoding from corrupted state
        if self.state_scrub != "off" and self.active:
            self._scrub_and_recover()
        finished = self._admit()
        if not self.active:
            self._refresh_state_check()
            return finished
        if self.stats.steps % self.snapshot_every == 0:
            self._take_snapshot()
        nxt, self.cache = self._decode(self.params, self.tokens, self.cache)
        self.tokens = nxt
        self.stats.steps += 1
        nxt_host = np.asarray(nxt)
        done_slots = []
        for slot, req in list(self.active.items()):
            req.output.append(int(nxt_host[slot]))
            self.slot_pos[slot] += 1
            self.slot_remaining[slot] -= 1
            self.stats.tokens_out += 1
            if (self.slot_remaining[slot] <= 0
                    or int(nxt_host[slot]) == self.eos_id
                    or self.slot_pos[slot] >= self.max_len - 1):
                req.finished_at = time.time()
                done_slots.append(slot)
        for slot in done_slots:
            finished.append(self.active.pop(slot))
        self._refresh_state_check()
        return finished

    def run(self, max_steps: int = 10_000) -> EngineStats:
        """Drain queue + active set."""
        while (self.queue or self.active) and self.stats.steps < max_steps:
            self.step()
        return self.stats

    # ----------------------------------------------------- fault tolerance
    def _take_snapshot(self):
        self._snapshot = {
            "cache": self.cache,
            "tokens": self.tokens,
            "slot_pos": self.slot_pos.copy(),
            "slot_remaining": self.slot_remaining.copy(),
            "active": dict(self.active),
            "outputs": {s: list(r.output) for s, r in self.active.items()},
            "steps": self.stats.steps,
            "tokens_out": self.stats.tokens_out,
            # golden-snapshot integrity: checksummed at capture so a later
            # restore can refuse a snapshot that was itself struck
            "check": (_state_checksums(
                {"cache": self.cache, "tokens": self.tokens})
                if self.state_scrub != "off" else None),
        }
        self._snapshot_step = self.stats.steps
        self._since_snapshot = []

    def restore_snapshot(self) -> int:
        """Roll back to the last snapshot (device-fault recovery path).

        The snapshot round-trips the *whole* decode state: cache, token
        buffer, per-slot bookkeeping, active-set membership, request outputs
        and the step/token counters — so ``tokens_per_step()`` and token
        accounting stay exact across a replay, and requests that finished or
        were admitted after the snapshot are correctly re-decoded / requeued.
        ``replays`` and ``faults_detected`` are lifetime counters and are
        never rolled back.

        Returns the number of steps replayed (lost work bound =
        snapshot_every).
        """
        if self._snapshot is None:
            raise RuntimeError("no snapshot taken yet")
        snap = self._snapshot
        if snap["check"] is not None:
            fresh = _state_checksums(
                {"cache": snap["cache"], "tokens": snap["tokens"]})
            if not _checks_equal(fresh, snap["check"]):
                raise RuntimeError(
                    "snapshot failed checksum verification (SEU struck the "
                    "golden snapshot itself) — refusing to restore; escalate "
                    "to drain + failover")
        self.cache = snap["cache"]
        self.tokens = snap["tokens"]
        self.slot_pos = snap["slot_pos"].copy()
        self.slot_remaining = snap["slot_remaining"].copy()
        # active set as of the snapshot: resurrects requests that finished
        # after it (their post-snapshot tokens are suspect) and drops ones
        # admitted after it (requeued below; the cache rollback erased their
        # prefill rows)
        self.active = dict(snap["active"])
        for s, req in self.active.items():
            req.output = list(snap["outputs"][s])
            req.finished_at = 0.0
        for req in reversed(self._since_snapshot):
            req.output = None
            req.finished_at = 0.0
            self.queue.appendleft(req)
        self._since_snapshot = []
        lost = self.stats.steps - snap["steps"]
        self.stats.steps = snap["steps"]
        self.stats.tokens_out = snap["tokens_out"]
        self.stats.replays += 1
        self._refresh_state_check()
        return lost


def _cache_write_slot(cfg, batch_cache, one_cache, slot: int, n: int,
                      max_len: int):
    """Copy a single-request prefill cache into row ``slot`` of the batch
    cache.  Works on any family's cache pytree: leaves are (L, B, T, ...)
    for KV or (L, B, ...) for recurrent state (batch at dim 1); per-row
    length vectors are (B,) int (batch at dim 0); scalar counters are maxed.
    """
    def write(bc, oc):
        if bc.ndim == 0:
            return jnp.maximum(bc, oc)
        if bc.ndim == 1 and jnp.issubdtype(bc.dtype, jnp.integer):
            return bc.at[slot].set(n)          # per-row length vector
        # one_cache leaf has batch=1 at dim 1
        row = jax.lax.dynamic_slice_in_dim(oc, 0, 1, axis=1)
        if bc.ndim >= 3 and bc.shape[2] != row.shape[2]:
            # time-indexed leaf with different max_len: copy the prefix
            pad = [(0, 0)] * row.ndim
            pad[2] = (0, bc.shape[2] - row.shape[2])
            row = jnp.pad(row, pad)
        return jax.lax.dynamic_update_slice_in_dim(bc, row.astype(bc.dtype),
                                                   slot, axis=1)

    return jax.tree_util.tree_map(write, batch_cache, one_cache)
