"""Batched serving engine — the Klepsydra-AI-runtime analogue.

The paper's runtime traits, mapped to a TPU serving engine:

  * **lock-free streaming execution** → a continuous-batching decode loop:
    one jitted ``decode_step`` over a fixed-capacity batch; requests slot in
    and out of the batch without recompilation (slot state is data, not
    structure).
  * **"no hardware-specific coding once configured"** → the engine is built
    from the same family-dispatching model API as training; any
    ``--arch`` serves through it unchanged.
  * **orchestration instructions** (payload computer → RTG4 → HPDP) →
    ``Request``/``Engine.submit`` → scheduler → device step.
  * **dependability hooks**: an optional dependability policy re-executes /
    checksums each step (core.dependability), and every N steps the engine
    snapshots decode state so a device fault replays at most N tokens.

Single-process implementation (CPU or one TPU slice) with the same
state-machine a multi-host engine needs; the scheduler is deliberately
deterministic so replay-after-fault is bit-exact.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dependability import DependabilityStats
from repro.models import api as model_api
from repro.models.config import ArchConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    # filled by the engine
    output: Optional[List[int]] = None
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    replays: int = 0
    faults_detected: int = 0

    def tokens_per_step(self) -> float:
        return self.tokens_out / max(self.steps, 1)


class Engine:
    """Fixed-capacity continuous-batching engine.

    capacity: decode batch width (slots).  Each slot is free or holds one
    request.  Prefill runs per-request (right-padded to ``prefill_pad``
    buckets to bound compile count); decode steps the whole batch.
    """

    def __init__(self, cfg: ArchConfig, params, capacity: int = 8,
                 max_len: int = 512, prefill_pad: int = 64,
                 snapshot_every: int = 32, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_pad = prefill_pad
        self.eos_id = eos_id
        self.snapshot_every = snapshot_every

        self.queue: deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.slot_pos = np.zeros(capacity, np.int32)  # current length per slot
        self.slot_remaining = np.zeros(capacity, np.int32)
        self.stats = EngineStats()

        # one KV cache for the whole batch; slots index rows
        self.cache = model_api.init_cache(cfg, capacity, max_len)
        self.tokens = jnp.zeros((capacity,), jnp.int32)

        def _step(p, t, c):
            logits, c = model_api.decode_step(cfg, p, t, c)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), c

        self._decode = jax.jit(_step)
        self._prefill = jax.jit(
            lambda p, t, c=None: model_api.prefill(cfg, p, t, max_len),
            static_argnums=())
        self._snapshot = None
        self._snapshot_step = 0
        self.dependability = DependabilityStats.zero()

    def reset(self, params=None):
        """Return the engine's run state (queue, slots, cache, per-run stats)
        to fresh, optionally with new (same-shaped) params.  Lifetime
        dependability counters (``self.dependability``) survive resets — a
        campaign accumulates verdicts across many reset+run trials.
        Campaigns reuse one engine across trials so the jitted prefill/decode
        stay compiled; swapping params is free because they are traced
        arguments, not constants."""
        if params is not None:
            self.params = params
        self.queue.clear()
        self.active.clear()
        self.slot_pos[:] = 0
        self.slot_remaining[:] = 0
        self.stats = EngineStats()
        self.cache = model_api.init_cache(self.cfg, self.capacity, self.max_len)
        self.tokens = jnp.zeros((self.capacity,), jnp.int32)
        self._snapshot = None
        self._snapshot_step = 0

    # ------------------------------------------------------- dependability
    def record_dependability(self, stats: dict):
        """Fold a DependabilityStats pytree (from dependable ops or a
        campaign's detection verdicts) into the engine-lifetime counters."""
        self.dependability = DependabilityStats.merge(self.dependability, stats)

    def dependability_report(self) -> dict:
        """Host-side dependability summary: detection counters + the
        replay/snapshot state a campaign needs to judge recovery cost."""
        out = DependabilityStats.to_host(self.dependability)
        out.update(steps=self.stats.steps, replays=self.stats.replays,
                   tokens_out=self.stats.tokens_out,
                   snapshot_every=self.snapshot_every)
        return out

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.capacity) if s not in self.active]

    def _admit(self):
        """Prefill queued requests into free slots (continuous batching)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = req.prompt[: self.max_len - req.max_new_tokens]
            pad = -(-len(prompt) // self.prefill_pad) * self.prefill_pad
            toks = jnp.asarray(
                [prompt + [0] * (pad - len(prompt))], jnp.int32)
            logits, cache1 = self._prefill(self.params, toks)
            # write this request's prefix rows into the batch cache
            self.cache = _cache_write_slot(
                self.cfg, self.cache, cache1, slot, len(prompt), self.max_len)
            nxt = int(jnp.argmax(logits[0, len(prompt) - 1]))
            self.tokens = self.tokens.at[slot].set(nxt)
            self.slot_pos[slot] = len(prompt)
            # the prefill itself produced the first new token
            self.slot_remaining[slot] = req.max_new_tokens - 1
            req.output = [nxt]
            self.active[slot] = req
            if self.slot_remaining[slot] <= 0:
                req.finished_at = time.time()
                del self.active[slot]

    # ----------------------------------------------------------------- steps
    def step(self) -> int:
        """One decode step for every active slot; returns #finished."""
        self._admit()
        if not self.active:
            return 0
        if self.stats.steps % self.snapshot_every == 0:
            self._take_snapshot()
        nxt, self.cache = self._decode(self.params, self.tokens, self.cache)
        self.tokens = nxt
        self.stats.steps += 1
        nxt_host = np.asarray(nxt)
        finished = []
        for slot, req in list(self.active.items()):
            req.output.append(int(nxt_host[slot]))
            self.slot_pos[slot] += 1
            self.slot_remaining[slot] -= 1
            self.stats.tokens_out += 1
            if (self.slot_remaining[slot] <= 0
                    or int(nxt_host[slot]) == self.eos_id
                    or self.slot_pos[slot] >= self.max_len - 1):
                req.finished_at = time.time()
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
        return len(finished)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        """Drain queue + active set."""
        while (self.queue or self.active) and self.stats.steps < max_steps:
            self.step()
        return self.stats

    # ----------------------------------------------------- fault tolerance
    def _take_snapshot(self):
        self._snapshot = (jax.tree_util.tree_map(lambda x: x, self.cache),
                          self.tokens, self.slot_pos.copy(),
                          self.slot_remaining.copy(),
                          {s: list(r.output) for s, r in self.active.items()})
        self._snapshot_step = self.stats.steps

    def restore_snapshot(self) -> int:
        """Roll back to the last snapshot (device-fault recovery path).

        Returns the number of steps replayed (lost work bound =
        snapshot_every).
        """
        if self._snapshot is None:
            raise RuntimeError("no snapshot taken yet")
        cache, tokens, pos, rem, outs = self._snapshot
        self.cache = cache
        self.tokens = tokens
        self.slot_pos = pos.copy()
        self.slot_remaining = rem.copy()
        for s, out in outs.items():
            if s in self.active:
                self.active[s].output = list(out)
        lost = self.stats.steps - self._snapshot_step
        self.stats.steps = self._snapshot_step
        self.stats.replays += 1
        return lost


def _cache_write_slot(cfg, batch_cache, one_cache, slot: int, n: int,
                      max_len: int):
    """Copy a single-request prefill cache into row ``slot`` of the batch
    cache.  Works on any family's cache pytree: leaves are (L, B, T, ...)
    for KV or (L, B, ...) for recurrent state (batch at dim 1); per-row
    length vectors are (B,) int (batch at dim 0); scalar counters are maxed.
    """
    def write(bc, oc):
        if bc.ndim == 0:
            return jnp.maximum(bc, oc)
        if bc.ndim == 1 and jnp.issubdtype(bc.dtype, jnp.integer):
            return bc.at[slot].set(n)          # per-row length vector
        # one_cache leaf has batch=1 at dim 1
        row = jax.lax.dynamic_slice_in_dim(oc, 0, 1, axis=1)
        if bc.ndim >= 3 and bc.shape[2] != row.shape[2]:
            # time-indexed leaf with different max_len: copy the prefix
            pad = [(0, 0)] * row.ndim
            pad[2] = (0, bc.shape[2] - row.shape[2])
            row = jnp.pad(row, pad)
        return jax.lax.dynamic_update_slice_in_dim(bc, row.astype(bc.dtype),
                                                   slot, axis=1)

    return jax.tree_util.tree_map(write, batch_cache, one_cache)
