"""Fault-tolerant training driver: the full inject → detect → recover loop.

Composes the substrate into the dependable-execution story the paper tells:

    data pipeline (deterministic batch_at)        — data/pipeline.py
    train step (pjit'd, sharded)                  — train/steps.py
    checkpoint every K steps (incremental, async, — train/checkpoint.py
      crc32-chained; dirty chunks only)             (IncrementalCheckpointer)
    SEU injection (optional, for drills)          — core/fault_injection.py
    detection: loss NaN/spike or ABFT flag        — here
    recovery: restore last checkpoint + replay    — here
    elastic: shrink mesh on simulated node loss   — runtime/orchestrator.py

Checkpointing runs through ``IncrementalCheckpointer``: saves snapshot the
state to host immediately and persist on a background writer (training never
blocks on disk unless ``max_pending`` snapshots are already in flight), and
only chunks whose mod-2^32 checksum changed since the last durable save are
rewritten.  Recovery calls ``wait()`` first so the restore reads a durable
manifest; restores of chained (format-2) checkpoints are bit-identical to
full ones, so the replay determinism contract below is unchanged.

Determinism contract: batch ``i`` is a pure function of (seed, i), so a
restore at step s replays steps [s, crash) on identical data — the loss
curve after recovery is bit-identical to a run that never crashed (tested
in tests/test_ft_loop.py).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TokenStream, shard_batch
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.transformer import ShardCtx
from repro.parallel import sharding as shd
from repro.runtime.orchestrator import Orchestrator
from repro.train import checkpoint as ckpt
from repro.train import optim as optim_mod
from repro.train import steps as steps_mod


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 20
    keep_n: int = 2
    loss_spike_factor: float = 10.0   # recovery trigger: loss > factor×median
    max_recoveries: int = 8
    seed: int = 0
    # incremental-checkpointer knobs: rebase cadence bounds manifest-chain
    # length; max_pending bounds how far durable state may trail the loop
    ckpt_full_every: int = 8
    ckpt_max_pending: int = 2


@dataclasses.dataclass
class RunReport:
    losses: List[float]
    recoveries: int
    steps_replayed: int
    wall_s: float
    events: List[str]
    ckpt_stats: Dict[str, int] = dataclasses.field(default_factory=dict)


def _is_bad(loss: float, history: List[float], factor: float) -> bool:
    if not np.isfinite(loss):
        return True
    if len(history) >= 8:
        med = float(np.median(history[-8:]))
        if loss > factor * max(med, 1e-6):
            return True
    return False


def run(cfg: ArchConfig, shape: ShapeConfig, ft: FTConfig,
        mesh=None, n_steps: int = 100,
        fault_hook: Optional[Callable[[int, Any], Any]] = None,
        lr: float = 3e-4) -> RunReport:
    """Train ``n_steps``; survive faults injected by ``fault_hook``.

    fault_hook(step, state) -> state | None: may corrupt the state (SEU
    drill) or raise ``RuntimeError("node lost")`` to simulate a device
    failure.  The driver recovers either way.
    """
    t0 = time.time()
    opt = optim_mod.make_optimizer(cfg.optimizer, lr=lr)
    stream = TokenStream(cfg, shape, seed=ft.seed, n_hosts=1, host_id=0)
    orch = Orchestrator(n_workers=1, heartbeat_timeout=1e9)

    ctx = None
    specs = None
    if mesh is not None:
        dp = tuple(a for a in mesh.axis_names if a != "model")
        ctx = ShardCtx(mesh=mesh, dp=dp, model="model")
    step_fn = jax.jit(steps_mod.make_train_step(cfg, ctx, opt))

    # incremental + async checkpointing: dirty-chunk writes on a background
    # thread; every restore below waits for in-flight saves to be durable
    # before reading, so recovery never races the writer
    ick = ckpt.IncrementalCheckpointer(
        ft.ckpt_dir, keep_n=ft.keep_n, full_every=ft.ckpt_full_every,
        max_pending=ft.ckpt_max_pending)
    try:
        # ---- init or resume
        start = ckpt.latest_step(ft.ckpt_dir)
        if start is None:
            state = steps_mod.init_train_state(cfg, jax.random.key(ft.seed),
                                               opt)
            ick.save(0, state)
            start = 0
        else:
            start, state = ckpt.restore(ft.ckpt_dir, start)

        losses: List[float] = []
        events: List[str] = []
        recoveries = 0
        replayed = 0
        step = start

        while step < n_steps:
            batch = {k: jnp.asarray(v)
                     for k, v in stream.batch_at(step).items()}
            try:
                if fault_hook is not None:
                    maybe = fault_hook(step, state)
                    if maybe is not None:
                        state = maybe
                t_step = time.time()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                orch.heartbeat(0, step, time.time() - t_step)

                if _is_bad(loss, losses, ft.loss_spike_factor):
                    raise RuntimeError(f"corruption detected: loss={loss}")

                losses.append(loss)
                step += 1
                if step % ft.ckpt_every == 0:
                    ick.save(step, state)
            except (RuntimeError, FloatingPointError) as e:
                recoveries += 1
                events.append(f"step {step}: {e} → restore+replay")
                if recoveries > ft.max_recoveries:
                    raise RuntimeError(
                        f"exceeded max_recoveries={ft.max_recoveries}") from e
                ick.wait()                  # durability barrier before read
                last = ckpt.latest_step(ft.ckpt_dir)
                restored, state = ckpt.restore(ft.ckpt_dir, last)
                # drop optimistic losses past the restore point, replay
                replayed += step - restored
                losses = losses[: restored - start]
                step = restored
    finally:
        ick.close()                         # flush pending writes, join

    return RunReport(losses=losses, recoveries=recoveries,
                     steps_replayed=replayed, wall_s=time.time() - t0,
                     events=events, ckpt_stats=dict(ick.stats))
