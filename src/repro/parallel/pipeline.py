"""Pipeline parallelism: shard_map + ppermute microbatch pipeline.

This is the TPU-native rendition of the paper's **HPDP→HPDP chaining**: the
RTG4 can route one co-processor's output feature map *directly into another
HPDP*, which "immediately processes the next AI layer without additional data
transfer".  On a TPU mesh the same pattern is a pipeline stage axis: each
stage owns a contiguous block of layers, activations hop stage→stage over ICI
with ``lax.ppermute`` (never through the host), and microbatches keep every
stage busy — the dataflow-streaming idea at mesh scale.

Schedule: GPipe-style fill/steady/drain loop written with ``lax.fori_loop``
(so the HLO is one while loop regardless of microbatch count).  Autodiff
through ``ppermute`` transposes to the reverse permutation, so the same code
trains (the backward pass drains the pipeline in reverse) — no hand-written
1F1B needed for correctness; the forward schedule's bubble fraction is
(S-1)/(M+S-1), reported by ``bubble_fraction``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule — the PP napkin-math term."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stack_stage_params(param_list):
    """Stack per-stage param pytrees along a new leading 'stage' axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *param_list)


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any,
                   microbatches: jax.Array,
                   mesh: Mesh,
                   axis: str = "stage",
                   checkpoint_stages: bool = True) -> jax.Array:
    """Run ``microbatches`` through a pipeline of stages over mesh axis ``axis``.

    stage_fn: (per-stage params, activation (mb, ...)) -> activation
    stage_params: pytree stacked on a leading stage axis (len = axis size)
    microbatches: (n_micro, mb, ...) — identical pytree structure in/out.

    Returns (n_micro, mb, ...) outputs (replicated over ``axis``).
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    total = n_micro + n_stages - 1
    fn = jax.checkpoint(stage_fn) if checkpoint_stages else stage_fn

    def body(carry_mb):
        """Per-device body under shard_map."""
        params, mb = carry_mb            # params: this stage's block params
        stage = lax.axis_index(axis)
        state = jnp.zeros_like(mb[0])    # live activation on this stage
        out = jnp.zeros_like(mb)         # collected on the last stage
        perm = [(i, i + 1) for i in range(n_stages - 1)]   # stage i -> i+1

        def tick(t, loop):
            state, out = loop
            # stage 0 ingests microbatch t during the fill/steady phase
            incoming = lax.dynamic_index_in_dim(
                mb, jnp.minimum(t, n_micro - 1), axis=0, keepdims=False)
            state = jnp.where(stage == 0, incoming, state)
            state = fn(params, state)
            # last stage emits microbatch t-(S-1) once the pipe is full
            emit_idx = jnp.maximum(t - (n_stages - 1), 0)
            emitted = lax.dynamic_update_index_in_dim(
                out, state, emit_idx, axis=0)
            take = jnp.logical_and(stage == n_stages - 1,
                                   t >= n_stages - 1)
            out = jnp.where(take, emitted, out)
            # hop the live activation to the next stage
            state = lax.ppermute(state, axis, perm)
            return state, out

        state, out = lax.fori_loop(0, total, tick, (state, out))
        # only the last stage holds real outputs; broadcast them
        out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
        return lax.psum(out, axis)

    # params: leading stage axis sharded over `axis` (each device = its block);
    # microbatches replicated (stage 0 is the only consumer).
    pparams_spec = jax.tree_util.tree_map(lambda _: P(axis), stage_params)

    def per_device(params, mb):
        # shard_map gives a size-1 stage slice; drop the leading axis
        params = jax.tree_util.tree_map(lambda x: x[0], params)
        return body((params, mb))

    out = shard_map(
        per_device, mesh=mesh,
        in_specs=(pparams_spec, P()), out_specs=P(),
        check_vma=False,   # carry becomes stage-varying after the first hop
    )(stage_params, microbatches)
    return out


def pipeline_loss(stage_fn, stage_params, microbatches, targets_fn,
                  mesh: Mesh, axis: str = "stage"):
    """Mean loss over microbatches, differentiable through the pipeline."""
    out = pipeline_apply(stage_fn, stage_params, microbatches, mesh, axis)
    return targets_fn(out)
