"""Logical sharding rules: parameter-tree paths → PartitionSpec.

Axis scheme (single-pod 16×16 and multi-pod 2×16×16 production meshes):

  batch          → dp axes ("data",) or ("pod", "data")
  heads / d_ff / vocab / experts' E  → "model"   (tensor / expert parallel)
  weight non-TP dim                  → "data" when cfg.fsdp_params (ZeRO-3)

Rules are name-based over the parameter pytree, so every model family in the
zoo gets its specs from this one table — the same way MaxText's
logical-axis-rules work, without requiring models to annotate tensors.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _rules(cfg: ArchConfig, dp: Tuple[str, ...], mdl: str,
           moe_mode: str = "ep"):
    """name → spec-builder. fsdp shards one non-TP dim over the dp axes."""
    fsdp = dp if cfg.fsdp_params else None

    # MoE expert layout must match the shard_map in_specs
    # (models.transformer.moe_mode): EP when E % model_size == 0, else
    # expert-TP (de → model, d → dp).
    if moe_mode == "ep":
        we_g = we_i = P(mdl, None, dp)
        we_o = P(mdl, dp, None)
    else:
        we_g = we_i = P(None, dp, mdl)
        we_o = P(None, mdl, dp)

    # (leading L axis is added automatically for stacked block params)
    table = {
        # transformer attention
        "wq": P(fsdp, mdl), "wk": P(fsdp, mdl), "wv": P(fsdp, mdl),
        "wo": P(mdl, fsdp),
        "bq": P(mdl), "bk": P(mdl), "bv": P(mdl),
        # dense mlp
        "wi": P(fsdp, mdl), "wg": P(fsdp, mdl), "wd": P(mdl, fsdp),
        "mlp_g": P(fsdp, mdl), "mlp_i": P(fsdp, mdl), "mlp_o": P(mdl, fsdp),
        "router": P(None, None),
        "we_g": we_g, "we_i": we_i, "we_o": we_o,
        # W8A8 (cfg.quant): int8 weights shard like their float originals,
        # per-out-channel scales follow the output dim's placement
        "wi_q": P(fsdp, mdl), "wg_q": P(fsdp, mdl), "wd_q": P(mdl, fsdp),
        "wi_s": P(mdl), "wg_s": P(mdl), "wd_s": P(fsdp),
        "we_g_q": we_g, "we_i_q": we_i, "we_o_q": we_o,
        "we_g_s": P(*(we_g[:1] + we_g[2:])),
        "we_i_s": P(*(we_i[:1] + we_i[2:])),
        "we_o_s": P(*(we_o[:1] + we_o[2:])),
        "ws_g": P(None, mdl), "ws_i": P(None, mdl), "ws_o": P(mdl, None),
        "ws_g_q": P(None, mdl), "ws_i_q": P(None, mdl), "ws_o_q": P(mdl, None),
        "ws_g_s": P(mdl), "ws_i_s": P(mdl), "ws_o_s": P(None),
        # rwkv time/channel mix
        "wr": P(fsdp, mdl),
        "cm_wk": P(fsdp, mdl), "cm_wv": P(mdl, fsdp), "cm_wr": P(fsdp, None),
        "ddl_A": P(fsdp, None), "ddl_B": P(None, None, fsdp),
        "dec_A": P(fsdp, None), "dec_B": P(None, fsdp),
        # griffin
        "w_x": P(fsdp, mdl), "w_gate": P(fsdp, mdl),
        "conv_w": P(None, mdl),
        "w_a": P(None, mdl), "w_i": P(None, mdl),
        "w_out": P(mdl, fsdp),
        "lam": P(mdl),
        # embeddings
        "embed": P(mdl, fsdp),
        "lm_head": P(fsdp, mdl),
    }
    return table


def _spec_for(name: str, ndim: int, stacked: bool, table) -> P:
    spec = table.get(name)
    if spec is None:
        return P()                     # norms, scalars, small adapters: replicated
    if stacked:
        spec = P(*((None,) + tuple(spec)))
    # pad/truncate to tensor rank (e.g. biases)
    parts = tuple(spec)
    if len(parts) < ndim:
        parts = parts + (None,) * (ndim - len(parts))
    elif len(parts) > ndim:
        parts = parts[:ndim]
    return P(*parts)


def param_specs(cfg: ArchConfig, params: Any,
                dp: Tuple[str, ...] = ("data",), mdl: str = "model",
                mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params``.

    ``mesh`` (when given) selects the MoE expert layout: EP if n_experts
    divides the model-axis size, expert-TP otherwise (mixtral 8e on a
    16-way axis).  Without a mesh the EP layout is assumed.
    """
    if cfg.layout == "dp":
        # pure-DP layout: the model axis is folded into dp by the caller;
        # no tensor dimension shards over it
        mdl = None
    mode = "ep"
    if cfg.moe is not None and mesh is not None and mdl is not None:
        from repro.models.transformer import moe_mode
        mode = moe_mode(cfg, int(mesh.shape[mdl]))
    table = _rules(cfg, dp, mdl, moe_mode=mode)

    def spec(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        stacked = any(n in ("blocks", "dense_blocks", "moe_blocks",
                            "rec_blocks", "attn_blocks", "tail_rec")
                      for n in names[:-1])
        # rwkv 'wk'/'wv'/'wo' are (d, d) projections: same rule applies
        return _spec_for(name, leaf.ndim, stacked, table)

    return jax.tree_util.tree_map_with_path(spec, params)


def shardings_for(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(dp: Tuple[str, ...] = ("data",)) -> Any:
    """tokens/labels (B, S) sharded over batch."""
    return P(dp, None)


def cache_specs(cfg: ArchConfig, dp: Tuple[str, ...], mdl: str) -> Any:
    """KV / recurrent cache specs by family (batch over dp, heads/width over model)."""
    dp = dp or None        # () → replicated batch (e.g. long_500k, B=1)
    if cfg.family == "transformer":
        from repro.models.transformer import KVCache
        # Shard the cache's TIME dim over the model axis (flash-decoding):
        # GQA KV heads (8) rarely divide the axis (16), but T always does.
        # XLA SPMD turns the softmax reductions over the sharded T into
        # local reductions + tiny all-reduces of per-shard partials — each
        # chip reads 1/msize of the cache instead of all of it, and the
        # 57 GB/dev replicated cache (kimi-k2 @ 32k) drops to 3.6 GB/dev.
        tshard = mdl if (mdl is not None and mdl not in (dp or ())) else None
        kv = P(None, dp, tshard, None, None)   # (L, B, T, KV, hd)
        if cfg.quant_kv:
            sc = P(None, dp, tshard, None)     # (L, B, T, KV) scales
            return KVCache(kv, kv, P(dp), sc, sc)
        return KVCache(kv, kv, P(dp))          # per-row lengths (B,)
    if cfg.family == "rwkv":
        from repro.models.rwkv6 import RwkvCache
        return RwkvCache(P(None, dp, mdl), P(None, dp, None, None, None),
                         P(None, dp, mdl), P())
    if cfg.family == "hybrid":
        from repro.models.griffin import GriffinCache
        return GriffinCache(P(None, dp, None, mdl), P(None, dp, mdl),
                            P(None, dp, None, None, None),
                            P(None, dp, None, None, None), P())
    raise ValueError(cfg.family)
