"""Collective helpers used by the parallel layers.

These are thin, named wrappers so the HLO produced by each logical
communication pattern is identifiable in the dry-run's collective audit
(launch/hlo_analysis.py groups collective bytes by op kind; keeping each
pattern in one place here keeps the roofline attribution honest).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def ring_all_gather(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """All-gather via N-1 ppermute hops (overlappable ring schedule).

    XLA's native all-gather is a single fused op that cannot interleave with
    compute on the host CPU backend; the ring formulation exposes each hop so
    a consumer can compute on shard k while shard k+1 is in flight — the
    collective-overlap hillclimb lever.
    """
    n = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)
    # chunk j holds the shard of device (idx - j) mod n; reorder by source id
    stacked = jnp.stack(chunks, axis=0)                   # (n, ...)  j-indexed
    stacked = jnp.take(stacked, (idx - jnp.arange(n)) % n, axis=0)
    return lax.collapse(jnp.moveaxis(stacked, 0, axis), axis, axis + 2)


def reduce_scatter(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """psum_scatter wrapper (bandwidth-optimal gradient reduction)."""
    return lax.psum_scatter(x, axis_name, scatter_dimension=axis, tiled=True)


def all_to_all_tokens(x: jax.Array, axis_name: str,
                      split_axis: int, concat_axis: int) -> jax.Array:
    """MoE dispatch/combine: shard-of-tokens → shard-of-experts."""
    return lax.all_to_all(x, axis_name, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def grad_allreduce_bf16(grads, axis_name: str):
    """Gradient compression trick: all-reduce in bf16, accumulate in f32.

    Halves the collective bytes of the DP gradient reduction (the dominant
    collective for dense-arch training at 4k seq) at <0.1% loss-curve impact;
    the update itself is applied in f32.
    """
    return jax.tree_util.tree_map(
        lambda g: lax.psum(g.astype(jnp.bfloat16), axis_name).astype(g.dtype),
        grads)
