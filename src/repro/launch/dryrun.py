import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with zero device allocation (ShapeDtypeStruct
inputs):
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * the collective schedule     — parsed from the compiled HLO, summed per
                                  collective kind for the roofline's
                                  collective term
Artifacts are written to benchmarks/artifacts/<cell>.json; EXPERIMENTS.md
§Dry-run / §Roofline and benchmarks/roofline.py read them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
  REPRO_XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.dryrun --arch ... --mesh 2,4
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_mod
from repro.models.config import SHAPES, ArchConfig, ShapeConfig, valid_cells
from repro.models.transformer import ShardCtx
from repro.parallel import sharding as shd
from repro.train import steps as steps_mod

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|s8|u32|u8|pred|s64|u64|f64)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dtype]


def parse_collectives(hlo_text: str):
    """Sum operand bytes of every collective op in the compiled HLO."""
    per_kind = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        opname = m.group(2)
        kind = next((k for k in COLLECTIVE_OPS
                     if opname == k or opname.startswith(k + ".")), None)
        if kind is None:
            continue
        # operand types appear inside the call parens
        args = s[s.index("(") + 1:]
        operand_bytes = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(args))
        if operand_bytes == 0:
            # fall back to the result type (start of line)
            res = _SHAPE_RE.findall(m.group(1))
            operand_bytes = sum(_shape_bytes(d, dims) for d, dims in res)
        per_kind[kind] += operand_bytes
        counts[kind] += 1
    return per_kind, counts


def _memory_analysis_dict(compiled):
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        if hasattr(ma, f):
            out[f] = int(getattr(ma, f))
    return out


def _cost_analysis_dict(compiled):
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (jitted_fn, example_args) with shardings applied — not yet lowered."""
    dp = mesh_mod.dp_axes(mesh)
    mdl = "model"
    if cfg.layout == "dp":
        # pure data parallelism: the model axis carries extra batch shards
        # instead of TP (small archs whose heads don't divide the axis would
        # otherwise replicate the whole attention computation 16×)
        assert cfg.moe is None, "layout=dp is for non-MoE archs"
        dp = dp + ("model",)
    dp_size = int(jnp.prod(jnp.array([mesh.shape[a] for a in dp])))
    # activation batch shards over dp only when it divides (long_500k has B=1)
    bax = dp if shape.global_batch % dp_size == 0 else ()
    ctx = ShardCtx(mesh=mesh, dp=dp, model=mdl, batch=bax)
    bspec_ax = bax or None
    opt = None
    ins = steps_mod.input_specs(cfg, shape)

    if shape.kind == "train":
        from repro.train import optim as optim_mod
        opt = optim_mod.make_optimizer(cfg.optimizer)
        state = steps_mod.abstract_train_state(cfg, opt)
        sspecs = steps_mod.train_state_specs(cfg, state.params, dp, "model",
                                             cfg.optimizer, mesh=mesh)
        state_sh = shd.shardings_for(mesh, sspecs)
        bspec = {k: NamedSharding(mesh, P(bspec_ax, None, None)) if ins[k].ndim == 3
                 else NamedSharding(mesh, P(bspec_ax, None)) for k in ins}
        step = steps_mod.make_train_step(cfg, ctx, opt)
        fn = jax.jit(step, in_shardings=(state_sh, bspec), donate_argnums=(0,))
        return fn, (state, ins)

    if shape.kind == "prefill":
        params = steps_mod.abstract_train_state(cfg).params
        pspecs = shd.param_specs(cfg, params, dp, "model", mesh=mesh)
        params_sh = shd.shardings_for(mesh, pspecs)
        bspec = {k: NamedSharding(mesh, P(bspec_ax, None, None)) if ins[k].ndim == 3
                 else NamedSharding(mesh, P(bspec_ax, None)) for k in ins}
        step = steps_mod.make_prefill_step(cfg, max_len=shape.seq_len, ctx=ctx)
        fn = jax.jit(step, in_shardings=(params_sh, bspec))
        return fn, (params, ins)

    # decode
    params = steps_mod.abstract_train_state(cfg).params
    pspecs = shd.param_specs(cfg, params, dp, "model", mesh=mesh)
    params_sh = shd.shardings_for(mesh, pspecs)
    cspecs = shd.cache_specs(cfg, bax, "model")
    cache_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(mesh, P(bspec_ax))
    step = steps_mod.make_decode_step(cfg, ctx)
    fn = jax.jit(step, in_shardings=(params_sh, tok_sh, cache_sh),
                 donate_argnums=(2,))
    return fn, (params, ins["token"], ins["cache"])


def run_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, mesh_label: str,
             out_dir: Path, verbose: bool = True, tag: str = "",
             save_hlo: bool = True):
    cell = f"{cfg.name}__{shape.name}__{mesh_label}" + (f"__{tag}" if tag else "")
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    if save_hlo:
        import gzip
        out_dir.mkdir(parents=True, exist_ok=True)
        with gzip.open(out_dir / f"{cell}.hlo.gz", "wt") as f:
            f.write(hlo)
    coll_bytes, coll_counts = parse_collectives(hlo)
    # loop-aware accounting (XLA's cost_analysis visits while bodies once;
    # hlo_analysis multiplies by trip counts) — this is what §Roofline uses
    loop_aware = hlo_analysis.analyze(hlo)
    record = {
        "cell": cell,
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": mesh_label,
        "tag": tag,
        "n_devices": int(mesh.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _memory_analysis_dict(compiled),
        "cost_analysis": _cost_analysis_dict(compiled),
        "hlo_analysis": loop_aware,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(record, indent=1))
    if verbose:
        ma = record["memory_analysis"]
        ca = record["cost_analysis"]
        print(f"[OK] {cell}: lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args {ma.get('argument_size_in_bytes', 0)/2**30:.2f} GiB/dev "
              f"temp {ma.get('temp_size_in_bytes', 0)/2**30:.2f} GiB/dev | "
              f"flops/dev {loop_aware['flops']:.3e} | "
              f"coll {loop_aware['total_collective_bytes']/2**30:.3f} GiB/dev")
        sys.stdout.flush()
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mesh", type=str, default=None,
                    help="override mesh shape, e.g. '2,4' or '2,2,2' (testing)")
    ap.add_argument("--out", type=str, default=str(ARTIFACT_DIR))
    ap.add_argument("--set", action="append", default=[], metavar="FIELD=VAL",
                    help="ArchConfig override, e.g. --set remat=none "
                         "--set param_dtype=bfloat16 (hillclimb variants)")
    ap.add_argument("--tag", type=str, default="",
                    help="artifact suffix for variant runs")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip writing the gzipped HLO artifact")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = []
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        meshes.append((mesh_mod.make_mesh(shape), "x".join(map(str, shape))))
    else:
        if args.multi_pod in ("single", "both"):
            meshes.append((mesh_mod.make_production_mesh(multi_pod=False), "pod16x16"))
        if args.multi_pod in ("multi", "both"):
            meshes.append((mesh_mod.make_production_mesh(multi_pod=True), "2pod2x16x16"))

    if args.all:
        cells = registry.all_cells()
    else:
        cfg = registry.get(args.arch)
        shapes = [SHAPES[args.shape]] if args.shape else valid_cells(cfg)
        cells = [(cfg, s) for s in shapes]

    if args.set:
        import dataclasses as _dc
        overrides = {}
        for kv in args.set:
            k, v = kv.split("=", 1)
            fld = {f.name: f for f in _dc.fields(ArchConfig)}[k]
            if fld.type in ("bool", bool):
                v = v.lower() in ("1", "true", "yes")
            elif fld.type in ("int", int):
                v = int(v)
            elif fld.type in ("float", float):
                v = float(v)
            overrides[k] = v
        cells = [(_dc.replace(c, **overrides), s) for c, s in cells]

    failures = []
    for mesh, label in meshes:
        for cfg, shape in cells:
            try:
                run_cell(cfg, shape, mesh, label, out_dir, tag=args.tag,
                         save_hlo=not args.no_hlo)
            except Exception as e:  # noqa: BLE001 — report every cell
                failures.append((cfg.name, shape.name, label, repr(e)))
                print(f"[FAIL] {cfg.name}__{shape.name}__{label}: {e}")
                traceback.print_exc()
                sys.stdout.flush()

    print(f"\n{len(cells) * len(meshes) - len(failures)} passed, "
          f"{len(failures)} failed")
    if failures:
        for f in failures:
            print("  FAIL:", *f)
        sys.exit(1)


if __name__ == "__main__":
    main()
