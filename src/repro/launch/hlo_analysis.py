"""Loop-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` visits every while-loop body exactly ONCE
(verified empirically), so for scan-over-layers models it undercounts FLOPs,
bytes and collective traffic by the trip count (≈ n_layers, and ≈ n_chunks²
inside the chunked attention).  This module re-derives the roofline inputs by
walking the HLO call graph and multiplying while bodies by their trip counts.

What is counted:
  * FLOPs — dot: 2·|result|·k_contract; convolution: 2·|result|·(spatial·Cin);
    tallied per result dtype so the int8 (s32-accumulate) MXU path can use the
    2× int8 peak in the roofline.
  * bytes — per-op operand+result bytes at fusion granularity (a fusion's
    internals stay in registers/VMEM, so only the fusion op's own operands and
    result count — this mirrors XLA's bytes-accessed model).
  * collective bytes — operand bytes per collective kind (async *-start
    counted once, *-done skipped).

Trip counts come from the largest integer constant in a while op's condition
computation — exact for every `lax.scan`/`fori_loop` (static trip), which is
the only loop source in this codebase.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_TYPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")

_SKIP_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "copy", "after-all", "partition-id", "replica-id", "iota"}

# Ops whose operands/results are necessarily materialized in HBM on TPU.
# Elementwise chains, broadcasts, reshapes, converts etc. are fused into
# their consumers by XLA:TPU, so for the *memory roofline term* only these
# count; the CPU backend we lower on barely fuses, which would otherwise
# wildly overestimate HBM traffic (bytes_accessed keeps the raw count).
_MATERIALIZE_OPS = {"dot", "convolution", "fusion", "concatenate", "pad",
                    "gather", "scatter", "dynamic-slice",
                    "dynamic-update-slice", "sort", "reduce", "reduce-window",
                    "select-and-scatter", "custom-call", "cholesky",
                    "triangular-solve", "rng", "rng-bit-generator"}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(m: Tuple[str, str]) -> int:
    return _shape_elems(m[1]) * _BYTES[m[0]]


@dataclasses.dataclass
class Op:
    name: str
    result_types: List[Tuple[str, str]]       # [(dtype, dims), ...]
    opname: str
    args: List[str]                            # operand %names
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, List[Tuple[str, str]]]
    ops: List[Op]


_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        # strip /*index=N*/ comments — they contain '=' and break op parsing
        line = re.sub(r"/\*.*?\*/", "", raw).strip()
        if not line:
            continue
        if line.endswith("{") and ("(" in line) and ("=" not in line.split("(")[0]):
            m = _COMP_HDR_RE.match(line)
            if m:
                name, params_str = m.group(1), m.group(2)
                params = {}
                # a param type is either a tuple (...) or one dtype[shape]{layout}
                for pm in re.finditer(
                        r"([\w.\-]+):\s*(\([^)]*\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)",
                        params_str):
                    params[pm.group(1)] = _TYPE_RE.findall(pm.group(2))
                cur = Computation(name, params, [])
                comps[name] = cur
                if line.startswith("ENTRY"):
                    entry = name
                continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        opn = m.group(3)
        rest = m.group(4)
        # operands: %names before the closing paren of the call
        depth = 1
        i = 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        call_args = rest[:i - 1] if depth == 0 else rest
        attrs = rest[i:] if depth == 0 else ""
        args = re.findall(r"%([\w.\-]+)", call_args)
        cur.ops.append(Op(m.group(1), _TYPE_RE.findall(m.group(2)), opn,
                          args, attrs, line))
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops_by_dtype: Dict[str, float]
    bytes_accessed: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    hbm_bytes: float = 0.0

    @staticmethod
    def zero() -> "Cost":
        return Cost({}, 0.0, {k: 0.0 for k in COLLECTIVE_KINDS},
                    {k: 0.0 for k in COLLECTIVE_KINDS}, 0.0)

    def add(self, other: "Cost", mult: float = 1.0):
        for k, v in other.flops_by_dtype.items():
            self.flops_by_dtype[k] = self.flops_by_dtype.get(k, 0.0) + v * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in COLLECTIVE_KINDS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult

    @property
    def flops(self) -> float:
        return sum(self.flops_by_dtype.values())

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class HloAnalyzer:
    def __init__(self, hlo: str):
        self.comps, self.entry = parse_computations(hlo)
        self._memo: Dict[str, Cost] = {}

    # ---------------- symbol table ----------------

    def _types_of(self, comp: Computation, name: str) -> List[Tuple[str, str]]:
        for op in comp.ops:
            if op.name == name:
                return op.result_types
        if name in comp.params and comp.params[name]:
            return comp.params[name]
        return []

    # ---------------- per-op costs ----------------

    def _dot_flops(self, comp: Computation, op: Op) -> Tuple[str, float]:
        res = op.result_types
        if not res:
            return "f32", 0.0
        dtype, dims = res[0]
        out_elems = _shape_elems(dims)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        contract = 1
        if m and op.args:
            lhs_types = self._types_of(comp, op.args[0])
            if lhs_types:
                lhs_dims = [int(x) for x in lhs_types[0][1].split(",") if x]
                for ci in m.group(1).split(","):
                    if ci:
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            contract *= lhs_dims[ci]
        return dtype, 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, op: Op) -> Tuple[str, float]:
        res = op.result_types
        if not res or len(op.args) < 2:
            return "f32", 0.0
        dtype, dims = res[0]
        out_elems = _shape_elems(dims)
        k_types = self._types_of(comp, op.args[1])
        if not k_types:
            return dtype, 0.0
        k_dims = [int(x) for x in k_types[0][1].split(",") if x]
        # dim_labels=...io->...: 'o' position in kernel labels
        m = re.search(r"dim_labels=[^_]*_([0-9a-z]+)->", op.attrs)
        out_feat = 1
        if m:
            labels = m.group(1)
            if "o" in labels and len(labels) == len(k_dims):
                out_feat = k_dims[labels.index("o")]
        per_out = 1
        for d in k_dims:
            per_out *= d
        per_out //= max(out_feat, 1)
        fgc = re.search(r"feature_group_count=(\d+)", op.attrs)
        if fgc:
            per_out //= max(int(fgc.group(1)), 1)
        return dtype, 2.0 * out_elems * per_out

    # ---------------- aggregation ----------------

    def _trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        best = 1
        for op in comp.ops:
            for m in re.finditer(r"constant\((\d+)\)", op.line):
                best = max(best, int(m.group(1)))
        return float(best)

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost.zero()
        if comp is None:
            self._memo[comp_name] = total
            return total
        self._memo[comp_name] = total    # break cycles defensively
        for op in comp.ops:
            opn = op.opname
            if opn in _SKIP_OPS:
                continue
            # --- control flow / calls ---
            if opn == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                # exact trip count from XLA's backend_config when present
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
                if mt:
                    trip = float(mt.group(1))
                else:
                    trip = self._trip_count(mc.group(1)) if mc else 1.0
                if mb:
                    total.add(self.cost_of(mb.group(1)), mult=trip)
                continue
            if opn == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"true_computation=%?([\w.\-]+)|"
                                     r"false_computation=%?([\w.\-]+))", op.attrs):
                    for g in m.groups():
                        if g:
                            for nm in re.findall(r"%?([\w.\-]+)", g):
                                total.add(self.cost_of(nm), mult=1.0)
                continue
            if opn == "fusion":
                mcalls = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if mcalls:
                    inner = self.cost_of(mcalls.group(1))
                    # flops & collectives from inside; bytes at fusion boundary
                    only_compute = Cost(dict(inner.flops_by_dtype), 0.0,
                                        dict(inner.collective_bytes),
                                        dict(inner.collective_counts), 0.0)
                    total.add(only_compute)
                total.bytes_accessed += self._io_bytes(comp, op)
                total.hbm_bytes += self._fusion_hbm_traffic(comp, op)
                continue
            if opn in ("call", "async-start"):
                mcalls = re.search(r"(?:calls|called_computation)=%?([\w.\-]+)",
                                   op.attrs)
                if mcalls:
                    total.add(self.cost_of(mcalls.group(1)))
                continue
            # --- collectives ---
            kind = next((k for k in COLLECTIVE_KINDS if opn.startswith(k)), None)
            if kind is not None:
                if opn.endswith("-done"):
                    continue
                ob = sum(_type_bytes(t) for a in op.args
                         for t in self._types_of(comp, a))
                if ob == 0:
                    ob = sum(_type_bytes(t) for t in op.result_types)
                total.collective_bytes[kind] += ob
                total.collective_counts[kind] += 1
                io = self._io_bytes(comp, op)
                total.bytes_accessed += io
                total.hbm_bytes += io
                continue
            # --- compute ---
            if opn == "dot":
                dt, fl = self._dot_flops(comp, op)
                total.flops_by_dtype[dt] = total.flops_by_dtype.get(dt, 0.0) + fl
            elif opn == "convolution":
                dt, fl = self._conv_flops(comp, op)
                total.flops_by_dtype[dt] = total.flops_by_dtype.get(dt, 0.0) + fl
            io = self._io_bytes(comp, op)
            total.bytes_accessed += io
            if opn in _MATERIALIZE_OPS:
                total.hbm_bytes += self._op_hbm_traffic(comp, op)
        self._memo[comp_name] = total
        return total

    def _io_bytes(self, comp: Computation, op: Op) -> float:
        b = sum(_type_bytes(t) for t in op.result_types)
        for a in op.args:
            b += sum(_type_bytes(t) for t in self._types_of(comp, a))
        return float(b)

    # ---------------- slice-aware HBM traffic ----------------
    #
    # XLA performs dynamic-update-slice IN PLACE (the result buffer aliases
    # the target operand) and dynamic-slice touches only the slice region.
    # Loop-residual stacking (`lax.scan` saving per-step values) compiles to
    # exactly these ops over buffers n× larger than the touched slice, so
    # counting full operand/result sizes overstates scan-body HBM traffic by
    # the trip count — ~8× on an 8-chunk attention, ~n_layers× on layer
    # scans.  hbm_bytes uses the slice-aware model; bytes_accessed keeps the
    # raw (upper-bound) accounting for comparison.

    def _op_hbm_traffic(self, comp: Computation, op: Op) -> float:
        if op.opname == "dynamic-slice":
            return 2.0 * sum(_type_bytes(t) for t in op.result_types)
        if op.opname == "dynamic-update-slice":
            upd = sum(_type_bytes(t)
                      for t in self._types_of(comp, op.args[1])) \
                if len(op.args) > 1 else 0.0
            return 2.0 * upd
        if op.opname == "fusion":
            return self._fusion_hbm_traffic(comp, op)
        return self._io_bytes(comp, op)

    # Ops that neither move nor transform layout-significant data on TPU
    # (convert is NOT free in general, but a convert of a buffer that is
    # immediately DUS'd in place models as a fused element-wise epilogue).
    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape")

    def _fusion_hbm_traffic(self, comp: Computation, op: Op) -> float:
        mcalls = re.search(r"calls=%?([\w.\-]+)", op.attrs)
        inner = self.comps.get(mcalls.group(1)) if mcalls else None
        if inner is None:
            return self._io_bytes(comp, op)

        # map the fused computation's parameters to operand positions
        param_idx: Dict[str, int] = {}
        by_name: Dict[str, Op] = {}
        for iop in inner.ops:
            by_name[iop.name] = iop
            if iop.opname == "parameter":
                m = re.search(r"parameter\((\d+)\)", iop.line)
                if m:
                    param_idx[iop.name] = int(m.group(1))

        def resolve(name: str) -> str:
            """Follow convert/bitcast/copy/reshape chains back to a source."""
            seen = set()
            while name in by_name and name not in seen:
                seen.add(name)
                iop = by_name[name]
                if iop.opname in self._TRANSPARENT and len(iop.args) == 1:
                    name = iop.args[0]
                else:
                    break
            return name

        root = inner.ops[-1] if inner.ops else None
        for iop in inner.ops:
            if iop.line.startswith("ROOT "):
                root = iop
        if root is not None and root.opname in self._TRANSPARENT \
                and len(root.args) == 1 and root.args[0] in by_name:
            r = by_name[resolve(root.name)]
            root = r if r is not root else root

        # params consumed ONLY via dynamic-slice (or as a DUS target) are
        # touched at slice granularity, not buffer granularity
        sliced_bytes: Dict[int, float] = {}
        sliced_only: Dict[int, bool] = {}
        for iop in inner.ops:
            if iop.opname in ("parameter",) + self._TRANSPARENT:
                continue
            for ai, a in enumerate(iop.args):
                a = resolve(a)
                if a not in param_idx:
                    continue
                pidx = param_idx[a]
                if iop.opname == "dynamic-slice" and ai == 0:
                    sliced_bytes[pidx] = sliced_bytes.get(pidx, 0.0) + \
                        2.0 * sum(_type_bytes(t) for t in iop.result_types)
                    sliced_only.setdefault(pidx, True)
                elif iop.opname == "dynamic-update-slice" and ai == 0:
                    sliced_only.setdefault(pidx, True)    # aliased in place
                else:
                    sliced_only[pidx] = False

        total = 0.0
        for i, a in enumerate(op.args):
            full = float(sum(_type_bytes(t) for t in self._types_of(comp, a)))
            if sliced_only.get(i, False):
                total += min(sliced_bytes.get(i, 0.0), full)
            else:
                total += full

        # result side: in-place DUS roots write only the update slice.  A
        # multi-output fusion (scan body emitting several ys, e.g. the K and
        # V cache pages) roots at a TUPLE of DUS ops — discount each element.
        def dus_write(iop) -> Optional[float]:
            if iop is not None and iop.opname == "dynamic-update-slice" \
                    and iop.args and resolve(iop.args[0]) in param_idx:
                return 2.0 * sum(
                    _type_bytes(t)
                    for t in (self._types_of(inner, iop.args[1])
                              if len(iop.args) > 1 else []))
            return None

        if root is not None and root.opname == "tuple":
            for j, a in enumerate(root.args):
                w = dus_write(by_name.get(resolve(a)))
                if w is not None:
                    total += w
                elif j < len(op.result_types):
                    total += float(_type_bytes(op.result_types[j]))
        else:
            w = dus_write(root)
            if w is not None:
                total += w
            else:
                total += float(sum(_type_bytes(t) for t in op.result_types))
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost.zero()
        return self.cost_of(self.entry)


def analyze(hlo: str) -> Dict:
    cost = HloAnalyzer(hlo).entry_cost()
    return {
        "flops": cost.flops,
        "flops_by_dtype": cost.flops_by_dtype,
        "bytes_accessed": cost.bytes_accessed,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_counts": cost.collective_counts,
        "total_collective_bytes": cost.total_collective_bytes,
    }
