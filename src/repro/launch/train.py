"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1 [--reduced]

Wires together: config registry → mesh → data pipeline → fault-tolerant
training driver (checkpoint/restart, corruption detection) → metrics log.
On this CPU container use ``--reduced`` (same family, small dims); on a TPU
fleet the same entrypoint runs the full config — the mesh/launcher layers
are identical, only the device count changes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs import registry
from repro.models.config import ShapeConfig, reduced
from repro.runtime import ft_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", type=str, default=None)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, name=cfg.name)  # frozen copy

    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    ft = ft_loop.FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                          seed=args.seed)

    print(f"[train] arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq} "
          f"devices={jax.device_count()}")
    t0 = time.time()
    rep = ft_loop.run(cfg, shape, ft, n_steps=args.steps, lr=args.lr)
    dt = time.time() - t0

    toks = args.steps * args.batch * args.seq
    print(f"[train] done in {dt:.1f}s  ({toks/dt:.0f} tok/s)  "
          f"loss {rep.losses[0]:.4f} → {rep.losses[-1]:.4f}  "
          f"recoveries={rep.recoveries}")
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps({
            "arch": cfg.name, "losses": rep.losses, "wall_s": dt,
            "tokens_per_s": toks / dt, "recoveries": rep.recoveries}))


if __name__ == "__main__":
    main()
