"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init.

Topology (TPU v5e): one pod = 16×16 = 256 chips; multi-pod = 2 pods = 512.
  single-pod axes: ("data", "model")         = (16, 16)
  multi-pod axes:  ("pod", "data", "model")  = (2, 16, 16)
The "model" axis carries TP + EP (intra-pod, fastest ICI); "data" carries
DP + FSDP; "pod" is pure DP (or pipeline stages, see parallel/pipeline.py)
across the slower pod interconnect.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (tests / reduced dry-runs)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
