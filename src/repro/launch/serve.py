"""Serving launcher — batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 16 --max-new 24 [--dependability snapshot]

The paper's execution flow in TPU terms: the Engine (Klepsydra analogue)
admits requests into a fixed decode batch, the jitted step (HPDP analogue)
streams tokens out, and snapshots bound the replay window after a fault.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax

from repro.configs import registry
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-drill", action="store_true",
                    help="inject an SEU mid-serve and prove recovery")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    print(f"[serve] arch={cfg.name} capacity={args.capacity} "
          f"requests={args.requests}")
    params = model_api.init_params(cfg, jax.random.key(args.seed))
    eng = Engine(cfg, params, capacity=args.capacity, max_len=args.max_len,
                 snapshot_every=8)

    import numpy as np
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(3, 17))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        r = Request(uid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)

    t0 = time.time()
    if args.fault_drill:
        for _ in range(5):
            eng.step()
        print("[serve] injecting SEU into decode state …")
        eng.tokens = eng.tokens.at[0].set(99999 % cfg.vocab_size)
        lost = eng.restore_snapshot()
        print(f"[serve] rolled back {lost} steps from snapshot")
    stats = eng.run()
    dt = time.time() - t0

    lat = [r.finished_at - r.submitted_at for r in reqs if r.finished_at]
    print(f"[serve] {stats.tokens_out} tokens in {dt:.2f}s "
          f"({stats.tokens_out/dt:.1f} tok/s), steps={stats.steps}, "
          f"replays={stats.replays}")
    if lat:
        print(f"[serve] latency p50={statistics.median(lat):.2f}s "
              f"max={max(lat):.2f}s")
    assert all(len(r.output) >= 1 for r in reqs)
    print("[serve] all requests completed")


if __name__ == "__main__":
    main()
