"""jit'd public wrapper for flash attention (fwd + custom-VJP bwd kernels).

On TPU the Pallas kernels run compiled; on CPU (this container) the kernel
bodies execute under ``interpret=True`` for correctness tests, while model
code uses the jnp reference (XLA fuses it acceptably on CPU).  Layout
adapter: models carry (B, S, H, hd); the kernel wants (B, H, S, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flashattn.kernel import (
    flash_attention, flash_attention_bwd, flash_attention_fwd_lse)
from repro.kernels.flashattn.ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attn_diff(q, k, v, causal=True, window=None, block_q=128,
                    block_k=128, interpret=False):
    """Differentiable flash attention: fwd AND bwd are Pallas kernels.

    q (B,H,S,hd), k/v (B,KV,S,hd) → (B,H,S,hd).  The backward recomputes
    probability blocks from the saved logsumexp (Dao 2022) — the (S,S)
    score matrix never exists in HBM in either pass.
    """
    out, _ = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    return out


def _fad_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out, lse = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                       block_q=block_q, block_k=block_k,
                                       interpret=interpret)
    return out, (q, k, v, out, lse)


def _fad_bwd(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, do, causal=causal,
                                     window=window, block_q=block_q,
                                     block_k=block_k, interpret=interpret)
    return dq, dk, dv


flash_attn_diff.defvjp(_fad_fwd, _fad_bwd)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attn(q, k, v, *, causal: bool = True, window: int | None = None,
               interpret: bool | None = None) -> jax.Array:
    """q (B, S, H, hd), k/v (B, S, KV, hd) → (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if interpret is None:
        interpret = not _on_tpu()
    if _on_tpu() or interpret:
        if _on_tpu():
            out = flash_attention(qt, kt, vt, causal=causal, window=window)
        else:
            out = flash_attention(qt, kt, vt, causal=causal, window=window,
                                  interpret=True)
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    return jnp.swapaxes(out, 1, 2)


def flash_attn_model(q, k, v, *, causal=True, window=None,
                     block_q=128, block_k=128, interpret=None):
    """Differentiable model-layout wrapper: (B, S, H, hd) in/out, Pallas
    fwd+bwd kernels underneath (interpret on CPU)."""
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    S = qt.shape[2]
    bq, bk = min(block_q, S), min(block_k, S)
    out = flash_attn_diff(qt, kt, vt, causal, window, bq, bk, interpret)
    return jnp.swapaxes(out, 1, 2)
