"""Pallas TPU kernel: fused causal (optionally sliding-window) attention.

Beyond-paper optimization in the paper's own spirit: the HPDP insight is
*keep the stream inside the array* — conv and requant execute back-to-back
with no HBM round-trip.  Prefill attention has the same structure at
transformer scale: QKᵀ → softmax → PV materializes an (S × S) score matrix
in HBM if done naively.  This kernel streams K/V blocks through VMEM with an
online-softmax accumulator, so scores never leave the chip.

TPU codesign notes:
  * Grid (B·H, S/bq, S/bk), K innermost ("arbitrary"); the (bq, hd) f32
    accumulator + (bq,) running max/denominator live in VMEM scratch across
    K steps (the same revisiting pattern as qmatmul's int32 accumulator).
  * Causality is exploited at *grid* granularity: blocks entirely above the
    diagonal are skipped via ``pl.when`` (≈2× prefill FLOPs saved), and
    entirely-valid blocks skip the mask computation.
  * GQA folds into the grid: q-head h reads kv-head h // (H/KV) via the
    K/V BlockSpec index_map — no KV replication in HBM.
  * Sliding window (mixtral, recurrentgemma local attn) masks per-element
    and skips out-of-window blocks at grid level.
  * bq = bk = 128 default: MXU-aligned; working set ≈ 128·hd·(3 f32) +
    128·128 f32 ≈ 0.3 MB for hd=128 — double-buffers comfortably in VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, seq_len: int, block_q: int, block_k: int,
                  window: int | None, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k

    # does this block intersect the causal/window band at all?
    intersects = True
    if causal:
        intersects = k_lo <= q_lo + block_q - 1          # not above diagonal
    if window is not None:
        # lowest visible key for the *last* query row of the block
        intersects = jnp.logical_and(
            intersects, k_lo + block_k - 1 >= q_lo - window)

    @pl.when(intersects)
    def _attend():
        q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        # K-tail: out-of-bounds rows of the padded block are undefined; a
        # masked probability of exactly 0 still yields NaN via 0·NaN in p@v,
        # so zero the rows themselves.
        vrow = k_lo + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(vrow < seq_len, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len                             # K tail padding
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)                   # rescale old acc
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,            # (B, H, S, hd)
    k: jax.Array,            # (B, KV, S, hd)
    v: jax.Array,            # (B, KV, S, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, hd = q.shape
    KV = k.shape[1]
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, S)
    block_k = min(block_k, S)
    grid = (B * H, pl.cdiv(S, block_q), pl.cdiv(S, block_k))

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        h = bh % H
        b = bh // H
        return (b * KV + h // G, ki, 0)

    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * KV, S, hd)
    vr = v.reshape(B * KV, S, hd)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, seq_len=S,
                          block_q=block_q, block_k=block_k,
                          window=window, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd)


# ---------------------------------------------------------------------------
# Checked forward: fused two-tier ABFT outputs (docs/backends.md)
#
# Attention is float, so the exact mod-2^32 operand identity qmatmul enjoys
# does not exist for the *compute* path.  The checked kernel therefore emits
# two check outputs per query row, fused into the same pass:
#
#   check  (f32)  — an independent accumulation of rowsum_hd(out), carried
#                   through the online softmax alongside m/l/acc
#                   (c ← c·α + p · rowsum_hd(v)); verified with a tolerance,
#                   this covers the compute path (MXU/accumulator faults that
#                   perturb the math).
#   csum  (u32)   — the exact per-row mod-2^32 sum of the emitted output's
#                   bit patterns (``abft.storage_checksums`` at row
#                   granularity), computed in the epilogue from the very
#                   block written to HBM.  Verification is bit-exact, so ANY
#                   single-bit flip of the output between kernel and consumer
#                   is detected — zero false negatives, certifiable at 1.0.
# ---------------------------------------------------------------------------


def _flash_checked_kernel(q_ref, k_ref, v_ref, o_ref, chk_ref, csum_ref,
                          m_ref, l_ref, acc_ref, c_ref, *,
                          scale: float, seq_len: int, block_q: int,
                          block_k: int, window: int | None, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    intersects = True
    if causal:
        intersects = k_lo <= q_lo + block_q - 1
    if window is not None:
        intersects = jnp.logical_and(
            intersects, k_lo + block_k - 1 >= q_lo - window)

    @pl.when(intersects)
    def _attend():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        vrow = k_lo + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(vrow < seq_len, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # in-path check column: contract the probabilities with rowsum_hd(v)
        # — an accumulation independent of the (bq, hd) accumulator above,
        # tracking rowsum_hd(acc) through the same online rescaling
        v1 = jnp.sum(v, axis=-1)                          # (bk,)
        c_ref[...] = c_ref[...] * alpha + jnp.sum(p * v1[None, :], axis=-1)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0] = out
        chk_ref[0] = c_ref[...] / l
        if out.dtype == jnp.float32:
            bits = jax.lax.bitcast_convert_type(out, jnp.uint32)
        else:                                             # bf16 / f16 I/O
            bits = jax.lax.bitcast_convert_type(out, jnp.uint16).astype(
                jnp.uint32)
        csum_ref[0] = jnp.sum(bits, axis=-1)              # wraps mod 2^32


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_checked(
    q: jax.Array,            # (B, H, S, hd)
    k: jax.Array,            # (B, KV, S, hd)
    v: jax.Array,            # (B, KV, S, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Forward attention returning ``(out, check, csum)``.

    ``out`` (B,H,S,hd) as ``flash_attention``; ``check`` (B,H,S) f32 is the
    fused independent rowsum-of-output column (tolerance-verified);
    ``csum`` (B,H,S) u32 is the exact per-row bit checksum of ``out``
    (bit-exact verification; see ``core.abft.output_row_checksums``).
    """
    B, H, S, hd = q.shape
    KV = k.shape[1]
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    grid = (B * H, pl.cdiv(S, block_q), pl.cdiv(S, block_k))

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        h = bh % H
        b = bh // H
        return (b * KV + h // G, ki, 0)

    def row_map(bh, qi, ki):
        return (bh, qi)

    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * KV, S, hd)
    vr = v.reshape(B * KV, S, hd)
    out, check, csum = pl.pallas_call(
        functools.partial(_flash_checked_kernel, scale=scale, seq_len=S,
                          block_q=block_q, block_k=block_k,
                          window=window, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=[pl.BlockSpec((1, block_q, hd), q_map),
                   pl.BlockSpec((1, block_q), row_map),
                   pl.BlockSpec((1, block_q), row_map)],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((B * H, S), jnp.float32),
                   jax.ShapeDtypeStruct((B * H, S), jnp.uint32)],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return (out.reshape(B, H, S, hd), check.reshape(B, H, S),
            csum.reshape(B, H, S))


# ---------------------------------------------------------------------------
# Backward kernels (Dao 2022 two-pass formulation, TPU-adapted)
#
#   D  = rowsum(dO ∘ O)                       (computed outside, elementwise)
#   P  = exp(QKᵀ·s − L)            (recomputed per block from the saved lse)
#   dV = Pᵀ dO
#   dP = dO Vᵀ
#   dQ = s · [P ∘ (dP − D)] K      (kernel 1: grid over q blocks, scan kv)
#   dK = s · [P ∘ (dP − D)]ᵀ Q     (kernel 2: grid over kv blocks, scan q·G)
#
# The dkv kernel grids over B·KV (not B·H) so GQA head-group gradients
# accumulate in VMEM scratch instead of colliding across grid cells.
# ---------------------------------------------------------------------------


def _flash_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                          m_ref, l_ref, acc_ref, *,
                          scale, seq_len, block_q, block_k, window, causal):
    """Forward that also emits the logsumexp rows needed by the backward."""
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    intersects = True
    if causal:
        intersects = k_lo <= q_lo + block_q - 1
    if window is not None:
        intersects = jnp.logical_and(
            intersects, k_lo + block_k - 1 >= q_lo - window)

    @pl.when(intersects)
    def _attend():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        vrow = k_lo + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(vrow < seq_len, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def _recompute_p(q, k, lse_rows, q_lo, k_lo, *, scale, seq_len, block_q,
                 block_k, window, causal):
    """Rebuild the probability block from saved logsumexp rows."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window is not None:
        mask = jnp.logical_and(mask, kpos >= qpos - window)
    p = jnp.where(mask, jnp.exp(s - lse_rows[:, None]), 0.0)
    # q tail rows (beyond seq_len) have lse=0 → exp(s) garbage; zero them
    qvalid = qpos < seq_len
    return jnp.where(qvalid, p, 0.0)


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                         dq_ref, acc_ref, *,
                         scale, seq_len, block_q, block_k, window, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k
    intersects = True
    if causal:
        intersects = k_lo <= q_lo + block_q - 1
    if window is not None:
        intersects = jnp.logical_and(
            intersects, k_lo + block_k - 1 >= q_lo - window)

    @pl.when(intersects)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        krow = k_lo + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
        k = jnp.where(krow < seq_len, k, 0.0)
        v = jnp.where(krow < seq_len, v, 0.0)
        p = _recompute_p(q, k, lse_ref[0], q_lo, k_lo, scale=scale,
                         seq_len=seq_len, block_q=block_q, block_k=block_k,
                         window=window, causal=causal)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        # q-tail rows: OOB dvec/lse are undefined; 0·NaN = NaN would leak
        qrow1 = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)
        dvec = jnp.where(qrow1 < seq_len, dvec_ref[0], 0.0)
        ds = p * (dp - dvec[:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _epilogue():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *,
                          scale, seq_len, block_q, block_k, window, causal,
                          n_q_steps):
    ki = pl.program_id(1)
    step = pl.program_id(2)          # enumerates (g, qi) pairs

    @pl.when(step == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qi = step % n_q_steps
    q_lo = qi * block_q
    k_lo = ki * block_k
    intersects = True
    if causal:
        intersects = k_lo <= q_lo + block_q - 1
    if window is not None:
        intersects = jnp.logical_and(
            intersects, k_lo + block_k - 1 >= q_lo - window)

    @pl.when(intersects)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        qrow = q_lo + jax.lax.broadcasted_iota(jnp.int32, q.shape, 0)
        q = jnp.where(qrow < seq_len, q, 0.0)
        do = jnp.where(qrow < seq_len, do, 0.0)
        p = _recompute_p(q, k, lse_ref[0], q_lo, k_lo, scale=scale,
                         seq_len=seq_len, block_q=block_q, block_k=block_k,
                         window=window, causal=causal)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        # q-tail rows: OOB dvec is undefined; 0·NaN would poison the
        # q-contraction in dk below
        qrow1 = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)
        dvec = jnp.where(qrow1 < seq_len, dvec_ref[0], 0.0)
        ds = p * (dp - dvec[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(step == pl.num_programs(2) - 1)
    def _epilogue():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_fwd_lse(q, k, v, *, causal=True, window=None,
                            block_q=128, block_k=128, interpret=False):
    """Forward returning (out, lse); layout as flash_attention."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    grid = (B * H, pl.cdiv(S, block_q), pl.cdiv(S, block_k))

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        h = bh % H
        b = bh // H
        return (b * KV + h // G, ki, 0)

    def lse_map(bh, qi, ki):
        return (bh, qi)

    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * KV, S, hd)
    vr = v.reshape(B * KV, S, hd)
    out, lse = pl.pallas_call(
        functools.partial(_flash_fwd_lse_kernel, scale=scale, seq_len=S,
                          block_q=block_q, block_k=block_k, window=window,
                          causal=causal),
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_q, hd), q_map),
                  pl.BlockSpec((1, block_k, hd), kv_map),
                  pl.BlockSpec((1, block_k, hd), kv_map)],
        out_specs=[pl.BlockSpec((1, block_q, hd), q_map),
                   pl.BlockSpec((1, block_q), lse_map)],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((B * H, S), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd), lse.reshape(B, H, S)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, window=None,
                        block_q=128, block_k=128, interpret=False):
    """Returns (dq, dk, dv). q (B,H,S,hd), k/v (B,KV,S,hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(S, block_k)

    dvec = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1)                                   # (B, H, S)
    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * KV, S, hd)
    vr = v.reshape(B * KV, S, hd)
    dor = do.reshape(B * H, S, hd)
    lser = lse.reshape(B * H, S)
    dvr = dvec.reshape(B * H, S)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        h = bh % H
        b = bh // H
        return (b * KV + h // G, ki, 0)

    def lse_map(bh, qi, ki):
        return (bh, qi)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, seq_len=S,
                          block_q=block_q, block_k=block_k, window=window,
                          causal=causal),
        grid=(B * H, nq, nk),
        in_specs=[pl.BlockSpec((1, block_q, hd), q_map),
                  pl.BlockSpec((1, block_k, hd), kv_map),
                  pl.BlockSpec((1, block_k, hd), kv_map),
                  pl.BlockSpec((1, block_q, hd), q_map),
                  pl.BlockSpec((1, block_q), lse_map),
                  pl.BlockSpec((1, block_q), lse_map)],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, dor, lser, dvr)

    # dkv: grid over B·KV so head-group grads accumulate in scratch
    def kv_map2(bkv, ki, step):
        return (bkv, ki, 0)

    def q_map2(bkv, ki, step):
        b = bkv // KV
        kvh = bkv % KV
        g = step // nq
        qi = step % nq
        return (b * H + kvh * G + g, qi, 0)

    def lse_map2(bkv, ki, step):
        b = bkv // KV
        kvh = bkv % KV
        g = step // nq
        qi = step % nq
        return (b * H + kvh * G + g, qi)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale, seq_len=S,
                          block_q=block_q, block_k=block_k, window=window,
                          causal=causal, n_q_steps=nq),
        grid=(B * KV, nk, G * nq),
        in_specs=[pl.BlockSpec((1, block_q, hd), q_map2),
                  pl.BlockSpec((1, block_k, hd), kv_map2),
                  pl.BlockSpec((1, block_k, hd), kv_map2),
                  pl.BlockSpec((1, block_q, hd), q_map2),
                  pl.BlockSpec((1, block_q), lse_map2),
                  pl.BlockSpec((1, block_q), lse_map2)],
        out_specs=[pl.BlockSpec((1, block_k, hd), kv_map2),
                   pl.BlockSpec((1, block_k, hd), kv_map2)],
        out_shape=[jax.ShapeDtypeStruct((B * KV, S, hd), k.dtype),
                   jax.ShapeDtypeStruct((B * KV, S, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr, dor, lser, dvr)

    return (dq.reshape(B, H, S, hd), dk.reshape(B, KV, S, hd),
            dv.reshape(B, KV, S, hd))
