"""Pure-jnp oracle for the flash attention kernel (f32, materialized scores)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None) -> jax.Array:
    """q (B,H,S,hd), k/v (B,KV,S,hd) → (B,H,S,hd). Materializes (S,S)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos >= qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
