"""Pallas TPU kernel: int8 matmul with int32 accumulation + fused requantization.

This is the transformer-shaped rendition of the paper's HPDP dataflow
configuration: *one* compiled kernel whose weights, bias, zero-points and
requantization scales are all runtime operands — every layer of every model
reuses the same configuration, exactly like the XPP array is configured once
and then driven purely by streamed parameters.

Design notes (TPU codesign):
  * int8 × int8 → int32 runs natively on the MXU (v5e: 394 TOPS int8, 2× bf16).
  * The K reduction is the innermost grid dimension; an int32 VMEM scratch
    accumulator carries partial sums across K steps (revisiting pattern).
  * Requantization is fused into the epilogue of the *last* K step: the
    accumulator never leaves VMEM — one HBM write of int8 output instead of
    int32 intermediate + separate requant pass (4× less traffic than an
    unfused pipeline, mirroring the paper's "conv and requant process the
    stream in parallel" design).
  * fp32 requantization (round-half-to-even) — see core/quant.py docstring.
  * Default blocks: (128, 128) output tile, K-block 512.  MXU-aligned
    (multiples of 128 on both matmul dims); working set 128·512 + 512·128 int8
    + 128·128 int32 acc ≈ 192 KiB — comfortable in 16 MiB VMEM with double
    buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _qmatmul_kernel(x_ref, w_ref, colsum_ref, bias_ref, scale_ref, zps_ref,
                    out_ref, acc_ref, *, k_total: int):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _mask_k_tail(w_ref[...], k, k_total)

    # int8 × int8 → int32 on the MXU
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        x_zp = zps_ref[0]
        out_zp = zps_ref[1]
        acc = acc_ref[...]
        acc = acc - x_zp * colsum_ref[...][None, :] + bias_ref[...][None, :]
        y = acc.astype(jnp.float32) * scale_ref[...][None, :]
        y = jnp.round(y) + out_zp.astype(jnp.float32)
        out_ref[...] = jnp.clip(y, -128.0, 127.0).astype(jnp.int8)


def _mask_k_tail(block: jax.Array, k: jax.Array, k_total: int) -> jax.Array:
    """Zero the out-of-bounds rows of a padded K-tail block (undefined data
    must not pollute the reduction)."""
    block_k = block.shape[0]
    if k_total % block_k == 0:
        return block
    row = k * block_k + jax.lax.broadcasted_iota(jnp.int32, block.shape, 0)
    return jnp.where(row < k_total, block, 0)


def _qmatmul_acc_kernel(x_ref, w_ref, out_ref, *, k_total: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = _mask_k_tail(w_ref[...], k, k_total)
    out_ref[...] += jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _qmatmul_acc_checksum_kernel(x_ref, w_ref, wcheck_ref, out_ref, check_ref,
                                 *, k_total: int):
    """Accumulator kernel with the ABFT check vector fused in: alongside each
    (block_m, block_k) × (block_k, block_n) MXU step, one extra block-row
    matvec accumulates want = X · w_check into a second output — detection
    costs ~1/block_n extra work inside the kernel instead of a separate
    matvec pass over X."""
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((k == 0) & (n == 0))
    def _init_check():
        check_ref[...] = jnp.zeros_like(check_ref)

    w = _mask_k_tail(w_ref[...], k, k_total)
    out_ref[...] += jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    # the check column is N-independent: accumulate it once per (m, k) tile
    @pl.when(n == 0)
    def _check():
        wc = _mask_k_tail(wcheck_ref[...], k, k_total)
        check_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.int32), wc,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )


def _acc_grid(M, N, K, block_m, block_n, block_k):
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    grid = (pl.cdiv(M, block_m), pl.cdiv(N, block_n), pl.cdiv(K, block_k))
    return grid, block_m, block_n, block_k


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def qmatmul_acc(
    x_q: jax.Array,          # (M, K) int8
    w_q: jax.Array,          # (K, N) int8
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Raw int32 accumulator X·W — the backend-registry entry point.

    Unlike ``qmatmul`` the accumulator leaves the kernel, so the
    dependability layer can inject faults into it, checksum it, and share
    the zero-point/bias/requant epilogue across every backend."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    grid, block_m, block_n, block_k = _acc_grid(M, N, K, block_m, block_n,
                                                block_k)
    return pl.pallas_call(
        functools.partial(_qmatmul_acc_kernel, k_total=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def qmatmul_acc_checksum(
    x_q: jax.Array,          # (M, K) int8
    w_q: jax.Array,          # (K, N) int8
    w_check: jax.Array,      # (K,) int32 — deploy-time checksum_vector(w)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
):
    """(acc, want): accumulator plus the fused ABFT check vector.

    Returns acc (M, N) i32 and want (M,) i32 with want == rowsum(acc) mod
    2^32 on a fault-free pass; any single accumulator bit-flip breaks the
    identity exactly (see core/abft.py)."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    grid, block_m, block_n, block_k = _acc_grid(M, N, K, block_m, block_n,
                                                block_k)
    acc, want = pl.pallas_call(
        functools.partial(_qmatmul_acc_checksum_kernel, k_total=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((block_k, 1), lambda m, n, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
            # revisited across n and k → n must be "arbitrary" below
            pl.BlockSpec((block_m, 1), lambda m, n, k: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int32),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q, w_check.reshape(-1, 1))
    return acc, want[:, 0]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def qmatmul(
    x_q: jax.Array,          # (M, K) int8
    w_q: jax.Array,          # (K, N) int8
    colsum: jax.Array,       # (N,)  int32 — sum_k w_q[k, n]
    bias: jax.Array,         # (N,)  int32
    scale: jax.Array,        # (N,)  f32 — s_in * s_w / s_out (per-channel)
    zps: jax.Array,          # (2,)  int32 — [x_zp, out_zp]
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)

    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    grid = (pl.cdiv(M, block_m), pl.cdiv(N, block_n), pl.cdiv(K, block_k))

    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, k_total=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((block_n,), lambda m, n, k: (n,)),
            pl.BlockSpec((block_n,), lambda m, n, k: (n,)),
            pl.BlockSpec((block_n,), lambda m, n, k: (n,)),
            pl.BlockSpec((2,), lambda m, n, k: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q, colsum, bias, scale, zps)
