"""jit'd public wrapper around the qmatmul Pallas kernel.

``qlinear`` is the layer-level entry point used by the model zoo: it takes a
float activation + pre-quantized weight bundle and produces a float
activation, running the hot matmul entirely in int8/int32 (the paper's
technique), with requantization fused.

The kernel runs natively on TPU; on hosts without TPU (this container) it
executes under ``interpret=True``, which is the same "cycle-level simulator
stands in for hardware" methodology the paper uses (XDBG / HPDP simulator vs
the flight unit).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.qmatmul.kernel import qmatmul as qmatmul_pallas
from repro.kernels.qmatmul.ref import qmatmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class QLinearParams(NamedTuple):
    """Pre-quantized weight bundle for one linear layer (pytree-compatible)."""

    w_q: jax.Array       # (K, N) int8, per-output-channel symmetric
    w_scale: jax.Array   # (N,) f32
    colsum: jax.Array    # (N,) int32 — sum_k w_q
    bias_f: jax.Array    # (N,) f32 — kept in float; int32 bias derives per input scale


def make_qlinear_params(w: jax.Array, bias: jax.Array | None = None) -> QLinearParams:
    """Quantize a float (K, N) weight into the runtime parameter bundle."""
    qt = quant.quantize_weight(w, axis=-1)
    colsum = jnp.sum(qt.q.astype(jnp.int32), axis=0)
    if bias is None:
        bias = jnp.zeros((w.shape[-1],), jnp.float32)
    return QLinearParams(qt.q, qt.scale, colsum, bias.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def qmatmul_op(
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, colsum: jax.Array,
    bias_i32: jax.Array, scale: jax.Array, out_zp: jax.Array,
    *, use_kernel: bool = True, interpret: bool = False,
) -> jax.Array:
    """int8 in → int8 out quantized matmul. Dispatches kernel vs jnp ref."""
    if use_kernel:
        zps = jnp.stack([x_zp.astype(jnp.int32), out_zp.astype(jnp.int32)])
        return qmatmul_pallas(x_q, w_q, colsum, bias_i32, scale, zps,
                              interpret=interpret or not _on_tpu())
    return qmatmul_ref(x_q, x_zp, w_q, bias_i32, scale, out_zp)


def qlinear_act(
    x: jax.Array,                 # (..., K) float
    params: QLinearParams,
    x_scale: jax.Array, x_zp: jax.Array,       # calibrated input qparams
    out_scale: jax.Array, out_zp: jax.Array,   # calibrated output qparams
    *, use_kernel: bool = False, interpret: bool = False,
) -> jax.Array:
    """float → [quantize] → int8 matmul+requant → [dequantize] → float.

    This is the "simulated quantized inference" layer API: models call it with
    calibrated static qparams; everything between quantize and dequantize is
    integer, exactly as executed on the HPDP / TPU MXU.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x_q = quant.quantize(x.reshape(-1, K), x_scale, x_zp)
    bias_i32 = jnp.round(params.bias_f / (x_scale * params.w_scale)).astype(jnp.int32)
    rq_scale = quant.requant_scale(x_scale, params.w_scale, out_scale)
    y_q = qmatmul_op(x_q, x_zp, params.w_q, params.colsum, bias_i32, rq_scale,
                     out_zp, use_kernel=use_kernel, interpret=interpret)
    y = (y_q.astype(jnp.float32) - out_zp.astype(jnp.float32)) * out_scale
    return y.reshape(*lead, -1)


def qlinear_int8_bf16out(
    x: jax.Array,                 # (..., K) float (bf16/f32)
    params: QLinearParams,
    x_scale: jax.Array, x_zp: jax.Array,
) -> jax.Array:
    """W8A8 linear with float output (no output requantization).

    The serving fast path used by the LM archs: dynamic per-tensor activation
    quantization, int8 MXU matmul, fp32 dequantize epilogue.  XLA fuses the
    dequant into the matmul consumer; on TPU this hits the 394-TOPS int8 MXU
    path.  (The fully-quantized int8-chain variant above is the
    paper-faithful mode; this is the beyond-paper throughput mode.)
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x_q = quant.quantize(x.reshape(-1, K), x_scale, x_zp)
    acc = jax.lax.dot_general(
        x_q, params.w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc = acc - x_zp.astype(jnp.int32) * params.colsum[None, :]
    y = acc.astype(jnp.float32) * (x_scale * params.w_scale)[None, :] + params.bias_f
    return y.reshape(*lead, -1).astype(x.dtype)
