"""Pure-jnp oracle for the quantized matmul + fused requantization kernel.

Semantics (TFLite-compatible, per Jacob et al.):

    acc[m, n] = sum_k (x_q[m, k] - x_zp) * w_q[k, n]  + bias[n]      (int32)
    y[m, n]   = requantize(acc[m, n], scale[n], out_zp)              (int8)

The zero-point correction is algebraically hoisted out of the inner product:

    acc = x_q @ w_q - x_zp * colsum(w_q) + bias

which is exactly what the Pallas kernel computes (one int8 MXU matmul plus an
epilogue), and exactly what the HPDP dataflow graph computes (the XPP array
streams x through the multiply-accumulate PAEs; the correction terms are
folded into the bias path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import requantize


def qmatmul_acc_ref(x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array,
                    bias: jax.Array) -> jax.Array:
    """int32 accumulator (pre-requantization). x_q: (M, K) int8, w_q: (K, N) int8."""
    acc = jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    return acc - x_zp.astype(jnp.int32) * colsum[None, :] + bias[None, :].astype(jnp.int32)


def qmatmul_ref(x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
                scale: jax.Array, out_zp: jax.Array) -> jax.Array:
    """Full quantized matmul + requant. Returns int8 (M, N)."""
    acc = qmatmul_acc_ref(x_q, x_zp, w_q, bias)
    return requantize(acc, scale, out_zp)
