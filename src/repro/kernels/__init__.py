"""Custom compute kernels (Pallas TPU) + the execution-backend dispatch.

Three kernel families, each a (kernel.py, ops.py, ref.py) triple:

  qmatmul    int8 matmul + int32 accumulate + fused requant — the paper's
             hot-path primitive, transformer-shaped
  qconv2d    int8 NHWC conv + fused requant — the HPDP's Table-1 op
  flashattn  fused attention fwd/bwd (scores never hit HBM)

``dispatch`` registers the ref / jnp / pallas implementations of the
accumulator-level quantized entries into the ``core.backend`` registry;
everything above the kernels (dependability policies, campaigns, serving,
fleets) selects among them by name.  See docs/backends.md.
"""
from repro.kernels import dispatch
from repro.kernels.dispatch import (
    conv_acc, conv_acc_checksum, matmul_acc, matmul_acc_checksum)
from repro.kernels.flashattn.ops import flash_attn, flash_attn_model
from repro.kernels.qconv2d.ops import (
    QConvParams, make_qconv_params, qconv2d_op, qconv_act)
from repro.kernels.qmatmul.ops import (
    QLinearParams, make_qlinear_params, qlinear_act, qlinear_int8_bf16out,
    qmatmul_op)

__all__ = [
    "dispatch",
    "matmul_acc", "matmul_acc_checksum", "conv_acc", "conv_acc_checksum",
    "qmatmul_op", "qlinear_act", "qlinear_int8_bf16out",
    "QLinearParams", "make_qlinear_params",
    "qconv2d_op", "qconv_act", "QConvParams", "make_qconv_params",
    "flash_attn", "flash_attn_model",
]
