"""Built-in execution backends for the quantized primitives.

Registers the ``ref`` / ``jnp`` / ``pallas`` implementations of the
accumulator-level qmatmul / qconv2d entries into ``core.backend``'s
registry (see that module for the contract and selection precedence).
Importing this module is what makes the built-ins available; the registry
imports it lazily so ``core/`` never depends on ``kernels/`` at load time.

All three backends are bit-identical: the hot path is integer (int8 × int8
→ int32, wrapping mod 2^32), so accumulation order cannot change results.
``tests/test_backend.py`` enforces the parity across every policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import abft as abft_mod
from repro.core import backend as backend_mod
from repro.kernels.flashattn.kernel import (
    flash_attention as flash_attention_pallas,
    flash_attention_checked as flash_attention_checked_pallas)
from repro.kernels.flashattn.ref import attention_ref
from repro.kernels.qconv2d.kernel import (
    qconv2d_acc as qconv2d_acc_pallas,
    qconv2d_acc_checksum as qconv2d_acc_checksum_pallas)
from repro.kernels.qmatmul.kernel import (
    qmatmul_acc as qmatmul_acc_pallas,
    qmatmul_acc_checksum as qmatmul_acc_checksum_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# jnp — XLA-native int8 dot / conv (the historical inlined path)
# ---------------------------------------------------------------------------


def _matmul_acc_jnp(x_q, w_q):
    return jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def _matmul_acc_checksum_jnp(x_q, w_q, w_check):
    acc = _matmul_acc_jnp(x_q, w_q)
    want = jax.lax.dot_general(
        x_q, w_check[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)[:, 0]
    return acc, want


def _conv_i32(x, w, stride, padding):
    return jax.lax.conv_general_dilated(
        x, w, stride, padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)


def _conv_acc_jnp(x_q, x_zp, w_q, stride, padding):
    x = x_q.astype(jnp.int32) - x_zp.astype(jnp.int32)
    return _conv_i32(x, w_q.astype(jnp.int32), stride, padding)


def _conv_acc_checksum_jnp(x_q, x_zp, w_q, w_check, stride, padding):
    x = x_q.astype(jnp.int32) - x_zp.astype(jnp.int32)
    acc = _conv_i32(x, w_q.astype(jnp.int32), stride, padding)
    want = _conv_i32(x, w_check, stride, padding)[..., 0]
    return acc, want


# ---------------------------------------------------------------------------
# ref — independent oracle: int32-upcast matmul, explicit tap-loop conv
# ---------------------------------------------------------------------------


def _matmul_acc_ref(x_q, w_q):
    return jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))


def _matmul_acc_checksum_ref(x_q, w_q, w_check):
    acc = _matmul_acc_ref(x_q, w_q)
    want = jnp.matmul(x_q.astype(jnp.int32), w_check)
    return acc, want


def _resolve_pads(h, w, kh, kw, stride, padding):
    from repro.kernels.qconv2d.ops import _same_pads
    if padding == "SAME":
        return _same_pads(h, w, kh, kw, *stride)
    if padding == "VALID":
        return ((0, 0), (0, 0))
    return tuple(padding)


def _tap_loop_conv(x, w, stride, pads):
    """Direct shifted-window convolution in plain jnp — structurally the
    Pallas kernel's tap loop, independently implemented (no XLA conv op)."""
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    sh, sw = stride
    xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]
    oh = (hp - kh) // sh + 1
    ow = (wp - kw) // sw + 1
    acc = jnp.zeros((n, oh, ow, cout), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (oh - 1) * sh + 1, j + (ow - 1) * sw + 1, cin),
                (1, sh, sw, 1))
            acc = acc + jnp.einsum("nhwc,cf->nhwf", patch, w[i, j],
                                   preferred_element_type=jnp.int32)
    return acc


def _conv_acc_ref(x_q, x_zp, w_q, stride, padding):
    n, h, wd, _ = x_q.shape
    kh, kw = w_q.shape[0], w_q.shape[1]
    pads = _resolve_pads(h, wd, kh, kw, stride, padding)
    x = x_q.astype(jnp.int32) - x_zp.astype(jnp.int32)
    return _tap_loop_conv(x, w_q.astype(jnp.int32), stride, pads)


def _conv_acc_checksum_ref(x_q, x_zp, w_q, w_check, stride, padding):
    acc = _conv_acc_ref(x_q, x_zp, w_q, stride, padding)
    n, h, wd, _ = x_q.shape
    kh, kw = w_q.shape[0], w_q.shape[1]
    pads = _resolve_pads(h, wd, kh, kw, stride, padding)
    x = x_q.astype(jnp.int32) - x_zp.astype(jnp.int32)
    want = _tap_loop_conv(x, w_check, stride, pads)[..., 0]
    return acc, want


# ---------------------------------------------------------------------------
# pallas — the co-processor path (interpret=True off-TPU, per the paper's
# simulator-stands-in-for-hardware methodology)
# ---------------------------------------------------------------------------


def _matmul_acc_pallas(x_q, w_q):
    return qmatmul_acc_pallas(x_q, w_q, interpret=not _on_tpu())


def _matmul_acc_checksum_pallas(x_q, w_q, w_check):
    return qmatmul_acc_checksum_pallas(x_q, w_q, w_check,
                                       interpret=not _on_tpu())


def _pad_zp(x_q, x_zp, pads):
    """Zero-point padding: padded taps contribute (zp - zp)·w == 0, i.e.
    padding with the zp value is exactly 'pad with real 0.0'."""
    return jax.lax.pad(
        x_q, x_zp.astype(jnp.int8),
        ((0, 0, 0),
         (pads[0][0], pads[0][1], 0),
         (pads[1][0], pads[1][1], 0),
         (0, 0, 0)))


def _conv_acc_pallas(x_q, x_zp, w_q, stride, padding):
    n, h, wd, _ = x_q.shape
    kh, kw = w_q.shape[0], w_q.shape[1]
    pads = _resolve_pads(h, wd, kh, kw, stride, padding)
    xp = _pad_zp(x_q, x_zp, pads)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=(0, 1, 2))
    zp = x_zp.astype(jnp.int32).reshape(1)
    return qconv2d_acc_pallas(xp, w_q, colsum, zp, stride=stride,
                              interpret=not _on_tpu())


def _conv_acc_checksum_pallas(x_q, x_zp, w_q, w_check, stride, padding):
    n, h, wd, _ = x_q.shape
    kh, kw = w_q.shape[0], w_q.shape[1]
    pads = _resolve_pads(h, wd, kh, kw, stride, padding)
    xp = _pad_zp(x_q, x_zp, pads)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=(0, 1, 2))
    zp = x_zp.astype(jnp.int32).reshape(1)
    return qconv2d_acc_checksum_pallas(xp, w_q, colsum, w_check, zp,
                                       stride=stride,
                                       interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# attention — the float hot kernel, per backend
#
# Attention has no integer operand identity, so the checksummed entry is
# two-tier (core/backend.py docstring): a float check column verified with
# a tolerance plus an exact bit checksum of the emitted output rows.  On
# the pallas backend both are fused into the kernel epilogue; jnp/ref
# compute them as separate passes in the execution path, exactly as their
# qmatmul checksums are separate dots.
# ---------------------------------------------------------------------------


def _attn_check_column(q, k, v, *, causal, window):
    """Independent rowsum_hd(out) accumulation: softmax probabilities
    contracted with rowsum_hd(v) — never touches the (hd-wide) output
    accumulation it checks."""
    import math
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    v1 = jnp.sum(jnp.repeat(v, G, axis=1).astype(jnp.float32), axis=-1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk) \
        / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos >= qpos - window)
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    return jnp.einsum("bhqk,bhk->bhq", p, v1)


def _attn_jnp(q, k, v, *, causal=True, window=None):
    return attention_ref(q, k, v, causal=causal, window=window)


def _attn_checksum_jnp(q, k, v, *, causal=True, window=None):
    out = attention_ref(q, k, v, causal=causal, window=window)
    check = _attn_check_column(q, k, v, causal=causal, window=window)
    return out, check, abft_mod.output_row_checksums(out)


def _attn_ref(q, k, v, *, causal=True, window=None):
    """Independent oracle: explicit two-pass softmax (max/exp/normalize),
    no ``jax.nn.softmax``."""
    import math
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk) \
        / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos >= qpos - window)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)


def _attn_checksum_ref(q, k, v, *, causal=True, window=None):
    out = _attn_ref(q, k, v, causal=causal, window=window)
    check = _attn_check_column(q, k, v, causal=causal, window=window)
    return out, check, abft_mod.output_row_checksums(out)


def _attn_pallas(q, k, v, *, causal=True, window=None):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=not _on_tpu())


def _attn_checksum_pallas(q, k, v, *, causal=True, window=None):
    return flash_attention_checked_pallas(q, k, v, causal=causal,
                                          window=window,
                                          interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# registration + convenience dispatchers
# ---------------------------------------------------------------------------

for _be in (
    backend_mod.Backend(
        name="jnp",
        matmul_acc=_matmul_acc_jnp,
        matmul_acc_checksum=_matmul_acc_checksum_jnp,
        conv_acc=_conv_acc_jnp,
        conv_acc_checksum=_conv_acc_checksum_jnp,
        attn=_attn_jnp,
        attn_checksum=_attn_checksum_jnp,
        description="XLA-native int8 dot_general / conv_general_dilated"),
    backend_mod.Backend(
        name="ref",
        matmul_acc=_matmul_acc_ref,
        matmul_acc_checksum=_matmul_acc_checksum_ref,
        conv_acc=_conv_acc_ref,
        conv_acc_checksum=_conv_acc_checksum_ref,
        attn=_attn_ref,
        attn_checksum=_attn_checksum_ref,
        description="independent jnp oracle (int32 upcast / tap loop)"),
    backend_mod.Backend(
        name="pallas",
        matmul_acc=_matmul_acc_pallas,
        matmul_acc_checksum=_matmul_acc_checksum_pallas,
        conv_acc=_conv_acc_pallas,
        conv_acc_checksum=_conv_acc_checksum_pallas,
        attn=_attn_pallas,
        attn_checksum=_attn_checksum_pallas,
        description="Pallas TPU kernels with fused ABFT checksum "
                    "(interpret=True off-TPU)"),
):
    backend_mod.register_backend(_be, overwrite=True)
del _be


def matmul_acc(x_q, w_q, *, backend: backend_mod.BackendLike = None):
    """Raw int32 accumulator X·W on the selected backend."""
    return backend_mod.resolve(backend).matmul_acc(x_q, w_q)


def matmul_acc_checksum(x_q, w_q, w_check, *,
                        backend: backend_mod.BackendLike = None):
    """(acc, want) with the ABFT check vector computed in the execution path."""
    return backend_mod.resolve(backend).matmul_acc_checksum(x_q, w_q, w_check)


def conv_acc(x_q, x_zp, w_q, stride=(1, 1), padding="SAME", *,
             backend: backend_mod.BackendLike = None):
    """Raw int32 conv accumulator conv(x - zp, w) on the selected backend."""
    return backend_mod.resolve(backend).conv_acc(x_q, x_zp, w_q, stride,
                                                 padding)


def conv_acc_checksum(x_q, x_zp, w_q, w_check, stride=(1, 1), padding="SAME",
                      *, backend: backend_mod.BackendLike = None):
    """(acc, want) conv accumulator plus the fused per-pixel ABFT channel."""
    return backend_mod.resolve(backend).conv_acc_checksum(
        x_q, x_zp, w_q, w_check, stride, padding)


def attn(q, k, v, *, causal=True, window=None,
         backend: backend_mod.BackendLike = None):
    """Fused attention (B,H,S,hd layout) on the selected backend."""
    return backend_mod.resolve(backend).attn(q, k, v, causal=causal,
                                             window=window)


def attn_checksum(q, k, v, *, causal=True, window=None,
                  backend: backend_mod.BackendLike = None):
    """(out, check, csum): attention plus the two-tier ABFT check outputs
    (float check column + exact output-row bit checksum), fused into the
    kernel on the pallas backend."""
    return backend_mod.resolve(backend).attn_checksum(q, k, v, causal=causal,
                                                      window=window)
