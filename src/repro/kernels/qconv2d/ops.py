"""jit'd public wrapper for the qconv2d Pallas kernel.

Handles zero-point padding, parameter bundle preparation, kernel-vs-ref
dispatch, and falls back to the jnp reference when the image does not fit the
whole-image VMEM strategy (not the case for any paper workload).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.qconv2d.kernel import qconv2d as qconv2d_pallas
from repro.kernels.qconv2d.ref import qconv2d_ref

# Whole-image VMEM strategy budget (int8 bytes): input + weights + acc must
# sit in ~16 MiB VMEM; stay conservative.
_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class QConvParams(NamedTuple):
    """Runtime parameter bundle for one conv layer (the HPDP 'stream inputs')."""

    w_q: jax.Array       # (KH, KW, Cin, Cout) int8
    w_scale: jax.Array   # (Cout,) f32
    colsum: jax.Array    # (Cout,) int32
    bias_f: jax.Array    # (Cout,) f32


def make_qconv_params(w: jax.Array, bias: jax.Array | None = None) -> QConvParams:
    qt = quant.quantize_weight(w, axis=-1)
    colsum = jnp.sum(qt.q.astype(jnp.int32), axis=(0, 1, 2))
    if bias is None:
        bias = jnp.zeros((w.shape[-1],), jnp.float32)
    return QConvParams(qt.q, qt.scale, colsum, bias.astype(jnp.float32))


def _same_pads(h: int, w: int, kh: int, kw: int, sh: int, sw: int):
    oh = -(-h // sh)
    ow = -(-w // sw)
    ph = max((oh - 1) * sh + kh - h, 0)
    pw = max((ow - 1) * sw + kw - w, 0)
    return ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))


@functools.partial(jax.jit, static_argnames=("stride", "padding", "use_kernel", "interpret"))
def qconv2d_op(
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, colsum: jax.Array,
    bias_i32: jax.Array, scale: jax.Array, out_zp: jax.Array,
    *, stride: Tuple[int, int] = (1, 1), padding: str = "SAME",
    use_kernel: bool = True, interpret: bool = False,
) -> jax.Array:
    """int8 NHWC in → int8 NHWC out quantized conv+requant."""
    n, h, w, cin = x_q.shape
    kh, kw, _, cout = w_q.shape
    sh, sw = stride
    if padding == "SAME":
        pads = _same_pads(h, w, kh, kw, sh, sw)
    elif padding == "VALID":
        pads = ((0, 0), (0, 0))
    else:
        pads = tuple(padding)

    fits = (h + sum(pads[0])) * (w + sum(pads[1])) * cin + kh * kw * cin * min(cout, 128) \
        <= _VMEM_BUDGET_BYTES
    if use_kernel and fits:
        # zero-point padding: padded taps contribute (x_zp - x_zp)·w == 0,
        # i.e. padding with the zp value is exactly "pad with real 0.0"
        xp = jax.lax.pad(
            x_q, x_zp.astype(jnp.int8),
            ((0, 0, 0),
             (pads[0][0], pads[0][1], 0),
             (pads[1][0], pads[1][1], 0),
             (0, 0, 0)),
        )
        zps = jnp.stack([x_zp.astype(jnp.int32), out_zp.astype(jnp.int32)])
        return qconv2d_pallas(xp, w_q, colsum, bias_i32, scale, zps,
                              stride=stride,
                              interpret=interpret or not _on_tpu())
    return qconv2d_ref(x_q, x_zp, w_q, bias_i32, scale, out_zp,
                       stride=stride, padding=pads if padding not in ("SAME", "VALID") else padding)


def qconv_act(
    x: jax.Array,                 # (N, H, W, Cin) float
    params: QConvParams,
    x_scale: jax.Array, x_zp: jax.Array,
    out_scale: jax.Array, out_zp: jax.Array,
    *, stride: Tuple[int, int] = (1, 1), padding: str = "SAME",
    use_kernel: bool = False, interpret: bool = False,
) -> jax.Array:
    """float → int8 conv+requant → float, integer arithmetic in between."""
    x_q = quant.quantize(x, x_scale, x_zp)
    bias_i32 = jnp.round(params.bias_f / (x_scale * params.w_scale)).astype(jnp.int32)
    rq_scale = quant.requant_scale(x_scale, params.w_scale, out_scale)
    y_q = qconv2d_op(x_q, x_zp, params.w_q, params.colsum, bias_i32, rq_scale,
                     out_zp, stride=stride, padding=padding,
                     use_kernel=use_kernel, interpret=interpret)
    return (y_q.astype(jnp.float32) - out_zp.astype(jnp.float32)) * out_scale
