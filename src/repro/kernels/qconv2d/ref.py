"""Pure-jnp oracle for the quantized conv2d + fused requantization kernel.

This plays the role of the paper's PyTorch reference implementation (Fig. 4):
a functionally equivalent convolution whose output feature maps the
kernel-under-simulation is compared against, inside a unit-test framework.

Layout: NHWC activations, HWIO weights (TPU-native).  Semantics per Jacob et
al.: int8 activations with zero-point, symmetric per-output-channel int8
weights, int32 bias at scale s_in·s_w, int8 output after requantization.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import requantize


def qconv2d_acc_ref(
    x_q: jax.Array,          # (N, H, W, Cin) int8
    x_zp: jax.Array,         # scalar int32
    w_q: jax.Array,          # (KH, KW, Cin, Cout) int8
    bias: jax.Array,         # (Cout,) int32
    stride: Tuple[int, int] = (1, 1),
    padding: str | Sequence[Tuple[int, int]] = "SAME",
) -> jax.Array:
    """int32 accumulator. Zero-point-corrected conv in integer arithmetic."""
    x = x_q.astype(jnp.int32) - x_zp.astype(jnp.int32)
    w = w_q.astype(jnp.int32)
    acc = jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    return acc + bias[None, None, None, :].astype(jnp.int32)


def qconv2d_ref(
    x_q: jax.Array, x_zp: jax.Array, w_q: jax.Array, bias: jax.Array,
    scale: jax.Array, out_zp: jax.Array,
    stride: Tuple[int, int] = (1, 1),
    padding: str | Sequence[Tuple[int, int]] = "SAME",
) -> jax.Array:
    """Full quantized conv + requant. Returns int8 NHWC."""
    acc = qconv2d_acc_ref(x_q, x_zp, w_q, bias, stride, padding)
    return requantize(acc, scale, out_zp)
