"""Pallas TPU kernel: int8 NHWC conv2d + fused requantization.

The paper's core contribution is this exact op on the HPDP's XPP dataflow
array: convolution and re-quantization executing *in parallel on the stream*,
configured once, driven by runtime parameters (weights, bias, activations,
requant params).  TPU adaptation:

  * The XPP's 4D-DMA complex addressing → a shifted-window direct convolution:
    for each (kh, kw) tap, a strided slice of the input tile feeds one int8
    MXU matmul of shape (OH·OW, Cin) × (Cin, Cout_tile).  No im2col
    materialization in HBM — the "im2col" happens implicitly in VMEM
    addressing, the way the RAM-PAEs re-stream the input window.
  * Zero-point padding: ops.py pads the input with x_zp, so padded taps
    contribute exactly zero after the zero-point correction (standard
    integer-conv identity, also what the HPDP bias path folds in).
  * Requantization is fused in the epilogue — int32 accumulator never leaves
    VMEM (the paper: "these two operations process the data stream in
    parallel, ensuring continuous execution without introducing additional
    delays").
  * Grid: (batch, Cout tiles).  One (padded) input image and one Cout tile of
    weights resident in VMEM per step.  Paper-scale layers (194×194×24 int8 ≈
    0.9 MiB) fit trivially; ops.py asserts the VMEM budget and row-tiles the
    image when larger.

Taps (KH·KW) are unrolled in Python — static 1–9 iterations for the paper's
1×1/3×3 layers, each a dense MXU call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _qconv2d_kernel(x_ref, w_ref, colsum_ref, bias_ref, scale_ref, zps_ref,
                    out_ref, *, stride, oh, ow):
    kh, kw, cin, _ = w_ref.shape
    x = x_ref[0]                      # (Hp, Wp, Cin) int8
    acc = _tap_acc(x, w_ref, oh, ow, stride, cin, out_ref.shape[-1])
    x_zp = zps_ref[0]
    out_zp = zps_ref[1]
    acc = acc - x_zp * colsum_ref[...][None, :] + bias_ref[...][None, :]
    y = acc.astype(jnp.float32) * scale_ref[...][None, :]
    y = jnp.round(y) + out_zp.astype(jnp.float32)
    out_ref[0] = jnp.clip(y, -128.0, 127.0).astype(jnp.int8).reshape(
        oh, ow, out_ref.shape[-1])


def _tap_acc(x, w_ref, oh, ow, stride, cin, cout, dtype=None):
    """Shifted-window tap loop: the shared direct-conv inner pattern."""
    sh, sw = stride
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    acc = jnp.zeros((oh * ow, cout), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (i, j, 0), (i + (oh - 1) * sh + 1, j + (ow - 1) * sw + 1, cin),
                (sh, sw, 1),
            )
            lhs = patch.reshape(oh * ow, cin)
            rhs = w_ref[i, j]
            if dtype is not None:
                lhs = lhs.astype(dtype)
            acc += jax.lax.dot_general(
                lhs, rhs,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    return acc


def _qconv2d_acc_kernel(x_ref, w_ref, colsum_ref, zp_ref, out_ref,
                        *, stride, oh, ow):
    kh, kw, cin, _ = w_ref.shape
    cout = out_ref.shape[-1]
    x = x_ref[0]                      # (Hp, Wp, Cin) int8, zp-padded
    acc = _tap_acc(x, w_ref, oh, ow, stride, cin, cout)
    # conv(x_p - zp, w) == conv(x_p, w) - zp * sum(w): every output pixel
    # covers all kh·kw·cin taps because x is pre-padded with the zero point
    acc = acc - zp_ref[0] * colsum_ref[...][None, :]
    out_ref[0] = acc.reshape(oh, ow, cout)


def _qconv2d_acc_checksum_kernel(x_ref, w_ref, colsum_ref, wcheck_ref,
                                 zp_ref, out_ref, check_ref, *, stride, oh, ow):
    """Accumulator kernel with the ABFT check channel fused in: one extra
    Cout=1 tap matvec per step emits want = conv(x - zp, w_check) as a
    second output, so per-pixel detection needs no separate conv pass."""
    c = pl.program_id(1)
    kh, kw, cin, _ = w_ref.shape
    cout = out_ref.shape[-1]
    x = x_ref[0]
    acc = _tap_acc(x, w_ref, oh, ow, stride, cin, cout)
    acc = acc - zp_ref[0] * colsum_ref[...][None, :]
    out_ref[0] = acc.reshape(oh, ow, cout)

    # the check channel is Cout-block-independent: emit it once per image
    @pl.when(c == 0)
    def _check():
        want = _tap_acc(x, wcheck_ref, oh, ow, stride, cin, 1,
                        dtype=jnp.int32)
        # conv(x_p - zp, w_check) == conv(x_p, w_check) - zp * sum(w_check);
        # w_check is fully resident, so its tap sum is computed in-kernel
        want = want - zp_ref[0] * jnp.sum(wcheck_ref[...])
        check_ref[0] = want.reshape(oh, ow)


def _conv_geometry(x_q, w_q, stride, block_cout):
    n, hp, wp, cin = x_q.shape
    kh, kw, cin2, cout = w_q.shape
    assert cin == cin2, (x_q.shape, w_q.shape)
    sh, sw = stride
    oh = (hp - kh) // sh + 1
    ow = (wp - kw) // sw + 1
    block_cout = min(block_cout, cout)
    return n, hp, wp, cin, kh, kw, cout, oh, ow, block_cout


@functools.partial(
    jax.jit, static_argnames=("stride", "block_cout", "interpret")
)
def qconv2d_acc(
    x_q: jax.Array,          # (N, Hp, Wp, Cin) int8 — already zp-padded
    w_q: jax.Array,          # (KH, KW, Cin, Cout) int8
    colsum: jax.Array,       # (Cout,) int32 — sum over (KH, KW, Cin)
    zp: jax.Array,           # (1,) int32 — input zero point
    *,
    stride: tuple = (1, 1),
    block_cout: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw int32 conv accumulator conv(x - zp, w) — backend-registry entry."""
    n, hp, wp, cin, kh, kw, cout, oh, ow, block_cout = _conv_geometry(
        x_q, w_q, stride, block_cout)
    kernel = functools.partial(_qconv2d_acc_kernel, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(n, pl.cdiv(cout, block_cout)),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, block_cout), lambda b, c: (0, 0, 0, c)),
            pl.BlockSpec((block_cout,), lambda b, c: (c,)),
            pl.BlockSpec((1,), lambda b, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, block_cout), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x_q, w_q, colsum, zp)


@functools.partial(
    jax.jit, static_argnames=("stride", "block_cout", "interpret")
)
def qconv2d_acc_checksum(
    x_q: jax.Array,          # (N, Hp, Wp, Cin) int8 — already zp-padded
    w_q: jax.Array,          # (KH, KW, Cin, Cout) int8
    colsum: jax.Array,       # (Cout,) int32
    w_check: jax.Array,      # (KH, KW, Cin, 1) int32 — conv_checksum_weight(w)
    zp: jax.Array,           # (1,) int32
    *,
    stride: tuple = (1, 1),
    block_cout: int = 128,
    interpret: bool = False,
):
    """(acc, want): conv accumulator plus the fused per-pixel ABFT channel.

    want (N, OH, OW) i32 equals the Cout-sum of acc mod 2^32 on a fault-free
    pass; see core/abft.abft_qconv2d."""
    n, hp, wp, cin, kh, kw, cout, oh, ow, block_cout = _conv_geometry(
        x_q, w_q, stride, block_cout)
    kernel = functools.partial(_qconv2d_acc_checksum_kernel, stride=stride,
                               oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=(n, pl.cdiv(cout, block_cout)),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, block_cout), lambda b, c: (0, 0, 0, c)),
            pl.BlockSpec((block_cout,), lambda b, c: (c,)),
            pl.BlockSpec((kh, kw, cin, 1), lambda b, c: (0, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, oh, ow, block_cout), lambda b, c: (b, 0, 0, c)),
            # revisited across cout blocks → c must be "arbitrary" below
            pl.BlockSpec((1, oh, ow), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32),
            jax.ShapeDtypeStruct((n, oh, ow), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_q, colsum, w_check, zp)


@functools.partial(
    jax.jit, static_argnames=("stride", "block_cout", "interpret")
)
def qconv2d(
    x_q: jax.Array,          # (N, Hp, Wp, Cin) int8 — already zp-padded
    w_q: jax.Array,          # (KH, KW, Cin, Cout) int8
    colsum: jax.Array,       # (Cout,) int32 — sum over (KH, KW, Cin)
    bias: jax.Array,         # (Cout,) int32
    scale: jax.Array,        # (Cout,) f32
    zps: jax.Array,          # (2,) int32 — [x_zp, out_zp]
    *,
    stride: tuple = (1, 1),
    block_cout: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, hp, wp, cin = x_q.shape
    kh, kw, cin2, cout = w_q.shape
    assert cin == cin2, (x_q.shape, w_q.shape)
    sh, sw = stride
    oh = (hp - kh) // sh + 1
    ow = (wp - kw) // sw + 1
    block_cout = min(block_cout, cout)
    grid = (n, pl.cdiv(cout, block_cout))

    kernel = functools.partial(_qconv2d_kernel, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, block_cout), lambda b, c: (0, 0, 0, c)),
            pl.BlockSpec((block_cout,), lambda b, c: (c,)),
            pl.BlockSpec((block_cout,), lambda b, c: (c,)),
            pl.BlockSpec((block_cout,), lambda b, c: (c,)),
            pl.BlockSpec((2,), lambda b, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, block_cout), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int8),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x_q, w_q, colsum, bias, scale, zps)
