"""Process-isolation transport for the serving fleet.

The fleet's stages already communicate over bounded SPSC ``Channel``s
(runtime/dataflow.py); this module is the cut point that lets one of those
seams cross a *process* boundary: a ``Replica`` whose ``StreamingExecutor``
runs in a spawned worker process (``fleet/worker.py``), driven by the
parent fleet through the same ``submit``/``step``/``cancel``/``scrub``
surface the in-process replica exposes — ``Fleet``/``Supervisor``/``Router``
code is unchanged.

Wire protocol (length-prefixed, msgpack-free):

    MAGIC "RFT1" | u32 header_len | header JSON (utf-8) | raw array bytes…

The header carries ``{"seq", "op", "payload", "arrays": [{name, dtype,
shape, nbytes}, …]}``; array payloads (weight leaves, golden checksums,
PRNG key data) ride as concatenated raw bytes after the header, in header
order — JSON for structure, numpy bytes for bulk, no third-party codec.
Each direction numbers its frames with a monotonically increasing ``seq``
and the receiver rejects any gap or reordering (``ProtocolError``), so a
torn or duplicated frame can never be silently absorbed.

Dead-peer detection is deadline-based: every parent-side RPC bounds its
wait (``WorkerHandle.call(deadline=…)``); a timeout, pipe EOF, or a worker
process that is no longer alive raises ``TransportDead``, which the fleet
maps onto the same drain → failover path a heartbeat loss takes.  Every
answered RPC doubles as a transport-level heartbeat — there is no separate
keepalive traffic to schedule.

``ProcReplica`` duck-types ``fleet.replica.Replica``: health state, the
uncertified list, and request custody live parent-side (the canonical
``Request`` objects the fleet's records reference), while the engine, its
weights, and the golden checksums live in the worker.  The certify gate
runs parent-side via an *upcall*: when the worker's certify stage holds a
finished request, it sends a ``certify`` frame and blocks for the verdict —
servicing nested RPCs (scrub, cancel, reload) while it waits, because the
fleet's gate may re-enter the very replica being certified (DMR
attribution scrubs both replicas of a pair).
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"RFT1"
_HEADER_LEN = struct.Struct(">I")

# parent-side RPC deadlines (seconds).  ``init`` covers a cold jax import
# plus the worker's prefill/decode compiles; steady-state ops are bounded
# far tighter so a hung worker is detected within one fleet tick.
READY_DEADLINE = 600.0
CALL_DEADLINE = 120.0


class TransportError(Exception):
    """Base class for transport faults."""


class ProtocolError(TransportError):
    """Framing violation: bad magic, short frame, or a sequence gap."""


class TransportDead(TransportError):
    """The peer is gone (EOF / deadline exceeded / process exit)."""

    def __init__(self, msg: str, rid: int = -1):
        super().__init__(msg)
        self.rid = rid


class WorkerError(TransportError):
    """The worker executed the op and raised; carries its traceback."""


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def encode_frame(seq: int, op: str, payload: Optional[dict] = None,
                 arrays: Optional[Dict[str, np.ndarray]] = None) -> bytes:
    """One wire frame: JSON header + concatenated raw array bytes."""
    arrays = arrays or {}
    metas, blobs = [], []
    for name, arr in arrays.items():
        # asarray(order="C"), not ascontiguousarray: the latter silently
        # promotes 0-d arrays (scalar leaves) to shape (1,) on the wire
        arr = np.asarray(arr, order="C")
        metas.append({"name": name, "dtype": str(arr.dtype),
                      "shape": list(arr.shape), "nbytes": int(arr.nbytes)})
        blobs.append(arr.tobytes())
    header = json.dumps({"seq": int(seq), "op": op,
                         "payload": payload or {}, "arrays": metas},
                        separators=(",", ":")).encode("utf-8")
    return b"".join([MAGIC, _HEADER_LEN.pack(len(header)), header] + blobs)


def decode_frame(buf: bytes) -> Tuple[int, str, dict, Dict[str, np.ndarray]]:
    """Inverse of ``encode_frame``; raises ``ProtocolError`` on any damage."""
    if len(buf) < len(MAGIC) + _HEADER_LEN.size or buf[:len(MAGIC)] != MAGIC:
        raise ProtocolError(f"bad frame magic: {buf[:8]!r}")
    off = len(MAGIC)
    (hlen,) = _HEADER_LEN.unpack_from(buf, off)
    off += _HEADER_LEN.size
    if len(buf) < off + hlen:
        raise ProtocolError(f"truncated header: want {hlen} bytes, "
                            f"frame holds {len(buf) - off}")
    header = json.loads(buf[off:off + hlen].decode("utf-8"))
    off += hlen
    arrays: Dict[str, np.ndarray] = {}
    for meta in header.get("arrays", []):
        n = int(meta["nbytes"])
        if len(buf) < off + n:
            raise ProtocolError(f"truncated array {meta['name']!r}")
        arrays[meta["name"]] = np.frombuffer(
            buf, dtype=np.dtype(meta["dtype"]), count=max(
                n // max(np.dtype(meta["dtype"]).itemsize, 1), 0),
            offset=off).reshape(meta["shape"])
        off += n
    if off != len(buf):
        raise ProtocolError(f"{len(buf) - off} trailing bytes after frame")
    return int(header["seq"]), str(header["op"]), header.get("payload", {}), \
        arrays


class PipeChannel:
    """The ``Channel`` API shimmed over one end of a multiprocessing pipe.

    Same surface as the in-process SPSC channel — ``put``/``try_put``,
    ``get``/``try_get``, ``close`` — with frames instead of object refs:
    an *item* is an ``(op, payload, arrays)`` triple.  Outgoing frames are
    seq-stamped; incoming frames must arrive with strictly consecutive
    seqs.  ``get`` takes a deadline (seconds) and raises ``TransportDead``
    when the peer misses it or the pipe hits EOF — the transport analogue
    of ``Channel``'s ``Closed`` wake-up.
    """

    _EMPTY = object()

    def __init__(self, conn, name: str = ""):
        self.conn = conn
        self.name = name
        self._send_seq = 0
        self._recv_seq = 0
        self._closed = False

    @classmethod
    def is_empty_token(cls, item) -> bool:
        return item is cls._EMPTY

    def put(self, item) -> None:
        op, payload, arrays = item
        if self._closed:
            raise TransportDead(f"{self.name}: channel closed", -1)
        self._send_seq += 1
        try:
            self.conn.send_bytes(encode_frame(self._send_seq, op, payload,
                                              arrays))
        except (BrokenPipeError, EOFError, OSError) as e:
            self._closed = True
            raise TransportDead(f"{self.name}: peer gone on send ({e})") \
                from e

    def try_put(self, item) -> bool:
        if self._closed:
            return False
        self.put(item)
        return True

    def _decode(self, buf: bytes):
        seq, op, payload, arrays = decode_frame(buf)
        self._recv_seq += 1
        if seq != self._recv_seq:
            raise ProtocolError(
                f"{self.name}: sequence gap (got {seq}, "
                f"want {self._recv_seq})")
        return op, payload, arrays

    def get(self, deadline: Optional[float] = None):
        """Next frame, blocking up to ``deadline`` seconds (None = forever).
        Raises ``TransportDead`` on timeout or EOF."""
        if self._closed:
            raise TransportDead(f"{self.name}: channel closed")
        try:
            if deadline is not None and not self.conn.poll(deadline):
                raise TransportDead(
                    f"{self.name}: peer missed {deadline:.0f}s deadline")
            return self._decode(self.conn.recv_bytes())
        except (BrokenPipeError, EOFError, OSError) as e:
            self._closed = True
            raise TransportDead(f"{self.name}: peer gone on recv ({e})") \
                from e

    def try_get(self):
        if self._closed or not self.conn.poll(0):
            return self._EMPTY
        return self.get(deadline=0.1)

    def close(self) -> None:
        self._closed = True
        try:
            self.conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Doc serialization for the structured payloads (config, requests, faults)
# ---------------------------------------------------------------------------


def cfg_to_doc(cfg) -> dict:
    """ArchConfig → JSON doc (nested MoE/recurrent configs flatten too)."""
    return dataclasses.asdict(cfg)


def cfg_from_doc(doc: dict):
    from repro.models.config import ArchConfig, MoEConfig, RecurrentConfig
    doc = dict(doc)
    if doc.get("moe"):
        doc["moe"] = MoEConfig(**doc["moe"])
    if doc.get("recurrent"):
        rec = dict(doc["recurrent"])
        rec["block_pattern"] = tuple(rec.get("block_pattern", ()))
        doc["recurrent"] = RecurrentConfig(**rec)
    return ArchConfig(**doc)


def fault_to_name(fault) -> str:
    """Serialize an injection callable by *name* so the worker can resolve
    the identical function: campaign fault models by registry name,
    ``core.fault_injection`` primitives by attribute name."""
    from repro.campaign import faultload as fl
    for name, fm in fl.FAULT_MODELS.items():
        if fault is fm or fault is fm.apply:
            return "model:" + name
    n = getattr(fault, "__name__", "")
    from repro.core import fault_injection as fi
    if n and getattr(fi, n, None) is fault:
        return "fi:" + n
    raise ValueError(
        f"cannot serialize fault {fault!r} for the proc transport; use a "
        f"registered campaign fault model or a core.fault_injection "
        f"primitive")


def fault_from_name(name: str):
    kind, _, n = name.partition(":")
    if kind == "model":
        from repro.campaign import faultload as fl
        return fl.resolve_fault_model(n).apply
    from repro.core import fault_injection as fi
    return getattr(fi, n)


def leaves_to_arrays(tree) -> Dict[str, np.ndarray]:
    """Flatten a pytree to {manifest-path: host array} — the wire form of
    weight and checksum payloads (paths are ``train/checkpoint.path_str``,
    the same addressing scrub verdicts and ``restore_leaves`` speak)."""
    import jax
    from repro.train import checkpoint as ckpt_mod
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {ckpt_mod.path_str(path): np.asarray(jax.device_get(leaf))
            for path, leaf in flat}


# ---------------------------------------------------------------------------
# Parent-side worker handle
# ---------------------------------------------------------------------------


class WorkerHandle:
    """One spawned worker process + its framed control pipe.

    ``call`` is the single RPC surface: send one frame, then pump replies
    until the worker answers — handling ``certify`` upcalls (the worker's
    certify stage asking the parent's release gate for a verdict) and
    ``error`` frames (worker-side exceptions, re-raised as ``WorkerError``)
    along the way.  Any deadline miss, EOF, or dead process raises
    ``TransportDead``; after that the handle is permanently dead and every
    further call fails fast.
    """

    def __init__(self, rid: int, *, deadline: float = CALL_DEADLINE):
        self.rid = rid
        self.deadline = deadline
        self.proc = None
        self.ch: Optional[PipeChannel] = None
        self.dead = False

    def spawn(self) -> None:
        import multiprocessing as mp
        from repro.fleet import worker as worker_mod
        ctx = mp.get_context("spawn")      # never fork a live XLA runtime
        parent_conn, child_conn = ctx.Pipe(duplex=True)
        # pin the child's platform to the parent's before the spawn snapshot
        # of os.environ is taken, so the worker cannot race the parent for
        # an accelerator it was not meant to share
        unset = "JAX_PLATFORMS" not in os.environ
        if unset:
            import jax
            os.environ["JAX_PLATFORMS"] = jax.default_backend()
        try:
            self.proc = ctx.Process(
                target=worker_mod.worker_entry, args=(child_conn, self.rid),
                name=f"fleet-worker-{self.rid}", daemon=True)
            self.proc.start()
        finally:
            if unset:
                del os.environ["JAX_PLATFORMS"]
        child_conn.close()
        self.ch = PipeChannel(parent_conn, f"worker{self.rid}")
        self.dead = False

    def alive(self) -> bool:
        return (not self.dead and self.proc is not None
                and self.proc.is_alive())

    def _mark_dead(self, why: str) -> TransportDead:
        self.dead = True
        return TransportDead(f"worker {self.rid}: {why}", self.rid)

    def call(self, op: str, payload: Optional[dict] = None,
             arrays: Optional[Dict[str, np.ndarray]] = None, *,
             deadline: Optional[float] = None,
             on_upcall: Optional[Callable[[dict], dict]] = None
             ) -> Tuple[dict, Dict[str, np.ndarray]]:
        if self.dead or self.ch is None:
            raise self._mark_dead("transport already dead")
        deadline = self.deadline if deadline is None else deadline
        try:
            self.ch.put((op, payload or {}, arrays or {}))
            while True:
                rop, rpayload, rarrays = self.ch.get(deadline)
                if rop == "certify":
                    if on_upcall is None:
                        raise ProtocolError(
                            f"worker {self.rid}: certify upcall outside a "
                            f"step call")
                    verdict = on_upcall(rpayload)
                    self.ch.put(("verdict", verdict, {}))
                    continue
                if rop == "error":
                    raise WorkerError(
                        f"worker {self.rid} failed op {op!r}:\n"
                        f"{rpayload.get('traceback', rpayload)}")
                return rpayload, rarrays
        except TransportDead as e:
            raise self._mark_dead(str(e)) from e

    def kill(self) -> None:
        """Hard-stop the worker (chaos hook / cleanup)."""
        self.dead = True
        if self.proc is not None and self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=5.0)
        if self.ch is not None:
            self.ch.close()

    def shutdown(self) -> None:
        """Graceful stop: ask, wait briefly, then kill."""
        if not self.dead and self.ch is not None and self.alive():
            try:
                self.call("shutdown", deadline=10.0)
            except TransportError:
                pass
        if self.proc is not None:
            self.proc.join(timeout=5.0)
        self.kill()


# ---------------------------------------------------------------------------
# ProcReplica: the Replica surface over a WorkerHandle
# ---------------------------------------------------------------------------


class _StatsView:
    """Parent-side mirror of the worker engine's EngineStats."""

    def __init__(self):
        self.steps = 0
        self.tokens_out = 0
        self.replays = 0
        self.faults_detected = 0


class _EngineProxy:
    """The slice of the ``Engine`` surface the fleet drives, forwarded over
    the transport.  Queue/occupancy reads are served from a cache refreshed
    by every RPC ack (the worker answers each op with a sync blob), so the
    router's load decisions see exactly the values an in-process fleet
    would at the same decision points — no extra round trips."""

    def __init__(self, owner: "ProcReplica"):
        self._o = owner

    # cached occupancy (refreshed from every ack's sync blob)
    @property
    def queue(self) -> bool:
        return self._o._queue

    @property
    def active(self) -> bool:
        return self._o._active

    @property
    def stats(self) -> _StatsView:
        return self._o._stats

    @property
    def state_scrub(self) -> str:
        return self._o._state_scrub

    @state_scrub.setter
    def state_scrub(self, mode: str) -> None:
        self._o._set_state_scrub(mode)

    def submit(self, req) -> None:
        self._o._submit(req)

    def cancel(self, uid: int) -> bool:
        return self._o._cancel(uid)

    def step(self) -> List:
        return self._o._step()

    def reset(self, params=None) -> None:
        self._o._engine_reset(params=params)

    def strike(self, site: str, fault, key) -> None:
        self._o._strike(site, fault, key)

    def drain_state_events(self) -> List[dict]:
        ev, self._o._state_events = self._o._state_events, []
        return ev


class ProcReplica:
    """A fleet replica whose engine lives in a worker process.

    Duck-types ``fleet.replica.Replica``: same attributes (``rid``,
    ``state``, ``paused``, ``routable``, ``uncertified``, ``recoveries``,
    scrub bookkeeping) and methods (``install_certifier``, ``load``,
    ``in_flight``, ``scrub``, ``reload``/``reload_leaves``/``patch_leaves``,
    ``reset``).  The canonical ``Request`` objects stay parent-side in a
    submission-ordered registry, so custody transfers (certify verdicts,
    drains after a worker dies, failover replays) operate on the same
    objects the fleet's records track — exactly like the in-process fleet.
    """

    def __init__(self, rid: int, cfg, *, ckpt_dir: str, step: int = 0,
                 capacity: int = 4, max_len: int = 128, prefill_pad: int = 8,
                 snapshot_every: int = 16, eos_id: int = -1,
                 backend: Optional[str] = None, state_scrub: str = "off",
                 deadline: float = CALL_DEADLINE,
                 ready_deadline: float = READY_DEADLINE):
        from repro.fleet.replica import ReplicaState
        self._RS = ReplicaState
        self.rid = rid
        self.cfg = cfg
        self.state = ReplicaState.HEALTHY
        self.paused = False
        self.routable = True
        self.golden = None                 # lives worker-side
        self.uncertified: List[Any] = []
        self.recoveries = 0
        self.last_clean_scrub_tick = 0
        self.last_scrub_bad: List[str] = []
        self.engine = _EngineProxy(self)
        self._gate = None
        self._owned: Dict[int, Any] = {}   # uid -> canonical Request
        self._queue = False
        self._active = False
        self._pending = 0
        self._stats = _StatsView()
        self._state_events: List[dict] = []
        self._state_scrub = state_scrub
        self._ready_deadline = ready_deadline
        self._init_payload = {
            "cfg": cfg_to_doc(cfg), "ckpt_dir": str(ckpt_dir),
            "step": int(step), "capacity": int(capacity),
            "max_len": int(max_len), "prefill_pad": int(prefill_pad),
            "snapshot_every": int(snapshot_every), "eos_id": int(eos_id),
            "backend": backend, "state_scrub": state_scrub,
        }
        self.handle = WorkerHandle(rid, deadline=deadline)
        self.handle.spawn()
        self._init_sent = False
        self._start_init()

    # ------------------------------------------------------------ lifecycle
    def _start_init(self) -> None:
        """Send the init frame without waiting — callers spawn a fleet of
        workers first and then ``wait_ready`` on each, so cold jax imports
        and prefill/decode compiles overlap across workers."""
        self.handle.ch.put(("init", self._init_payload, {}))
        self._init_sent = True
        self._ready = False

    def wait_ready(self) -> None:
        if self._ready:
            return
        # the init reply is the first frame the worker sends; read it
        # directly rather than issuing a second op
        try:
            rop, rpayload, _ = self.handle.ch.get(self._ready_deadline)
        except TransportDead as e:
            self.handle.dead = True
            raise TransportDead(
                f"worker {self.rid} died during init: {e}", self.rid) from e
        if rop == "error":
            raise WorkerError(
                f"worker {self.rid} failed init:\n"
                f"{rpayload.get('traceback', rpayload)}")
        if rop != "ready":
            raise ProtocolError(f"worker {self.rid}: expected ready frame, "
                                f"got {rop!r}")
        self._sync(rpayload)
        self._ready = True

    def respawn(self, ckpt_dir: str, step: int) -> None:
        """Replace a dead worker with a fresh one restored from the named
        checkpoint step (the transport-loss recovery path)."""
        self.handle.kill()
        self._init_payload["ckpt_dir"] = str(ckpt_dir)
        self._init_payload["step"] = int(step)
        self._init_payload["state_scrub"] = self._state_scrub
        self.handle = WorkerHandle(self.rid, deadline=self.handle.deadline)
        self.handle.spawn()
        self._start_init()
        self.wait_ready()
        self._owned = {}
        self._queue = self._active = False
        self._pending = 0
        self._state_events = []

    def close(self) -> None:
        self.handle.shutdown()

    @property
    def alive(self) -> bool:
        return self.handle.alive()

    # ----------------------------------------------------- replica surface
    def install_certifier(self, gate) -> None:
        self._gate = gate

    @property
    def healthy(self) -> bool:
        return self.state is self._RS.HEALTHY and not self.paused

    def load(self) -> int:
        return self._pending

    def in_flight(self) -> List[Any]:
        """Canonical Request objects still inside the worker's pipeline, in
        the worker's deterministic stage order.  A dead transport falls
        back to the parent-side registry (submission order) — that is the
        drain list failover replays from, so it must survive the worker."""
        if not self.handle.alive() or self.handle.dead:
            return list(self._owned.values())
        payload, _ = self.handle.call("in_flight")
        self._sync(payload)
        out = []
        for doc in payload["reqs"]:
            req = self._owned.get(int(doc["uid"]))
            if req is None:
                from repro.runtime.dataflow import Request
                req = Request.from_doc(doc)
            else:
                req.sync_from_doc(doc)
            out.append(req)
        return out

    def scrub(self) -> List[str]:
        payload, _ = self.handle.call("scrub")
        self._sync(payload)
        self.last_scrub_bad = list(payload["bad"])
        return self.last_scrub_bad

    def reload(self, params) -> None:
        self.handle.call("reload_leaves", {},
                         leaves_to_arrays(params))
        self._after_reset()

    def reload_leaves(self, leaves: Dict[str, np.ndarray]) -> None:
        self.handle.call("reload_leaves", {},
                         {str(k): np.asarray(v) for k, v in leaves.items()})
        self._after_reset()

    def patch_leaves(self, leaves: Dict[str, np.ndarray],
                     golden=None) -> None:
        """Live weight swap: patch leaves into the running worker engine
        without clearing its pipeline (the zero-drain deploy path); the new
        golden checksums ship alongside as one u32 per tensor."""
        arrays = {"leaf:" + str(k): np.asarray(v)
                  for k, v in leaves.items()}
        if golden is not None:
            arrays.update({"gold:" + k: v
                           for k, v in leaves_to_arrays(golden).items()})
        payload, _ = self.handle.call("patch_leaves", {}, arrays)
        self._sync(payload)

    def reset_from_ckpt(self, ckpt_dir: str, step: int) -> None:
        """Fresh-trial revival: worker restores the named checkpoint step
        (byte-identical to the parent's golden params — crc32-verified) and
        resets its run state.  A dead worker is respawned first."""
        if not self.handle.alive() or self.handle.dead:
            self.respawn(ckpt_dir, step)
        else:
            payload, _ = self.handle.call(
                "reset", {"ckpt_dir": str(ckpt_dir), "step": int(step)})
            self._sync(payload)
        self._after_reset()
        self.state = self._RS.HEALTHY
        self.paused = False
        self.routable = True
        self.last_clean_scrub_tick = 0
        self.last_scrub_bad = []

    def reset(self, params=None) -> None:
        """Replica.reset parity.  The proc replica restores its baseline
        from the checkpoint store rather than shipping ``params`` over the
        wire; callers that need a specific step use ``reset_from_ckpt``."""
        self.reset_from_ckpt(self._init_payload["ckpt_dir"],
                             self._init_payload["step"])

    # ----------------------------------------------------- engine forwards
    def _sync(self, payload: dict) -> None:
        s = payload.get("sync")
        if not s:
            return
        self._pending = int(s["pending"])
        self._queue = bool(s["queue"])
        self._active = bool(s["active"])
        self._stats.steps = int(s["steps"])
        self._stats.tokens_out = int(s["tokens_out"])
        self._stats.replays = int(s["replays"])
        self._stats.faults_detected = int(s["faults_detected"])

    def _after_reset(self) -> None:
        self._owned = {}
        self._queue = self._active = False
        self._pending = 0
        self._state_events = []

    def _submit(self, req) -> None:
        self._owned[req.uid] = req
        payload, _ = self.handle.call("submit", {"req": req.to_doc()})
        self._sync(payload)

    def _cancel(self, uid: int) -> bool:
        self._owned.pop(uid, None)
        if self.handle.dead or not self.handle.alive():
            return False
        payload, _ = self.handle.call("cancel", {"uid": int(uid)})
        self._sync(payload)
        return bool(payload["found"])

    def _on_certify(self, payload: dict) -> dict:
        doc = payload["req"]
        uid = int(doc["uid"])
        req = self._owned.pop(uid, None)
        if req is None:
            from repro.runtime.dataflow import Request
            req = Request.from_doc(doc)
        else:
            req.sync_from_doc(doc)
        release = bool(self._gate(self, req)) if self._gate else True
        return {"uid": uid, "release": release}

    def _step(self) -> List:
        payload, _ = self.handle.call("step", on_upcall=self._on_certify)
        self._sync(payload)
        self._state_events.extend(payload.get("state_events", []))
        for uid in payload.get("released", []):
            self._owned.pop(int(uid), None)
        return []

    def _engine_reset(self, params=None) -> None:
        if params is not None:
            self.handle.call("reload_leaves", {}, leaves_to_arrays(params))
        else:
            payload, _ = self.handle.call("engine_reset")
            self._sync(payload)
        self._after_reset()

    def _strike(self, site: str, fault, key) -> None:
        import jax
        key_data = np.asarray(jax.random.key_data(key))
        payload, _ = self.handle.call(
            "strike", {"site": site, "fault": fault_to_name(fault)},
            {"key": key_data})
        self._sync(payload)

    def _set_state_scrub(self, mode: str) -> None:
        self._state_scrub = mode
        payload, _ = self.handle.call("set_state_scrub", {"mode": mode})
        self._sync(payload)
