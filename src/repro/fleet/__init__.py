"""Dependable serving fleet: N supervised Engine replicas behind a
deterministic router, with ABFT weight scrubbing, checkpoint-reload
recovery, DMR pair-serving, and bit-exact failover.

See docs/fleet.md for the architecture and the recovery state machine, and
``python -m repro.fleet.cli --help`` for the drill runner.
"""
from repro.fleet.fleet import FLEET_POLICIES, TRANSPORTS, Fleet
from repro.fleet.metrics import FleetMetrics
from repro.fleet.replica import Replica, ReplicaState
from repro.fleet.router import Router
from repro.fleet.supervisor import Supervisor
from repro.fleet.transport import ProcReplica, TransportDead, WorkerHandle

__all__ = [
    "FLEET_POLICIES", "TRANSPORTS", "Fleet", "FleetMetrics", "ProcReplica",
    "Replica", "ReplicaState", "Router", "Supervisor", "TransportDead",
    "WorkerHandle",
]
