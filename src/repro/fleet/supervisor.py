"""Fleet supervision: heartbeats, stragglers, scrubbing, and recovery.

The supervisor is the fleet's RTG4: it never touches a token itself, it
watches the replicas that do.  Health tracking reuses the training
``Orchestrator`` policies verbatim (heartbeat timeout ⇒ dead, step time
vs cluster median ⇒ straggler) with the fleet's deterministic tick counter
as the clock, so verdicts replay bit-exactly under campaign seeds.

On top of health it owns the two dependability duties the serving layer
needs:

  * **scrub** — verify a replica's live weights against the deploy-time
    ABFT storage checksums (``core.abft.storage_checksums``); any mismatch
    is a detected weight-SEU.
  * **recover** — drive the quarantine → restore → re-verify → readmit
    state machine for a replica whose scrub failed.  Recovery is
    *incremental first*: the scrub verdict names exactly which tensors are
    corrupted, so the supervisor re-reads only those leaves from the golden
    checkpoint (``train/checkpoint.restore_leaves``, crc32-verified) and
    patches them in — a full reload is the fallback, not the default.
    Every recovery is wall-clock timed into ``FleetMetrics`` (the paper's
    recovery-time argument needs a measured number, not a story).
    Re-verification scrubs the restored weights before the replica serves
    again; a replica that cannot be re-verified is DEAD.
"""
from __future__ import annotations

import time
from typing import List, Optional

from repro.fleet.metrics import FleetMetrics
from repro.fleet.replica import Replica, ReplicaState
from repro.runtime.orchestrator import Orchestrator
from repro.train import checkpoint as ckpt_mod


class Supervisor:
    def __init__(self, n_replicas: int, *, scrub_every: int = 8,
                 heartbeat_timeout: float = 25.0,
                 straggler_factor: float = 3.0):
        self.n_replicas = n_replicas
        self.scrub_every = scrub_every
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.orch = Orchestrator(n_replicas,
                                 heartbeat_timeout=heartbeat_timeout,
                                 straggler_factor=straggler_factor)
        self.events: List[str] = self.orch.events   # one shared event log
        # structured dependability event log (repro.obs.EventLog) — the
        # fleet installs its own so supervisor verdicts carry provenance;
        # None keeps the supervisor usable standalone
        self.event_log = None

    def _emit(self, kind: str, tick: int, **fields):
        if self.event_log is not None:
            self.event_log.emit(kind, tick=tick, **fields)

    def reset(self):
        self.orch = Orchestrator(self.n_replicas,
                                 heartbeat_timeout=self.heartbeat_timeout,
                                 straggler_factor=self.straggler_factor)
        self.events = self.orch.events

    # ------------------------------------------------------------ heartbeats
    def heartbeat(self, rid: int, step: int, step_time: float, tick: int):
        self.orch.heartbeat(rid, step, step_time, now=float(tick))

    def newly_dead(self, tick: int) -> List[int]:
        """Replica uids whose heartbeats stopped (timeout in ticks)."""
        return self.orch.check_health(now=float(tick))

    def stragglers(self) -> List[int]:
        return self.orch.detect_stragglers()

    # ---------------------------------------------------------------- scrub
    def due_for_scrub(self, tick: int) -> bool:
        return self.scrub_every > 0 and tick % self.scrub_every == 0

    def scrub(self, replica: Replica, metrics: FleetMetrics,
              tick: int) -> bool:
        """Weight-integrity check; returns True when clean."""
        metrics.scrubs += 1
        bad = replica.scrub()
        if bad:
            metrics.detections += 1
            self.events.append(
                f"tick {tick}: replica {replica.rid} scrub FAILED "
                f"({len(bad)} corrupted leaves, e.g. {bad[0]})")
            self._emit("detection", tick, site="weights",
                       replica=replica.rid,
                       detail={"check": "storage_scrub",
                               "leaves": len(bad)})
            return False
        replica.last_clean_scrub_tick = tick
        return True

    # ------------------------------------------------------------- recovery
    def _full_reload(self, replica: Replica, ckpt_dir,
                     step: Optional[int] = None) -> None:
        _, params = ckpt_mod.restore(ckpt_dir, step)  # crc32-verified read
        replica.reload(params)

    def recover(self, replica: Replica, ckpt_dir, metrics: FleetMetrics,
                tick: int, step: Optional[int] = None) -> bool:
        """quarantine → restore → re-verify → readmit.  Returns True when
        the replica is HEALTHY again; on any failure it is left DEAD.

        The restore is incremental when the scrub verdict
        (``replica.last_scrub_bad``) names the corrupted leaves: only those
        are re-read from the golden checkpoint and patched in.  If the
        partial restore cannot cover the verdict, or re-verification still
        fails afterwards (e.g. the corruption moved while we restored), the
        supervisor escalates to a full reload before giving up.

        ``step`` pins which checkpoint step is golden — after a rolling
        deploy the fleet's current step moves, and recovering a replica
        from an older step would re-verify against the wrong checksums."""
        t0 = time.perf_counter()
        replica.state = ReplicaState.QUARANTINED
        self.events.append(f"tick {tick}: replica {replica.rid} quarantined")
        self._emit("quarantine", tick, replica=replica.rid)
        replica.state = ReplicaState.RECOVERING
        bad = list(replica.last_scrub_bad)
        incremental = False
        try:
            if bad:
                leaves = ckpt_mod.restore_leaves(ckpt_dir, bad, step=step)
                if set(leaves) == set(bad):
                    replica.reload_leaves(leaves)
                    incremental = True
            if not incremental:
                self._full_reload(replica, ckpt_dir, step)
        except Exception as e:                        # noqa: BLE001
            replica.state = ReplicaState.DEAD
            metrics.replicas_lost += 1
            self.events.append(
                f"tick {tick}: replica {replica.rid} DEAD "
                f"(checkpoint restore failed: {e})")
            self._emit("replica_dead", tick, replica=replica.rid,
                       detail={"reason": "restore_failed"})
            return False
        still_bad = replica.scrub()
        if still_bad and incremental:
            # partial restore did not satisfy the re-verify — escalate
            self.events.append(
                f"tick {tick}: replica {replica.rid} incremental restore "
                f"insufficient ({len(still_bad)} leaves still dirty); "
                f"falling back to full reload")
            incremental = False
            try:
                self._full_reload(replica, ckpt_dir, step)
            except Exception as e:                    # noqa: BLE001
                replica.state = ReplicaState.DEAD
                metrics.replicas_lost += 1
                self.events.append(
                    f"tick {tick}: replica {replica.rid} DEAD "
                    f"(fallback reload failed: {e})")
                self._emit("replica_dead", tick, replica=replica.rid,
                           detail={"reason": "fallback_reload_failed"})
                return False
            still_bad = replica.scrub()
        if still_bad:
            replica.state = ReplicaState.DEAD
            metrics.replicas_lost += 1
            self.events.append(
                f"tick {tick}: replica {replica.rid} DEAD "
                f"(re-verify failed after restore)")
            self._emit("replica_dead", tick, replica=replica.rid,
                       detail={"reason": "reverify_failed"})
            return False
        seconds = time.perf_counter() - t0
        replica.state = ReplicaState.HEALTHY
        replica.routable = True
        replica.last_clean_scrub_tick = tick
        replica.recoveries += 1
        metrics.recoveries += 1
        metrics.observe_recovery(seconds, leaves=len(bad),
                                 incremental=incremental)
        self._emit("recovery", tick, site="weights", replica=replica.rid,
                   seconds=seconds,
                   detail={"incremental": incremental, "leaves": len(bad)})
        how = (f"incremental restore of {len(bad)} leaves" if incremental
               else "full reload")
        self.events.append(
            f"tick {tick}: replica {replica.rid} readmitted "
            f"({how} + re-verify ok, {seconds * 1e3:.1f} ms)")
        return True
