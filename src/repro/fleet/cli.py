"""Fleet CLI — serve a deterministic request stream through a dependable
multi-replica fleet, optionally striking one replica with an SEU, and write
the fleet metrics report.

    PYTHONPATH=src python -m repro.fleet.cli \
        --arch smollm-135m --replicas 2 --requests 6 \
        --policy abft --inject weights --seed 0

The run always serves the same stream twice: once fault-free (the golden
reference) and once under the requested fault.  The exit code is the
dependability verdict: 0 when every released token stream matches the
golden run, 1 when the fault silently corrupted the released output —
so ``--policy none --inject weights`` is *expected* to exit 1 on
manifesting faults, and abft/dmr/ckpt must always exit 0.

``--inject kv_cache`` / ``--inject decode_state`` strike a replica's live
transient state mid-serve: DMR catches the divergence by pair-comparison,
ABFT by the decode-state scrub (drain + failover), and CKPT by the scrub
with an in-place engine snapshot rollback (docs/recovery.md).

``--transport proc`` runs every replica in its own worker process over the
framed pipe transport (docs/multihost.md); the verdict contract is
identical.  ``--deploy`` performs a zero-drain rolling weight deploy
mid-serve in both passes; combined with ``--inject weights`` the drill
strikes replica 0 *while replica 1 is mid-swap* — the hardest window.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import numpy as np

from repro.core import fault_injection as fi
from repro.core.dependability import Policy
from repro.fleet.fleet import FLEET_POLICIES, TRANSPORTS, Fleet
from repro.fleet.router import POLICIES as ROUTER_POLICIES
from repro.obs import SpanTracer, dump_merged
from repro.runtime.serving import Request

INJECT_SITES = ("none", "weights", "kv_cache", "decode_state")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.fleet.cli",
        description="Dependable multi-replica serving drill")
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--policy", default="abft",
                   choices=[pol.value for pol in FLEET_POLICIES])
    p.add_argument("--router", default="least_loaded",
                   choices=list(ROUTER_POLICIES))
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new-tokens", type=int, default=6)
    p.add_argument("--capacity", type=int, default=3,
                   help="decode slots per replica")
    p.add_argument("--scrub-every", type=int, default=4,
                   help="weight-scrub cadence in fleet ticks (abft)")
    p.add_argument("--inject", default="none", choices=list(INJECT_SITES),
                   help="SEU drill: corrupt replica 0's weights before "
                        "serving, or its decode-state buffer mid-serve")
    p.add_argument("--kill", type=int, default=-1, metavar="RID",
                   help="kill replica RID mid-serve (failover drill)")
    p.add_argument("--transport", default="inproc", choices=list(TRANSPORTS),
                   help="replica isolation: inproc (threads of one process) "
                        "or proc (one worker process per replica)")
    p.add_argument("--deploy", action="store_true",
                   help="rolling weight deploy mid-serve in both passes; "
                        "with --inject weights the strike lands during the "
                        "swap window")
    p.add_argument("--backend", default=None,
                   help="execution backend for every replica's quantized "
                        "hot paths (jnp | ref | pallas; default: cfg's)")
    p.add_argument("--policy-map", default=None, metavar="JSON",
                   help="per-site dependability policy map for the in-graph "
                        "hot paths: path to a PolicyMap JSON file (e.g. "
                        "reports/dse/best_map.json) or inline JSON text; "
                        "implies the W8A8 FFN quantized path so the ffn.* "
                        "sites exist (docs/dse.md)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="reports/fleet",
                   help="output directory for fleet.json")
    p.add_argument("--trace-out", default=None,
                   help="write a Chrome trace_event JSON of the drill pass "
                        "(every replica's pipeline spans; ui.perfetto.dev)")
    p.add_argument("--metrics-out", default=None,
                   help="write the drill pass's metrics registry snapshot "
                        "(.prom extension → Prometheus text format)")
    p.add_argument("--events-out", default=None,
                   help="write the drill pass's structured dependability "
                        "event log + reconstructed timelines as JSON")
    p.add_argument("--quiet", action="store_true")
    return p


def _serve(fleet: Fleet, prompts, max_new_tokens: int, *,
           inject: str = "none", kill: int = -1, key=None,
           deploy: bool = False):
    fleet.reset()
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        fleet.submit(r)
    if inject == "weights" and not deploy:
        fleet.strike(0, "weights", fi.flip_one_bit, key)
    mid_drill = inject in ("kv_cache", "decode_state") or kill >= 0
    if mid_drill:
        for _ in range(2):
            fleet.tick()
        if inject in ("kv_cache", "decode_state"):
            fleet.strike(0, inject, fi.flip_one_bit, key)
        if kill >= 0:
            fleet.kill_replica(kill)
    if deploy:
        for _ in range(2):
            fleet.tick()
        mid_swap = None
        if inject == "weights":
            struck = []

            def mid_swap(rid):
                # strike replica 0's weights while a *different* replica is
                # mid-swap — the in-flight-deploy SEU window (once per pass)
                if rid != 0 and not struck:
                    struck.append(rid)
                    fleet.strike(0, "weights", fi.flip_one_bit, key)
        fleet.deploy(params=fleet._params0, mid_swap=mid_swap)
    fleet.run()
    outputs = tuple(
        tuple(fleet.released[r.uid].output) if r.uid in fleet.released
        else None
        for r in reqs)
    return outputs


def main(argv=None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    from repro.configs import registry
    from repro.models import api as model_api
    from repro.models.config import reduced

    log = (lambda s: None) if args.quiet else (lambda s: print(s, flush=True))
    cfg = reduced(registry.get(args.arch))
    policy_map = None
    if args.policy_map is not None:
        import dataclasses
        from repro.core.policy_map import as_policy_map
        policy_map = as_policy_map(args.policy_map)
        # the mapped ffn.* sites live on the W8A8 quantized FFN path
        cfg = dataclasses.replace(cfg, quant="w8a8_ffn")
    params = model_api.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(2, 7))).tolist()
               for _ in range(args.requests)]

    fleet = Fleet(cfg, params, n_replicas=args.replicas,
                  policy=Policy(args.policy), router=args.router,
                  scrub_every=args.scrub_every, capacity=args.capacity,
                  max_len=96, prefill_pad=8, backend=args.backend,
                  policy_map=policy_map, transport=args.transport)

    log(f"fleet: {args.replicas}×{cfg.name} replicas, policy={args.policy}, "
        f"router={args.router}, transport={args.transport}")
    log("golden pass (fault-free%s) …"
        % (", rolling deploy" if args.deploy else ""))
    golden = _serve(fleet, prompts, args.max_new_tokens, deploy=args.deploy)

    drill = args.inject != "none" or args.kill >= 0
    if drill:
        log(f"drill pass (inject={args.inject}, kill="
            f"{args.kill if args.kill >= 0 else 'none'}) …")
    tracers = []
    if args.trace_out and args.transport == "inproc":
        # one tracer per replica engine (pid = replica id) — attached after
        # the golden pass so the trace covers exactly the drill.  (proc
        # replicas run their engine in another process; spans stay there.)
        for r in fleet.replicas:
            tr = SpanTracer(name=f"replica{r.rid}", pid=r.rid)
            r.engine.tracer = tr
            tracers.append(tr)
    observed = _serve(fleet, prompts, args.max_new_tokens,
                      inject=args.inject, kill=args.kill,
                      key=jax.random.key(args.seed + 1),
                      deploy=args.deploy)

    report = fleet.report()
    report["arch"] = cfg.name
    report["router"] = args.router
    report["seed"] = args.seed
    report["inject"] = args.inject
    report["kill"] = args.kill
    report["deploy"] = bool(args.deploy)
    report["policy_map"] = policy_map.to_doc() if policy_map else None
    report["outputs_match_golden"] = observed == golden
    fleet.close()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    jpath = out / "fleet.json"
    jpath.write_text(json.dumps(report, indent=2))

    if args.trace_out:
        tpath = dump_merged(tracers, args.trace_out)
        log(f"wrote {tpath} (open in ui.perfetto.dev)")
    if args.metrics_out:
        mpath = fleet.metrics.registry.dump(args.metrics_out)
        log(f"wrote {mpath}")
    if args.events_out:
        epath = fleet.event_log.dump(args.events_out)
        log(f"wrote {epath} ({len(fleet.event_log)} events)")

    log(json.dumps({k: v for k, v in report.items() if k != "events"},
                   indent=2))
    for e in report["events"]:
        log(f"  event: {e}")
    print(f"released {report['released']}/{report['submitted']} requests, "
          f"recoveries={report['recoveries']}, detections="
          f"{report['detections']}, outputs_match_golden="
          f"{report['outputs_match_golden']}; wrote {jpath}")

    if not report["outputs_match_golden"]:
        print("released output stream differs from golden run "
              "(silent corruption under this policy)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
