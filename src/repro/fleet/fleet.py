"""Multi-replica dependable serving — the repo's "dependable service" layer.

The paper's system property — orchestrator watches, co-processor computes,
faults never corrupt the output stream — promoted from a single ``Engine``
to a supervised fleet of N of them:

    client ──▶ Router (hash / least-loaded, admission, deadlines)
                  │ assigns
                  ▼
        ┌──── Replica 0 ── Engine ────┐
        │     Replica 1 ── Engine     │──▶ certified output stream
        │         …                   │
        └── Replica N-1 ── Engine ────┘
                  ▲ scrubs / heartbeats / recovery
              Supervisor (Orchestrator policies + ABFT storage checksums
                          + checkpoint reload)

The dependability contract is **certify-before-release**, and since the
engine became a staged dataflow pipeline (runtime/dataflow.py) it is
enforced *inside each engine's certify stage*: the fleet installs a
release-gate hook (``_certify_finished``) into every replica's pipeline, so
a finished request is withheld at the certify stage — not by fleet code
wrapped around a monolithic step loop —

  * ``Policy.NONE``  release immediately (the undefended baseline campaigns
    measure SDC against);
  * ``Policy.ABFT``  release only after the serving replica passes a weight
    scrub dated *after* the request finished.  A failed scrub recalls every
    uncertified request and replays it on a verified replica, so a weight
    SEU can delay tokens but never ship them wrong.  Lost work is bounded
    by scrub_every × capacity tokens per replica.
  * ``Policy.DMR``   every request is decoded twice on distinct replicas
    (primary + shadow); bit-identical streams release immediately, any
    disagreement is detected, attributed by scrubbing both replicas
    (corrupted one recovers via checkpoint reload), and the request replays
    on a clean replica.  Catches *transient* compute/state faults the
    weight scrub cannot see, at 2× decode cost.
  * ``Policy.CKPT``  checkpoint/restart as the primary strategy: ABFT's
    certify-before-release weight scrubs, plus decode-state scrubbing with
    engine snapshot *rollback* — a transient SEU in the KV cache or token
    buffer is detected by checksum and healed by replaying at most
    ``snapshot_every`` steps in place, no failover needed.  ABFT fleets
    run the same decode-state scrub in detect-only mode (transient-site
    coverage the ROADMAP called for) and recover by drain + failover.

Quarantine-recovery (any policy) is *incremental first*: the scrub verdict
names the corrupted tensors and the supervisor restores exactly those
leaves from the golden checkpoint, timing every recovery into the metrics
(``recovery_mean_seconds``, ``incremental_restores`` vs ``full_reloads``).

Failover is deterministic: greedy decode is a pure function of (params,
prompt) and the engine's continuous batching is composition-independent, so
a replayed request reproduces its tokens bit-exactly on any clean replica —
the property the campaign workload certifies statistically.

Everything advances on an integer ``tick`` (one engine step per healthy
replica) and every decision is a pure function of fleet state, so a trial
replays bit-for-bit from its seed.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time
from typing import Dict, List, Optional

from repro.core.dependability import Policy
from repro.fleet.metrics import FleetMetrics
from repro.obs import EventLog
from repro.fleet.replica import Replica, ReplicaState
from repro.fleet.router import Router
from repro.fleet.supervisor import Supervisor
from repro.models.config import ArchConfig
from repro.runtime.serving import Request
from repro.train import checkpoint as ckpt_mod

FLEET_POLICIES = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.CKPT)

# policies whose release gate is the weight-scrub certification loop
_SCRUB_GATED = (Policy.ABFT, Policy.CKPT)


def _state_scrub_mode(policy: Policy) -> str:
    """Engine decode-state scrub mode per fleet policy: CKPT rolls back in
    place (engine-local checkpoint/restart), ABFT detects and lets the
    fleet drain + fail over, NONE/DMR leave the scrub off (DMR's pair
    comparison is its transient detector)."""
    if policy == Policy.CKPT:
        return "rollback"
    if policy == Policy.ABFT:
        return "detect"
    return "off"


@dataclasses.dataclass
class _Tracked:
    """Fleet-side lifecycle record for one submitted request."""
    req: Request                      # the caller's object (primary copy)
    shadow: Optional[Request]         # DMR twin, served on a different replica
    primary_rid: int
    shadow_rid: int = -1
    submitted_tick: int = 0
    deadline_ticks: Optional[int] = None
    primary_done: bool = False
    shadow_done: bool = False
    replays: int = 0
    released: bool = False
    expired: bool = False
    failed: bool = False

    @property
    def terminal(self) -> bool:
        return self.released or self.expired or self.failed


class Fleet:
    MAX_REPLAYS = 3

    def __init__(self, cfg: ArchConfig, params, n_replicas: int = 2, *,
                 policy: Policy = Policy.ABFT, router: str = "least_loaded",
                 admit_limit: Optional[int] = None, scrub_every: int = 4,
                 capacity: int = 4, max_len: int = 128, prefill_pad: int = 8,
                 snapshot_every: int = 16, eos_id: int = -1,
                 heartbeat_timeout: float = 25.0, ckpt_dir: Optional[str] = None,
                 backend: Optional[str] = None):
        if policy not in FLEET_POLICIES:
            raise ValueError(
                f"fleet policy must be one of {[p.value for p in FLEET_POLICIES]}"
                f" (TMR at fleet scale is three engines + vote; use DMR + "
                f"failover, the 2× alternative this fleet implements)")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.cfg = cfg
        self.policy = policy
        self.scrub_every = scrub_every

        # golden state: checkpoint for reload-recovery, checksums for scrub
        self._params0 = params
        self._owns_ckpt_dir = ckpt_dir is None
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="fleet-golden-")
        ckpt_mod.save(self.ckpt_dir, 0, params)

        # every replica serves on the same execution backend: bit-identical
        # failover (the fleet's core guarantee) holds *across* backends too,
        # but certify-before-release compares like for like within a fleet
        scrub_mode = _state_scrub_mode(policy)
        first = Replica(0, cfg, params, capacity=capacity, max_len=max_len,
                        prefill_pad=prefill_pad, snapshot_every=snapshot_every,
                        eos_id=eos_id, backend=backend,
                        state_scrub=scrub_mode)
        self.replicas: List[Replica] = [first] + [
            Replica(i, cfg, params, capacity=capacity, max_len=max_len,
                    prefill_pad=prefill_pad, snapshot_every=snapshot_every,
                    eos_id=eos_id, golden=first.golden,
                    compiled=first.engine.compiled, backend=backend,
                    state_scrub=scrub_mode)
            for i in range(1, n_replicas)]
        # the fleet's release gate runs inside each engine's certify stage
        for r in self.replicas:
            r.install_certifier(self._certify_finished)
        self.router = Router(router, admit_limit)
        self.supervisor = Supervisor(n_replicas, scrub_every=scrub_every,
                                     heartbeat_timeout=heartbeat_timeout)
        self.metrics = FleetMetrics(
            lost_work_bound_tokens=scrub_every * capacity)
        # structured dependability event log on the fleet tick clock; the
        # supervisor shares it so scrub/recovery verdicts carry provenance.
        # Replica engines do NOT share it — their pump-cycle clock differs
        # from the fleet tick, and mixing clocks would corrupt timeline
        # latencies; engine-level verdicts reach this log via
        # _settle_state_events (stamped with the fleet tick).
        self.event_log = EventLog(policy=policy.value)
        self.supervisor.event_log = self.event_log
        self.tick_no = 0
        self.records: Dict[int, _Tracked] = {}
        self.released: Dict[int, Request] = {}

    # ------------------------------------------------------------ admission
    def submit(self, req: Request,
               deadline_ticks: Optional[int] = None) -> bool:
        """Route a request into the fleet; False == rejected (admission
        control or no healthy replica)."""
        if req.uid in self.records:
            raise ValueError(f"duplicate request uid {req.uid}")
        self.metrics.submitted += 1
        primary = self.router.pick(req.uid, self.replicas)
        if primary is None:
            self.metrics.rejected += 1
            return False
        rec = _Tracked(req=req, shadow=None, primary_rid=primary.rid,
                       submitted_tick=self.tick_no,
                       deadline_ticks=deadline_ticks)
        if self.policy == Policy.DMR:
            self._place_shadow(rec)
        primary.engine.submit(req)
        self.records[req.uid] = rec
        return True

    def _place_shadow(self, rec: _Tracked):
        """DMR twin placement: a copy of the request on a healthy replica
        other than the primary.  With no second healthy replica the request
        serves undoubled (degraded DMR: release on finish, logged)."""
        shadow_replica = self.router.pick(rec.req.uid, self.replicas,
                                          exclude=(rec.primary_rid,))
        if shadow_replica is None:
            rec.shadow = None
            rec.shadow_rid = -1
            self.supervisor.events.append(
                f"tick {self.tick_no}: uid {rec.req.uid} served without "
                f"shadow (no second healthy replica)")
            return
        rec.shadow = Request(uid=rec.req.uid, prompt=list(rec.req.prompt),
                             max_new_tokens=rec.req.max_new_tokens)
        rec.shadow_rid = shadow_replica.rid
        shadow_replica.engine.submit(rec.shadow)

    # ----------------------------------------------------------- tick loop
    def tick(self):
        """One fleet scheduling round: step every healthy engine (each step
        pumps the replica's admit→…→release pipeline once, with the fleet's
        release gate live in the certify stage), heartbeat, scrub on
        cadence, expire deadlines."""
        self.tick_no += 1
        self.metrics.ticks += 1
        for r in self.replicas:
            if r.state is not ReplicaState.HEALTHY or r.paused:
                continue
            t0 = time.perf_counter()
            r.engine.step()
            self.metrics.engine_steps += 1
            self.supervisor.heartbeat(r.rid, r.engine.stats.steps,
                                      time.perf_counter() - t0, self.tick_no)
            self._settle_state_events(r)
        self.supervisor.stragglers()      # straggler log (advisory in-process)

        for rid in self.supervisor.newly_dead(self.tick_no):
            r = self.replicas[rid]
            if r.state is ReplicaState.HEALTHY:
                self._fail_replica(r, reason="heartbeat timeout",
                                   recover=False)

        if self.policy in _SCRUB_GATED and self.supervisor.due_for_scrub(
                self.tick_no):
            for r in self.replicas:
                if r.state is ReplicaState.HEALTHY:
                    self._scrub_and_settle(r)

        self._expire_deadlines()

    # ------------------------------------------------- decode-state scrubs
    def _settle_state_events(self, replica: Replica):
        """Fold the engine's decode-state scrub verdicts into fleet metrics
        and finish the recovery the engine could not do alone: a CKPT
        engine already rolled back (we only account it); a detect-only
        (ABFT) engine — or a rollback that found its snapshot corrupted —
        needs the fleet to drain the replica's work, clear its decode
        state, and replay on verified replicas."""
        for ev in replica.engine.drain_state_events():
            self.metrics.detections += 1
            self.metrics.state_scrub_detections += 1
            action = (f"rolled back {ev['steps_replayed']} steps"
                      if ev["recovered"] else "drain + replay")
            self.supervisor.events.append(
                f"tick {self.tick_no}: replica {replica.rid} decode-state "
                f"scrub detected corruption ({action})")
            self.event_log.emit(
                "detection", tick=self.tick_no, site="decode_state",
                replica=replica.rid, detail={"check": "state_scrub"})
            if ev["recovered"]:
                self.metrics.observe_recovery(ev["seconds"], rollback=True)
                self.event_log.emit(
                    "rollback", tick=self.tick_no, site="decode_state",
                    replica=replica.rid, seconds=ev["seconds"],
                    detail={"steps_replayed": ev["steps_replayed"]})
                continue
            t0 = time.perf_counter()
            drained = replica.in_flight() + replica.uncertified
            replica.uncertified = []
            # weights are untouched by a state SEU: a run-state reset (not a
            # quarantine) makes the replica clean again
            replica.engine.reset()
            seconds = time.perf_counter() - t0
            self.metrics.recovery_seconds.observe(seconds)
            self.metrics.state_drains += 1
            self.event_log.emit(
                "recovery", tick=self.tick_no, site="decode_state",
                replica=replica.rid, seconds=seconds,
                detail={"action": "drain_replay", "drained": len(drained)})
            for req in drained:
                rec = self.records.get(req.uid)
                if rec is not None and not rec.terminal:
                    self._replay(rec)

    def run(self, max_ticks: int = 100_000) -> FleetMetrics:
        """Serve until every submitted request reaches a terminal state
        (released / expired / failed) or the tick budget runs out."""
        while self.tick_no < max_ticks:
            if not self._work_pending():
                self._final_certification()
                if not self._work_pending():
                    break
            self.tick()
        return self.metrics

    # ------------------------------------------------------ finish handling
    def _certify_finished(self, replica: Replica, req: Request) -> bool:
        """The fleet's release gate, run *inside* each replica engine's
        certify stage (installed via ``Replica.install_certifier``).  True
        lets the request flow on to the engine's release stage; False
        withholds it — the fleet has taken custody (uncertified list, DMR
        pair bookkeeping, or a stale copy that is simply dropped)."""
        rec = self.records.get(req.uid)
        if rec is None or rec.terminal:
            return False
        is_primary = req is rec.req
        if not is_primary and req is not rec.shadow:
            return False                             # stale pre-replay copy
        if self.policy in _SCRUB_GATED:
            if is_primary:
                replica.uncertified.append(req)
            return False       # withheld until a clean post-finish scrub
        if self.policy == Policy.DMR and rec.shadow is not None:
            if is_primary:
                rec.primary_done = True
            else:
                rec.shadow_done = True
            if rec.primary_done and rec.shadow_done:
                if rec.req.output == rec.shadow.output:
                    self._release(rec)
                    return True
                self._dmr_mismatch(rec)
            return False
        # Policy.NONE (or degraded DMR): release on finish
        if is_primary:
            self._release(rec)
            return True
        return False

    def _release(self, rec: _Tracked):
        rec.released = True
        self.released[rec.req.uid] = rec.req
        self.metrics.observe_release(self.tick_no - rec.submitted_tick,
                                     len(rec.req.output or []))

    # ------------------------------------------------------------ ABFT path
    def _scrub_and_settle(self, replica: Replica):
        """Scrub a replica; clean ⇒ certify+release its finished requests,
        dirty ⇒ full recovery loop + recall/replay of everything uncertified
        or in flight."""
        if self.supervisor.scrub(replica, self.metrics, self.tick_no):
            for req in replica.uncertified:
                rec = self.records.get(req.uid)
                if rec is not None and not rec.terminal:
                    self._release(rec)
            replica.uncertified = []
        else:
            self._fail_replica(replica, reason="weight scrub failed",
                               recover=True)

    # ----------------------------------------------------------- DMR path
    def _dmr_mismatch(self, rec: _Tracked):
        """Primary and shadow streams disagree: detect, attribute by
        scrubbing both replicas (weight-SEU ⇒ recovery loop), then replay
        the request on a clean replica (transient faults leave both scrubs
        clean; the fresh third execution is the tie-breaker)."""
        self.metrics.detections += 1
        self.supervisor.events.append(
            f"tick {self.tick_no}: uid {rec.req.uid} DMR mismatch "
            f"(replicas {rec.primary_rid}/{rec.shadow_rid})")
        self.event_log.emit(
            "detection", tick=self.tick_no, uid=rec.req.uid,
            replica=rec.primary_rid,
            detail={"check": "dmr_compare", "shadow_rid": rec.shadow_rid})
        for rid in (rec.primary_rid, rec.shadow_rid):
            r = self.replicas[rid]
            if r.state is ReplicaState.HEALTHY and not self.supervisor.scrub(
                    r, self.metrics, self.tick_no):
                self._fail_replica(r, reason="weight scrub failed "
                                   "(DMR attribution)", recover=True)
        self._replay(rec)

    # ------------------------------------------------------------ injection
    def strike(self, rid: int, site: str, fault, key) -> None:
        """Campaign/drill injection surface: route an SEU to a replica's
        engine and record it — with fault provenance and the fleet tick —
        in the event log, so reports can reconstruct the
        injection→detection→recovery timeline."""
        self.event_log.emit(
            "strike", tick=self.tick_no, site=site, replica=rid,
            fault=getattr(fault, "name", getattr(fault, "__name__", "")))
        self.replicas[rid].engine.strike(site, fault, key)

    # ------------------------------------------------------------- failover
    def kill_replica(self, rid: int, reason: str = "killed"):
        """Simulated hard loss (test/campaign hook): the replica is DEAD and
        its in-flight work fails over to the healthy survivors."""
        r = self.replicas[rid]
        if r.state is ReplicaState.DEAD:
            return
        self._fail_replica(r, reason=reason, recover=False)

    def pause_replica(self, rid: int):
        """Stop stepping/heartbeating a replica without killing it — the
        supervisor's heartbeat timeout must notice on its own."""
        self.replicas[rid].paused = True

    def _fail_replica(self, replica: Replica, *, reason: str, recover: bool):
        """Common exit from HEALTHY: drain every request the replica owns
        (queued, decoding, finished-but-uncertified), run the recovery loop
        if asked, then replay the drained work on verified replicas."""
        drained = replica.in_flight() + replica.uncertified
        replica.uncertified = []
        self.supervisor.events.append(
            f"tick {self.tick_no}: replica {replica.rid} failed ({reason}); "
            f"{len(drained)} requests drained")
        if recover:
            self.supervisor.recover(replica, self.ckpt_dir, self.metrics,
                                    self.tick_no)
        else:
            replica.state = ReplicaState.DEAD
            self.metrics.replicas_lost += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: replica {replica.rid} DEAD ({reason})")
            self.event_log.emit("replica_dead", tick=self.tick_no,
                                replica=replica.rid,
                                detail={"reason": reason})
        for req in drained:
            rec = self.records.get(req.uid)
            if rec is not None and not rec.terminal:
                self._replay(rec)

    def _replay(self, rec: _Tracked):
        """Deterministic failover: requeue the request (and its DMR shadow)
        from the prompt on healthy replicas; decode determinism makes the
        replayed stream bit-identical to what a fault-free replica would
        have produced."""
        rec.replays += 1
        self.metrics.failovers += 1
        self.event_log.emit("failover", tick=self.tick_no, uid=rec.req.uid,
                            detail={"replay": rec.replays})
        self.metrics.lost_tokens += len(rec.req.output or [])
        if rec.shadow is not None:
            self.metrics.lost_tokens += len(rec.shadow.output or [])
        # evict any copy still resident somewhere (queued on a replica that
        # did not fail, half of a DMR pair, …)
        for r in self.replicas:
            r.engine.cancel(rec.req.uid)
            r.uncertified = [q for q in r.uncertified if q.uid != rec.req.uid]
        if rec.replays > self.MAX_REPLAYS:
            rec.failed = True
            self.metrics.failed += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: uid {rec.req.uid} FAILED "
                f"(replay budget exhausted)")
            return
        rec.req.output = None
        rec.req.finished_at = 0.0
        rec.primary_done = rec.shadow_done = False
        primary = self.router.pick(rec.req.uid, self.replicas)
        if primary is None:
            rec.failed = True
            self.metrics.failed += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: uid {rec.req.uid} FAILED "
                f"(no healthy replica for failover)")
            return
        rec.primary_rid = primary.rid
        if self.policy == Policy.DMR:
            self._place_shadow(rec)
        primary.engine.submit(rec.req)

    # ------------------------------------------------------------ deadlines
    def _expire_deadlines(self):
        for rec in self.records.values():
            if rec.terminal or rec.deadline_ticks is None:
                continue
            if self.tick_no - rec.submitted_tick > rec.deadline_ticks:
                rec.expired = True
                self.metrics.deadline_misses += 1
                for r in self.replicas:
                    r.engine.cancel(rec.req.uid)
                    r.uncertified = [q for q in r.uncertified
                                     if q.uid != rec.req.uid]
                self.supervisor.events.append(
                    f"tick {self.tick_no}: uid {rec.req.uid} missed its "
                    f"deadline ({rec.deadline_ticks} ticks)")

    # ------------------------------------------------------------- draining
    def _engines_busy(self) -> bool:
        return any(r.state is ReplicaState.HEALTHY and not r.paused
                   and (r.engine.queue or r.engine.active)
                   for r in self.replicas)

    def _work_pending(self) -> bool:
        if self._engines_busy():
            return True
        return any(not rec.terminal for rec in self.records.values())

    def _final_certification(self):
        """End-of-stream settlement: scrub every replica still holding
        uncertified output so the tail of the stream is certified (or
        recalled) even when the tick count never hits the scrub cadence."""
        if self.policy in _SCRUB_GATED:
            for r in self.replicas:
                if r.state is ReplicaState.HEALTHY and r.uncertified:
                    self._scrub_and_settle(r)
        # non-ABFT terminal stragglers: requests stranded on dead replicas
        for rec in list(self.records.values()):
            if not rec.terminal and not self._request_resident(rec):
                self._replay(rec)

    def _request_resident(self, rec: _Tracked) -> bool:
        """Is any live copy of the request still queued/decoding/uncertified
        on a healthy replica?"""
        for r in self.replicas:
            if r.state is not ReplicaState.HEALTHY:
                continue
            for req in r.in_flight() + r.uncertified:
                if req.uid == rec.req.uid:
                    return True
        return False

    # --------------------------------------------------------------- reset
    def reset(self, policy: Optional[Policy] = None):
        """Return the fleet to a fresh, fully-healthy state with the golden
        params (campaign trials reuse one fleet so engines stay compiled).
        Dependability counters restart; the golden checkpoint is reused."""
        if policy is not None:
            if policy not in FLEET_POLICIES:
                raise ValueError(f"fleet policy must be one of "
                                 f"{[p.value for p in FLEET_POLICIES]}")
            self.policy = policy
        scrub_mode = _state_scrub_mode(self.policy)
        for r in self.replicas:
            r.engine.state_scrub = scrub_mode
            r.reset(params=self._params0)
        self.supervisor.reset()
        self.metrics = FleetMetrics(
            lost_work_bound_tokens=self.metrics.lost_work_bound_tokens)
        self.event_log = EventLog(policy=self.policy.value)
        self.supervisor.event_log = self.event_log
        self.tick_no = 0
        self.records = {}
        self.released = {}

    def close(self):
        """Delete the golden checkpoint directory if this fleet created it
        (a caller-supplied ckpt_dir is the caller's to manage)."""
        if self._owns_ckpt_dir:
            shutil.rmtree(self.ckpt_dir, ignore_errors=True)
            self._owns_ckpt_dir = False

    def __del__(self):
        try:
            self.close()
        except Exception:       # noqa: BLE001 — interpreter teardown
            pass

    # -------------------------------------------------------------- report
    def report(self, wall: bool = False) -> dict:
        """Fleet metrics + per-replica state, JSON-ready.  ``wall=True``
        adds the wall-clock-derived rates (non-deterministic; see
        ``FleetMetrics.to_json``)."""
        out = self.metrics.to_json(wall=wall)
        out["policy"] = self.policy.value
        out["replicas"] = [
            {"rid": r.rid, "state": r.state.value,
             "recoveries": r.recoveries,
             "engine_steps": r.engine.stats.steps,
             "engine_tokens_out": r.engine.stats.tokens_out}
            for r in self.replicas]
        out["events"] = list(self.supervisor.events)
        return out
