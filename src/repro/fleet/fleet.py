"""Multi-replica dependable serving — the repo's "dependable service" layer.

The paper's system property — orchestrator watches, co-processor computes,
faults never corrupt the output stream — promoted from a single ``Engine``
to a supervised fleet of N of them:

    client ──▶ Router (hash / least-loaded, admission, deadlines)
                  │ assigns
                  ▼
        ┌──── Replica 0 ── Engine ────┐
        │     Replica 1 ── Engine     │──▶ certified output stream
        │         …                   │
        └── Replica N-1 ── Engine ────┘
                  ▲ scrubs / heartbeats / recovery
              Supervisor (Orchestrator policies + ABFT storage checksums
                          + checkpoint reload)

The dependability contract is **certify-before-release**, and since the
engine became a staged dataflow pipeline (runtime/dataflow.py) it is
enforced *inside each engine's certify stage*: the fleet installs a
release-gate hook (``_certify_finished``) into every replica's pipeline, so
a finished request is withheld at the certify stage — not by fleet code
wrapped around a monolithic step loop —

  * ``Policy.NONE``  release immediately (the undefended baseline campaigns
    measure SDC against);
  * ``Policy.ABFT``  release only after the serving replica passes a weight
    scrub dated *after* the request finished.  A failed scrub recalls every
    uncertified request and replays it on a verified replica, so a weight
    SEU can delay tokens but never ship them wrong.  Lost work is bounded
    by scrub_every × capacity tokens per replica.
  * ``Policy.DMR``   every request is decoded twice on distinct replicas
    (primary + shadow); bit-identical streams release immediately, any
    disagreement is detected, attributed by scrubbing both replicas
    (corrupted one recovers via checkpoint reload), and the request replays
    on a clean replica.  Catches *transient* compute/state faults the
    weight scrub cannot see, at 2× decode cost.
  * ``Policy.CKPT``  checkpoint/restart as the primary strategy: ABFT's
    certify-before-release weight scrubs, plus decode-state scrubbing with
    engine snapshot *rollback* — a transient SEU in the KV cache or token
    buffer is detected by checksum and healed by replaying at most
    ``snapshot_every`` steps in place, no failover needed.  ABFT fleets
    run the same decode-state scrub in detect-only mode (transient-site
    coverage the ROADMAP called for) and recover by drain + failover.

Quarantine-recovery (any policy) is *incremental first*: the scrub verdict
names the corrupted tensors and the supervisor restores exactly those
leaves from the golden checkpoint, timing every recovery into the metrics
(``recovery_mean_seconds``, ``incremental_restores`` vs ``full_reloads``).

Failover is deterministic: greedy decode is a pure function of (params,
prompt) and the engine's continuous batching is composition-independent, so
a replayed request reproduces its tokens bit-exactly on any clean replica —
the property the campaign workload certifies statistically.

Everything advances on an integer ``tick`` (one engine step per healthy
replica) and every decision is a pure function of fleet state, so a trial
replays bit-for-bit from its seed.

Two orthogonal capabilities ride on that contract (docs/multihost.md):

  * ``transport="proc"`` runs every replica's engine in a spawned worker
    process behind ``fleet/transport.py`` — same Fleet/Supervisor/Router
    code, real process isolation, token streams bit-identical to inproc.
    A dead worker (SIGKILL, crash, missed RPC deadline) takes the same
    quarantine → restore → re-verify → replay path a failed scrub does.
  * The supervisor's straggler verdicts drive **speculative backup
    dispatch**: a straggler's in-flight requests are re-issued to a warm
    spare, the first finisher wins, and the loser's copy is cancelled at
    release — certify-before-release applies to whichever copy wins.
  * ``Fleet.deploy`` performs **zero-drain rolling weight deploys**: one
    replica at a time leaves the router (still decoding what it owns),
    has the changed leaves patched into its live engine, re-verifies
    against the *new* storage checksums, and rejoins — the fleet serves
    throughout, and a strike landing mid-swap is caught by the re-verify.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time
from typing import Dict, List, Optional

from repro.core.dependability import Policy
from repro.fleet.metrics import FleetMetrics
from repro.obs import EventLog
from repro.fleet.replica import Replica, ReplicaState, _checksums_jit
from repro.fleet.router import Router
from repro.fleet.supervisor import Supervisor
from repro.fleet.transport import TransportDead
from repro.models.config import ArchConfig
from repro.runtime.serving import Request
from repro.train import checkpoint as ckpt_mod

TRANSPORTS = ("inproc", "proc")

FLEET_POLICIES = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.CKPT)

# policies whose release gate is the weight-scrub certification loop
_SCRUB_GATED = (Policy.ABFT, Policy.CKPT)


def _state_scrub_mode(policy: Policy) -> str:
    """Engine decode-state scrub mode per fleet policy: CKPT rolls back in
    place (engine-local checkpoint/restart), ABFT detects and lets the
    fleet drain + fail over, NONE/DMR leave the scrub off (DMR's pair
    comparison is its transient detector)."""
    if policy == Policy.CKPT:
        return "rollback"
    if policy == Policy.ABFT:
        return "detect"
    return "off"


@dataclasses.dataclass
class _Tracked:
    """Fleet-side lifecycle record for one submitted request."""
    req: Request                      # the caller's object (primary copy)
    shadow: Optional[Request]         # DMR twin, served on a different replica
    primary_rid: int
    shadow_rid: int = -1
    backup: Optional[Request] = None  # speculative copy on a warm spare
    backup_rid: int = -1
    submitted_tick: int = 0
    deadline_ticks: Optional[int] = None
    primary_done: bool = False
    shadow_done: bool = False
    replays: int = 0
    released: bool = False
    expired: bool = False
    failed: bool = False

    @property
    def terminal(self) -> bool:
        return self.released or self.expired or self.failed


class Fleet:
    MAX_REPLAYS = 3

    def __init__(self, cfg: ArchConfig, params, n_replicas: int = 2, *,
                 policy: Policy = Policy.ABFT, router: str = "least_loaded",
                 admit_limit: Optional[int] = None, scrub_every: int = 4,
                 capacity: int = 4, max_len: int = 128, prefill_pad: int = 8,
                 snapshot_every: int = 16, eos_id: int = -1,
                 heartbeat_timeout: float = 25.0, ckpt_dir: Optional[str] = None,
                 backend: Optional[str] = None, policy_map=None,
                 transport: str = "inproc"):
        # per-site selective hardening for every replica's in-graph hot
        # paths (core/policy_map.py; PolicyMap | JSON doc/text/path).  Baked
        # into cfg so all replicas — including proc-transport workers, which
        # receive the pickled config — compile the same mapped program.  The
        # fleet keeps its own scrub orchestration (certify-before-release
        # weight scrubs, decode-state scrub modes) driven by ``policy``;
        # the map governs the op-level policies inside each engine.
        from repro.models import api as _model_api
        cfg = _model_api.with_policy_map(cfg, policy_map)
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; "
                             f"known: {TRANSPORTS}")
        if policy not in FLEET_POLICIES:
            raise ValueError(
                f"fleet policy must be one of {[p.value for p in FLEET_POLICIES]}"
                f" (TMR at fleet scale is three engines + vote; use DMR + "
                f"failover, the 2× alternative this fleet implements)")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.cfg = cfg
        self.policy = policy
        self.scrub_every = scrub_every
        self.transport = transport

        # golden state: checkpoint for reload-recovery, checksums for scrub
        self._params0 = params
        self._owns_ckpt_dir = ckpt_dir is None
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="fleet-golden-")
        ckpt_mod.save(self.ckpt_dir, 0, params)
        self._current_step = 0      # the checkpoint step replicas serve from

        # every replica serves on the same execution backend: bit-identical
        # failover (the fleet's core guarantee) holds *across* backends too,
        # but certify-before-release compares like for like within a fleet
        scrub_mode = _state_scrub_mode(policy)
        if transport == "proc":
            # each replica's engine lives in a spawned worker process; the
            # workers restore the golden checkpoint themselves (crc32-
            # verified, so byte-identical to ``params``) and compile in
            # parallel — spawn all first, then wait on each
            from repro.fleet.transport import ProcReplica
            self.replicas: List[Replica] = [
                ProcReplica(i, cfg, ckpt_dir=self.ckpt_dir, step=0,
                            capacity=capacity, max_len=max_len,
                            prefill_pad=prefill_pad,
                            snapshot_every=snapshot_every, eos_id=eos_id,
                            backend=backend, state_scrub=scrub_mode)
                for i in range(n_replicas)]
            for r in self.replicas:
                r.wait_ready()
            self._golden0 = _checksums_jit(params)
        else:
            first = Replica(0, cfg, params, capacity=capacity,
                            max_len=max_len, prefill_pad=prefill_pad,
                            snapshot_every=snapshot_every,
                            eos_id=eos_id, backend=backend,
                            state_scrub=scrub_mode)
            self.replicas = [first] + [
                Replica(i, cfg, params, capacity=capacity, max_len=max_len,
                        prefill_pad=prefill_pad,
                        snapshot_every=snapshot_every,
                        eos_id=eos_id, golden=first.golden,
                        compiled=first.engine.compiled, backend=backend,
                        state_scrub=scrub_mode)
                for i in range(1, n_replicas)]
            self._golden0 = first.golden
        # the fleet's release gate runs inside each engine's certify stage;
        # ckpt_step pins which checkpoint step each replica's golden
        # checksums correspond to (it moves per-replica during a rolling
        # deploy, so recovery always restores what the replica certifies)
        for r in self.replicas:
            r.install_certifier(self._certify_finished)
            r.ckpt_step = 0
        self.router = Router(router, admit_limit)
        self.supervisor = Supervisor(n_replicas, scrub_every=scrub_every,
                                     heartbeat_timeout=heartbeat_timeout)
        self.metrics = FleetMetrics(
            lost_work_bound_tokens=scrub_every * capacity)
        # structured dependability event log on the fleet tick clock; the
        # supervisor shares it so scrub/recovery verdicts carry provenance.
        # Replica engines do NOT share it — their pump-cycle clock differs
        # from the fleet tick, and mixing clocks would corrupt timeline
        # latencies; engine-level verdicts reach this log via
        # _settle_state_events (stamped with the fleet tick).
        self.event_log = EventLog(policy=policy.value)
        self.supervisor.event_log = self.event_log
        self.tick_no = 0
        self.records: Dict[int, _Tracked] = {}
        self.released: Dict[int, Request] = {}

    # ------------------------------------------------------------ admission
    def submit(self, req: Request,
               deadline_ticks: Optional[int] = None) -> bool:
        """Route a request into the fleet; False == rejected (admission
        control or no healthy replica)."""
        if req.uid in self.records:
            raise ValueError(f"duplicate request uid {req.uid}")
        self.metrics.submitted += 1
        primary = self.router.pick(req.uid, self.replicas)
        if primary is None:
            self.metrics.rejected += 1
            return False
        rec = _Tracked(req=req, shadow=None, primary_rid=primary.rid,
                       submitted_tick=self.tick_no,
                       deadline_ticks=deadline_ticks)
        if self.policy == Policy.DMR:
            self._place_shadow(rec)
        primary.engine.submit(req)
        self.records[req.uid] = rec
        return True

    def _place_shadow(self, rec: _Tracked):
        """DMR twin placement: a copy of the request on a healthy replica
        other than the primary.  With no second healthy replica the request
        serves undoubled (degraded DMR: release on finish, logged)."""
        shadow_replica = self.router.pick(rec.req.uid, self.replicas,
                                          exclude=(rec.primary_rid,))
        if shadow_replica is None:
            rec.shadow = None
            rec.shadow_rid = -1
            self.supervisor.events.append(
                f"tick {self.tick_no}: uid {rec.req.uid} served without "
                f"shadow (no second healthy replica)")
            return
        rec.shadow = Request(uid=rec.req.uid, prompt=list(rec.req.prompt),
                             max_new_tokens=rec.req.max_new_tokens)
        rec.shadow_rid = shadow_replica.rid
        shadow_replica.engine.submit(rec.shadow)

    # ----------------------------------------------------------- tick loop
    def tick(self):
        """One fleet scheduling round: step every healthy engine (each step
        pumps the replica's admit→…→release pipeline once, with the fleet's
        release gate live in the certify stage), heartbeat, scrub on
        cadence, expire deadlines."""
        self.tick_no += 1
        self.metrics.ticks += 1
        for r in self.replicas:
            if r.state is not ReplicaState.HEALTHY or r.paused:
                continue
            t0 = time.perf_counter()
            try:
                r.engine.step()
            except TransportDead:
                self._recover_transport(r)
                continue
            self.metrics.engine_steps += 1
            # for the proc transport the step time is the RPC round trip —
            # a worker fighting its host shows up as a straggler naturally
            self.supervisor.heartbeat(r.rid, r.engine.stats.steps,
                                      time.perf_counter() - t0, self.tick_no)
            self._settle_state_events(r)
        stragglers = self.supervisor.stragglers()
        if stragglers:
            self._dispatch_backups(stragglers)

        for rid in self.supervisor.newly_dead(self.tick_no):
            r = self.replicas[rid]
            if r.state is ReplicaState.HEALTHY:
                self._fail_replica(r, reason="heartbeat timeout",
                                   recover=False)

        if self.policy in _SCRUB_GATED and self.supervisor.due_for_scrub(
                self.tick_no):
            for r in self.replicas:
                if r.state is ReplicaState.HEALTHY:
                    self._scrub_and_settle(r)

        self._expire_deadlines()

    # ------------------------------------------------- decode-state scrubs
    def _settle_state_events(self, replica: Replica):
        """Fold the engine's decode-state scrub verdicts into fleet metrics
        and finish the recovery the engine could not do alone: a CKPT
        engine already rolled back (we only account it); a detect-only
        (ABFT) engine — or a rollback that found its snapshot corrupted —
        needs the fleet to drain the replica's work, clear its decode
        state, and replay on verified replicas."""
        for ev in replica.engine.drain_state_events():
            self.metrics.detections += 1
            self.metrics.state_scrub_detections += 1
            action = (f"rolled back {ev['steps_replayed']} steps"
                      if ev["recovered"] else "drain + replay")
            self.supervisor.events.append(
                f"tick {self.tick_no}: replica {replica.rid} decode-state "
                f"scrub detected corruption ({action})")
            self.event_log.emit(
                "detection", tick=self.tick_no, site="decode_state",
                replica=replica.rid, detail={"check": "state_scrub"})
            if ev["recovered"]:
                self.metrics.observe_recovery(ev["seconds"], rollback=True)
                self.event_log.emit(
                    "rollback", tick=self.tick_no, site="decode_state",
                    replica=replica.rid, seconds=ev["seconds"],
                    detail={"steps_replayed": ev["steps_replayed"]})
                continue
            t0 = time.perf_counter()
            drained = replica.in_flight() + replica.uncertified
            replica.uncertified = []
            # weights are untouched by a state SEU: a run-state reset (not a
            # quarantine) makes the replica clean again
            replica.engine.reset()
            seconds = time.perf_counter() - t0
            self.metrics.recovery_seconds.observe(seconds)
            self.metrics.state_drains += 1
            self.event_log.emit(
                "recovery", tick=self.tick_no, site="decode_state",
                replica=replica.rid, seconds=seconds,
                detail={"action": "drain_replay", "drained": len(drained)})
            for req in drained:
                rec = self.records.get(req.uid)
                if rec is not None and not rec.terminal:
                    self._replay(rec)

    def run(self, max_ticks: int = 100_000) -> FleetMetrics:
        """Serve until every submitted request reaches a terminal state
        (released / expired / failed) or the tick budget runs out."""
        while self.tick_no < max_ticks:
            if not self._work_pending():
                self._final_certification()
                if not self._work_pending():
                    break
            self.tick()
        return self.metrics

    # ------------------------------------------------------ finish handling
    def _certify_finished(self, replica: Replica, req: Request) -> bool:
        """The fleet's release gate, run *inside* each replica engine's
        certify stage (installed via ``Replica.install_certifier``).  True
        lets the request flow on to the engine's release stage; False
        withholds it — the fleet has taken custody (uncertified list, DMR
        pair bookkeeping, or a stale copy that is simply dropped)."""
        rec = self.records.get(req.uid)
        if rec is None or rec.terminal:
            return False
        is_primary = req is rec.req
        is_shadow = rec.shadow is not None and req is rec.shadow
        is_backup = rec.backup is not None and req is rec.backup
        if not (is_primary or is_shadow or is_backup):
            return False                             # stale pre-replay copy
        if self.policy in _SCRUB_GATED:
            if is_primary or is_backup:
                replica.uncertified.append(req)
            return False       # withheld until a clean post-finish scrub
        if self.policy == Policy.DMR and rec.shadow is not None:
            if is_primary:
                rec.primary_done = True
            else:
                rec.shadow_done = True
            if rec.primary_done and rec.shadow_done:
                if rec.req.output == rec.shadow.output:
                    self._release(rec)
                    return True
                self._dmr_mismatch(rec)
            return False
        # Policy.NONE (or degraded DMR): release on finish — primary or
        # speculative backup, whichever finished first
        if is_primary or is_backup:
            self._release(rec, req)
            return True
        return False

    def _release(self, rec: _Tracked, req: Optional[Request] = None):
        """Certified release.  ``req`` is the winning copy (primary by
        default; the speculative backup when it finished/certified first) —
        the loser of a backup race is cancelled wherever it still runs, so
        its eventual release is suppressed."""
        req = rec.req if req is None else req
        rec.released = True
        self.released[rec.req.uid] = req
        self.metrics.observe_release(self.tick_no - rec.submitted_tick,
                                     len(req.output or []))
        if rec.backup is not None:
            won = req is rec.backup
            if won:
                self.metrics.backups_won += 1
            loser_rid = rec.primary_rid if won else rec.backup_rid
            if 0 <= loser_rid < len(self.replicas):
                loser = self.replicas[loser_rid]
                loser.engine.cancel(rec.req.uid)
                loser.uncertified = [q for q in loser.uncertified
                                     if q.uid != rec.req.uid]

    # ------------------------------------------------------------ ABFT path
    def _scrub_and_settle(self, replica: Replica):
        """Scrub a replica; clean ⇒ certify+release its finished requests,
        dirty ⇒ full recovery loop + recall/replay of everything uncertified
        or in flight."""
        if self.supervisor.scrub(replica, self.metrics, self.tick_no):
            for req in replica.uncertified:
                rec = self.records.get(req.uid)
                if rec is not None and not rec.terminal:
                    self._release(rec, req)
            replica.uncertified = []
        else:
            self._fail_replica(replica, reason="weight scrub failed",
                               recover=True)

    # ----------------------------------------------------------- DMR path
    def _dmr_mismatch(self, rec: _Tracked):
        """Primary and shadow streams disagree: detect, attribute by
        scrubbing both replicas (weight-SEU ⇒ recovery loop), then replay
        the request on a clean replica (transient faults leave both scrubs
        clean; the fresh third execution is the tie-breaker)."""
        self.metrics.detections += 1
        self.supervisor.events.append(
            f"tick {self.tick_no}: uid {rec.req.uid} DMR mismatch "
            f"(replicas {rec.primary_rid}/{rec.shadow_rid})")
        self.event_log.emit(
            "detection", tick=self.tick_no, uid=rec.req.uid,
            replica=rec.primary_rid,
            detail={"check": "dmr_compare", "shadow_rid": rec.shadow_rid})
        for rid in (rec.primary_rid, rec.shadow_rid):
            r = self.replicas[rid]
            if r.state is ReplicaState.HEALTHY and not self.supervisor.scrub(
                    r, self.metrics, self.tick_no):
                self._fail_replica(r, reason="weight scrub failed "
                                   "(DMR attribution)", recover=True)
        self._replay(rec)

    # ------------------------------------------------- speculative backups
    def _dispatch_backups(self, stragglers: List[int]):
        """Re-issue a straggler's in-flight requests to a warm spare; first
        finisher wins at the certify gate, the loser's release is
        suppressed.  Decode determinism makes the copies interchangeable —
        a backup that wins releases the exact bytes the primary would have.
        DMR requests already run doubled, so they are left alone."""
        for rid in stragglers:
            straggler = self.replicas[rid]
            if not straggler.healthy:
                continue
            for req in straggler.in_flight():
                rec = self.records.get(req.uid)
                if (rec is None or rec.terminal or rec.backup is not None
                        or rec.shadow is not None
                        or rec.primary_rid != rid):
                    continue
                spare = self.router.pick(req.uid, self.replicas,
                                         exclude=(rid,))
                if spare is None:
                    continue
                rec.backup = Request(uid=rec.req.uid,
                                     prompt=list(rec.req.prompt),
                                     max_new_tokens=rec.req.max_new_tokens)
                rec.backup_rid = spare.rid
                spare.engine.submit(rec.backup)
                self.metrics.backup_dispatches += 1
                self.supervisor.events.append(
                    f"tick {self.tick_no}: uid {rec.req.uid} speculative "
                    f"backup on replica {spare.rid} (straggler {rid})")
                self.event_log.emit(
                    "backup_dispatch", tick=self.tick_no, uid=rec.req.uid,
                    replica=spare.rid, detail={"straggler": rid})

    # ------------------------------------------------------------ injection
    def strike(self, rid: int, site: str, fault, key) -> None:
        """Campaign/drill injection surface: route an SEU to a replica's
        engine and record it — with fault provenance and the fleet tick —
        in the event log, so reports can reconstruct the
        injection→detection→recovery timeline."""
        self.event_log.emit(
            "strike", tick=self.tick_no, site=site, replica=rid,
            fault=getattr(fault, "name", getattr(fault, "__name__", "")))
        self.replicas[rid].engine.strike(site, fault, key)

    # ------------------------------------------------------------- failover
    def kill_replica(self, rid: int, reason: str = "killed"):
        """Simulated hard loss (test/campaign hook): the replica is DEAD and
        its in-flight work fails over to the healthy survivors."""
        r = self.replicas[rid]
        if r.state is ReplicaState.DEAD:
            return
        self._fail_replica(r, reason=reason, recover=False)

    def pause_replica(self, rid: int):
        """Stop stepping/heartbeating a replica without killing it — the
        supervisor's heartbeat timeout must notice on its own."""
        self.replicas[rid].paused = True

    def _fail_replica(self, replica: Replica, *, reason: str, recover: bool):
        """Common exit from HEALTHY: drain every request the replica owns
        (queued, decoding, finished-but-uncertified), run the recovery loop
        if asked, then replay the drained work on verified replicas."""
        drained = replica.in_flight() + replica.uncertified
        replica.uncertified = []
        self.supervisor.events.append(
            f"tick {self.tick_no}: replica {replica.rid} failed ({reason}); "
            f"{len(drained)} requests drained")
        if recover:
            self.supervisor.recover(replica, self.ckpt_dir, self.metrics,
                                    self.tick_no,
                                    step=getattr(replica, "ckpt_step",
                                                 self._current_step))
        else:
            replica.state = ReplicaState.DEAD
            self.metrics.replicas_lost += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: replica {replica.rid} DEAD ({reason})")
            self.event_log.emit("replica_dead", tick=self.tick_no,
                                replica=replica.rid,
                                detail={"reason": reason})
        for req in drained:
            rec = self.records.get(req.uid)
            if rec is not None and not rec.terminal:
                self._replay(rec)

    def _recover_transport(self, replica):
        """A worker process died mid-RPC (SIGKILL, crash, missed deadline).
        The parent-side request registry survives the worker, so custody is
        intact: drain it, respawn the worker from the current golden
        checkpoint step, re-verify the restored weights, readmit, and
        replay the drained work — the same chain a failed scrub takes, with
        process loss as the detection."""
        drained = replica.in_flight() + replica.uncertified
        replica.uncertified = []
        self.metrics.detections += 1
        self.supervisor.events.append(
            f"tick {self.tick_no}: replica {replica.rid} transport lost; "
            f"{len(drained)} requests drained")
        self.event_log.emit(
            "detection", tick=self.tick_no, replica=replica.rid,
            detail={"check": "transport", "reason": "peer_dead"})
        replica.state = ReplicaState.QUARANTINED
        self.event_log.emit("quarantine", tick=self.tick_no,
                            replica=replica.rid)
        step = getattr(replica, "ckpt_step", self._current_step)
        t0 = time.perf_counter()
        replica.state = ReplicaState.RECOVERING
        try:
            replica.reset_from_ckpt(self.ckpt_dir, step)
            still_bad = replica.scrub()
        except Exception as e:                        # noqa: BLE001
            replica.state = ReplicaState.DEAD
            self.metrics.replicas_lost += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: replica {replica.rid} DEAD "
                f"(worker respawn failed: {e})")
            self.event_log.emit("replica_dead", tick=self.tick_no,
                                replica=replica.rid,
                                detail={"reason": "respawn_failed"})
            still_bad = None                      # exception path: DEAD above
        if still_bad:
            replica.state = ReplicaState.DEAD
            self.metrics.replicas_lost += 1
            self.event_log.emit("replica_dead", tick=self.tick_no,
                                replica=replica.rid,
                                detail={"reason": "reverify_failed"})
        elif still_bad is not None:
            seconds = time.perf_counter() - t0
            replica.state = ReplicaState.HEALTHY
            replica.last_clean_scrub_tick = self.tick_no
            replica.recoveries += 1
            self.metrics.recoveries += 1
            self.metrics.observe_recovery(seconds)   # full restore by respawn
            self.event_log.emit(
                "recovery", tick=self.tick_no, replica=replica.rid,
                seconds=seconds,
                detail={"incremental": False, "action": "worker_respawn"})
            self.supervisor.events.append(
                f"tick {self.tick_no}: replica {replica.rid} worker "
                f"respawned + re-verified ({seconds * 1e3:.1f} ms)")
        for req in drained:
            rec = self.records.get(req.uid)
            if rec is not None and not rec.terminal:
                self._replay(rec)

    def _replay(self, rec: _Tracked):
        """Deterministic failover: requeue the request (and its DMR shadow)
        from the prompt on healthy replicas; decode determinism makes the
        replayed stream bit-identical to what a fault-free replica would
        have produced."""
        rec.replays += 1
        self.metrics.failovers += 1
        self.event_log.emit("failover", tick=self.tick_no, uid=rec.req.uid,
                            detail={"replay": rec.replays})
        self.metrics.lost_tokens += len(rec.req.output or [])
        if rec.shadow is not None:
            self.metrics.lost_tokens += len(rec.shadow.output or [])
        if rec.backup is not None:
            self.metrics.lost_tokens += len(rec.backup.output or [])
        rec.backup = None
        rec.backup_rid = -1
        # evict any copy still resident somewhere (queued on a replica that
        # did not fail, half of a DMR pair, …)
        for r in self.replicas:
            r.engine.cancel(rec.req.uid)
            r.uncertified = [q for q in r.uncertified if q.uid != rec.req.uid]
        if rec.replays > self.MAX_REPLAYS:
            rec.failed = True
            self.metrics.failed += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: uid {rec.req.uid} FAILED "
                f"(replay budget exhausted)")
            return
        rec.req.output = None
        rec.req.finished_at = 0.0
        rec.primary_done = rec.shadow_done = False
        primary = self.router.pick(rec.req.uid, self.replicas)
        if primary is None:
            rec.failed = True
            self.metrics.failed += 1
            self.supervisor.events.append(
                f"tick {self.tick_no}: uid {rec.req.uid} FAILED "
                f"(no healthy replica for failover)")
            return
        rec.primary_rid = primary.rid
        if self.policy == Policy.DMR:
            self._place_shadow(rec)
        primary.engine.submit(rec.req)

    # ------------------------------------------------------------ deadlines
    def _expire_deadlines(self):
        for rec in self.records.values():
            if rec.terminal or rec.deadline_ticks is None:
                continue
            if self.tick_no - rec.submitted_tick > rec.deadline_ticks:
                rec.expired = True
                self.metrics.deadline_misses += 1
                for r in self.replicas:
                    r.engine.cancel(rec.req.uid)
                    r.uncertified = [q for q in r.uncertified
                                     if q.uid != rec.req.uid]
                self.supervisor.events.append(
                    f"tick {self.tick_no}: uid {rec.req.uid} missed its "
                    f"deadline ({rec.deadline_ticks} ticks)")

    # ------------------------------------------------------------- draining
    def _engines_busy(self) -> bool:
        return any(r.state is ReplicaState.HEALTHY and not r.paused
                   and (r.engine.queue or r.engine.active)
                   for r in self.replicas)

    def _work_pending(self) -> bool:
        if self._engines_busy():
            return True
        return any(not rec.terminal for rec in self.records.values())

    def _final_certification(self):
        """End-of-stream settlement: scrub every replica still holding
        uncertified output so the tail of the stream is certified (or
        recalled) even when the tick count never hits the scrub cadence."""
        if self.policy in _SCRUB_GATED:
            for r in self.replicas:
                if r.state is ReplicaState.HEALTHY and r.uncertified:
                    self._scrub_and_settle(r)
        # non-ABFT terminal stragglers: requests stranded on dead replicas
        for rec in list(self.records.values()):
            if not rec.terminal and not self._request_resident(rec):
                self._replay(rec)

    def _request_resident(self, rec: _Tracked) -> bool:
        """Is any live copy of the request still queued/decoding/uncertified
        on a healthy replica?"""
        for r in self.replicas:
            if r.state is not ReplicaState.HEALTHY:
                continue
            for req in r.in_flight() + r.uncertified:
                if req.uid == rec.req.uid:
                    return True
        return False

    # ------------------------------------------------------ rolling deploy
    def deploy(self, params=None, *, ckpt_dir: Optional[str] = None,
               step: Optional[int] = None, mid_swap=None,
               ticks_between: int = 2) -> dict:
        """Zero-drain rolling weight deploy.

        The new weights (``params``, or a checkpoint read from an external
        ``ckpt_dir``/``step``) are first written to the fleet's own golden
        store — deploy truth is always the crc32-verified *storage* copy,
        and the new scrub checksums are computed from that round trip,
        never from live memory.  Then the fleet walks its healthy replicas
        one at a time:

          1. settle output certified under the *old* checksums,
          2. leave the router (``routable=False``; in-flight decodes keep
             running — nothing drains),
          3. patch exactly the changed leaves (manifest-path diff of old vs
             new storage checksums → ``restore_leaves``) into the live
             engine,
          4. re-verify against the **new** storage checksums before the
             replica takes new work again.  A strike landing mid-swap fails
             this re-verify and takes the standard quarantine → incremental
             restore (from the new step) → re-verify → replay path.

        ``mid_swap(rid)`` is a test/campaign hook invoked between patch and
        re-verify — the window the rolling-deploy campaign strikes SEUs
        into.  ``ticks_between`` fleet ticks run between replica swaps so
        the fleet demonstrably serves throughout.  Returns a summary dict.
        """
        import jax
        import numpy as np
        if (params is None) == (ckpt_dir is None):
            raise ValueError("deploy needs exactly one of params= or "
                             "ckpt_dir=")
        new_step = (ckpt_mod.latest_step(self.ckpt_dir) or 0) + 1
        if params is None:
            _, params = ckpt_mod.restore(ckpt_dir, step)
        ckpt_mod.save(self.ckpt_dir, new_step, params)
        _, new_params = ckpt_mod.restore(self.ckpt_dir, new_step)
        new_golden = _checksums_jit(new_params)

        def _by_path(tree):
            flat, _ = jax.tree_util.tree_flatten_with_path(tree)
            return {ckpt_mod.path_str(p): np.asarray(v) for p, v in flat}

        old_sums, new_sums = _by_path(self._golden0), _by_path(new_golden)
        changed = [p for p in ckpt_mod.manifest_paths(self.ckpt_dir,
                                                      new_step)
                   if p not in old_sums
                   or not np.array_equal(old_sums[p], new_sums[p])]
        leaves = ckpt_mod.restore_leaves(self.ckpt_dir, changed,
                                         step=new_step)
        self.metrics.deploys += 1
        self.event_log.emit(
            "deploy_start", tick=self.tick_no,
            detail={"step": new_step, "changed": len(changed)})
        self.supervisor.events.append(
            f"tick {self.tick_no}: deploy of step {new_step} started "
            f"({len(changed)} changed leaves)")

        swapped: List[int] = []
        failed: List[int] = []
        for r in self.replicas:
            if r.state is not ReplicaState.HEALTHY:
                continue
            # settle output that certifies against the old checksums while
            # they are still the truth
            if self.policy in _SCRUB_GATED and r.uncertified:
                self._scrub_and_settle(r)
                if r.state is not ReplicaState.HEALTHY:
                    failed.append(r.rid)
                    continue
            r.routable = False
            # ckpt_step moves first: a worker that dies mid-patch respawns
            # with a *full* restore of the new step (golden recomputed from
            # the restored weights), which completes the swap the hard way
            r.ckpt_step = new_step
            try:
                r.patch_leaves(leaves, golden=new_golden)
                if mid_swap is not None:
                    mid_swap(r.rid)
                clean = self.supervisor.scrub(r, self.metrics, self.tick_no)
            except TransportDead:
                self._recover_transport(r)
                clean = r.state is ReplicaState.HEALTHY
            if not clean and r.state is ReplicaState.HEALTHY:
                # a strike landed during the swap (or the patch tore):
                # caught before the replica rejoined the router
                self._fail_replica(r, reason="deploy re-verify failed",
                                   recover=True)
            if r.state is ReplicaState.HEALTHY:
                r.routable = True
                self.metrics.replicas_swapped += 1
                self.event_log.emit(
                    "replica_swapped", tick=self.tick_no, replica=r.rid,
                    detail={"step": new_step, "reverified": True,
                            "recovered": not clean})
                self.supervisor.events.append(
                    f"tick {self.tick_no}: replica {r.rid} swapped to step "
                    f"{new_step} (re-verified)")
                swapped.append(r.rid)
            else:
                failed.append(r.rid)
            for _ in range(ticks_between):
                self.tick()

        self._params0 = new_params
        self._golden0 = new_golden
        self._current_step = new_step
        return {"step": new_step, "changed": len(changed),
                "swapped": swapped, "failed": failed}

    # --------------------------------------------------------------- reset
    def reset(self, policy: Optional[Policy] = None):
        """Return the fleet to a fresh, fully-healthy state with the golden
        params (campaign trials reuse one fleet so engines stay compiled).
        Dependability counters restart; the golden checkpoint is reused."""
        if policy is not None:
            if policy not in FLEET_POLICIES:
                raise ValueError(f"fleet policy must be one of "
                                 f"{[p.value for p in FLEET_POLICIES]}")
            self.policy = policy
        scrub_mode = _state_scrub_mode(self.policy)
        for r in self.replicas:
            if hasattr(r, "reset_from_ckpt"):
                # proc replica: the worker restores the current golden step
                # itself (cached per step, crc32-verified — byte-identical
                # to ``self._params0``); a dead worker is respawned
                r.reset_from_ckpt(self.ckpt_dir, self._current_step)
                r.engine.state_scrub = scrub_mode
            else:
                r.engine.state_scrub = scrub_mode
                r.reset(params=self._params0)
                r.golden = self._golden0
                r.routable = True
            r.ckpt_step = self._current_step
        self.supervisor.reset()
        self.metrics = FleetMetrics(
            lost_work_bound_tokens=self.metrics.lost_work_bound_tokens)
        self.event_log = EventLog(policy=self.policy.value)
        self.supervisor.event_log = self.event_log
        self.tick_no = 0
        self.records = {}
        self.released = {}

    def close(self):
        """Shut down worker processes (proc transport) and delete the golden
        checkpoint directory if this fleet created it (a caller-supplied
        ckpt_dir is the caller's to manage)."""
        for r in self.replicas:
            if hasattr(r, "handle"):
                try:
                    r.close()
                except Exception:       # noqa: BLE001 — teardown best effort
                    pass
        if self._owns_ckpt_dir:
            shutil.rmtree(self.ckpt_dir, ignore_errors=True)
            self._owns_ckpt_dir = False

    def __del__(self):
        try:
            self.close()
        except Exception:       # noqa: BLE001 — interpreter teardown
            pass

    # -------------------------------------------------------------- report
    def report(self, wall: bool = False) -> dict:
        """Fleet metrics + per-replica state, JSON-ready.  ``wall=True``
        adds the wall-clock-derived rates (non-deterministic; see
        ``FleetMetrics.to_json``)."""
        out = self.metrics.to_json(wall=wall)
        out["policy"] = self.policy.value
        out["transport"] = self.transport
        out["ckpt_step"] = self._current_step
        out["replicas"] = [
            {"rid": r.rid, "state": r.state.value,
             "recoveries": r.recoveries,
             "engine_steps": r.engine.stats.steps,
             "engine_tokens_out": r.engine.stats.tokens_out}
            for r in self.replicas]
        out["events"] = list(self.supervisor.events)
        return out
