"""One supervised serving replica: an Engine plus its dependability lifecycle.

The replica is the fleet's unit of failure.  Its state machine is the
recovery loop the ROADMAP asked for (quarantine → reload → re-verify →
readmit), driven by the supervisor:

    HEALTHY ──scrub fail / heartbeat loss──▶ QUARANTINED
    QUARANTINED ──checkpoint reload──▶ RECOVERING
    RECOVERING ──re-verify ok──▶ HEALTHY   (readmitted, recoveries += 1)
    RECOVERING ──re-verify fail──▶ DEAD
    any ──kill──▶ DEAD

Weight integrity is judged against deploy-time ABFT storage checksums
(``core.abft.storage_checksums``): computed once from the known-good params,
carried by every replica, exact mod 2^32 — the same Huang–Abraham identity
that guards the matmul accumulator, applied to the parameter store.
"""
from __future__ import annotations

import enum
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abft
from repro.models.config import ArchConfig
from repro.runtime.serving import Engine, Request
from repro.train import checkpoint as ckpt_mod

# jitted once per pytree structure, shared by all replicas
_checksums_jit = jax.jit(abft.storage_checksums)
_verify_jit = jax.jit(abft.verify_storage)


class ReplicaState(str, enum.Enum):
    HEALTHY = "healthy"
    QUARANTINED = "quarantined"
    RECOVERING = "recovering"
    DEAD = "dead"


class Replica:
    """An ``Engine`` wrapped with identity, health state, and scrub support."""

    def __init__(self, rid: int, cfg: ArchConfig, params, *,
                 capacity: int = 4, max_len: int = 128, prefill_pad: int = 8,
                 snapshot_every: int = 16, eos_id: int = -1,
                 golden=None, compiled=None, backend: Optional[str] = None,
                 state_scrub: str = "off"):
        self.rid = rid
        self.engine = Engine(cfg, params, capacity=capacity, max_len=max_len,
                             prefill_pad=prefill_pad,
                             snapshot_every=snapshot_every, eos_id=eos_id,
                             compiled=compiled, backend=backend,
                             state_scrub=state_scrub)
        self.state = ReplicaState.HEALTHY
        self.paused = False          # test hook: stop heartbeating (looks dead)
        self.routable = True         # False while a rolling deploy swaps us
        self.golden = golden if golden is not None else _checksums_jit(params)
        self.uncertified: List[Request] = []   # finished, awaiting clean scrub
        self.recoveries = 0
        self.last_clean_scrub_tick = 0
        self.last_scrub_bad: List[str] = []    # verdict of the newest scrub

    def install_certifier(self, gate) -> None:
        """Wire the fleet's release gate into this replica's certify stage:
        every request the engine finishes passes through
        ``gate(replica, req)`` before it may release — certify-before-
        release as a pipeline stage, not a wrapper."""
        self.engine.certify = lambda req: gate(self, req)

    # --------------------------------------------------------------- status
    @property
    def healthy(self) -> bool:
        return self.state is ReplicaState.HEALTHY and not self.paused

    def load(self) -> int:
        """Requests this replica's pipeline currently owns — router's cost."""
        return self.engine.executor.pending_count()

    def in_flight(self) -> List[Request]:
        """Every request in the replica's pipeline, in deterministic
        stage-then-slot order (the order failover drains replay in)."""
        return self.engine.executor.in_flight()

    # ---------------------------------------------------------------- scrub
    def scrub(self) -> List[str]:
        """Verify live weights against deploy-time checksums; returns the
        paths of corrupted leaves ([] == clean).  Paths use the checkpoint
        manifest's encoding (``train/checkpoint.path_str``), so a scrub
        verdict is directly a ``restore_leaves`` read-list — the link that
        makes quarantine-recovery incremental."""
        ok_tree = _verify_jit(self.engine.params, self.golden)
        flat, _ = jax.tree_util.tree_flatten_with_path(ok_tree)
        bad = []
        for path, ok in flat:
            if not bool(ok):
                bad.append(ckpt_mod.path_str(path))
        self.last_scrub_bad = bad
        return bad

    # ------------------------------------------------------------- recovery
    def reload(self, params):
        """Replace params with a known-good copy and clear all run state
        (the reload step of the recovery loop; compiled fns are kept)."""
        params = jax.tree_util.tree_map(jnp.asarray, params)
        self.engine.reset(params=params)
        self.uncertified = []

    def reload_leaves(self, leaves: Dict[str, np.ndarray]):
        """Incremental reload: patch only the named leaves (checkpoint-
        manifest paths → golden bytes) into the live params, then clear run
        state.  The quarantine-recovery fast path — a replica with two
        corrupted tensors re-reads two tensors, not the whole model."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            self.engine.params)
        patched = []
        for path, leaf in flat:
            p = ckpt_mod.path_str(path)
            if p in leaves:
                leaf = jnp.asarray(leaves[p], dtype=leaf.dtype).reshape(
                    leaf.shape)
            patched.append(leaf)
        self.engine.reset(params=jax.tree_util.tree_unflatten(treedef, patched))
        self.uncertified = []

    def patch_leaves(self, leaves: Dict[str, np.ndarray], golden=None):
        """Live weight swap for zero-drain rolling deploys: patch the named
        leaves into the running engine *without* resetting its pipeline —
        params are traced arguments of the compiled step fns, so in-flight
        decodes simply see the new weights on their next step.  ``golden``
        (the new deploy's storage checksums, computed from the checkpoint
        store, never from live weights) replaces the scrub baseline so
        re-verification certifies against what was *deployed*."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            self.engine.params)
        patched = []
        for path, leaf in flat:
            p = ckpt_mod.path_str(path)
            if p in leaves:
                leaf = jnp.asarray(leaves[p], dtype=leaf.dtype).reshape(
                    leaf.shape)
            patched.append(leaf)
        self.engine.params = jax.tree_util.tree_unflatten(treedef, patched)
        if golden is not None:
            self.golden = golden

    def reset(self, params=None):
        """Full revival for a new trial/run: fresh engine state, HEALTHY."""
        if params is not None:
            params = jax.tree_util.tree_map(jnp.asarray, params)
        self.engine.reset(params=params)
        self.uncertified = []
        self.state = ReplicaState.HEALTHY
        self.paused = False
        self.last_clean_scrub_tick = 0
        self.last_scrub_bad = []
