"""Deterministic request dispatch with admission control.

The router is the fleet's front door: every request is either assigned to
exactly one healthy replica or rejected outright (admission control), and
the decision is a pure function of (request uid, replica states, loads) —
no wall clock, no randomness — so a campaign trial replays bit-exactly and
a failover replay lands deterministically.

Two dispatch policies:

  hash          crc32(uid) over the healthy replicas — stable assignment,
                cache-friendly (a retried uid lands on the same replica
                while the fleet composition is unchanged)
  least_loaded  fewest owned requests wins, ties to the lowest rid —
                classic shortest-queue dispatch
"""
from __future__ import annotations

import zlib
from typing import List, Optional, Sequence

from repro.fleet.replica import Replica

POLICIES = ("least_loaded", "hash")


class Router:
    def __init__(self, policy: str = "least_loaded",
                 admit_limit: Optional[int] = None):
        """``admit_limit``: max owned requests per replica before the fleet
        refuses new work (None == unbounded)."""
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"known: {POLICIES}")
        self.policy = policy
        self.admit_limit = admit_limit

    def _room(self, r: Replica) -> bool:
        return self.admit_limit is None or r.load() < self.admit_limit

    def pick(self, uid: int, replicas: Sequence[Replica],
             exclude: Sequence[int] = ()) -> Optional[Replica]:
        """Choose the serving replica for a request, or None to reject.

        ``exclude``: rids to avoid (DMR shadow placement, failover away from
        the replica that just lost the request).

        A replica mid-swap in a rolling deploy advertises ``routable=False``
        — healthy (it keeps decoding what it owns) but closed to new work
        until it re-verifies against the new checksums.
        """
        healthy: List[Replica] = [
            r for r in replicas
            if r.healthy and getattr(r, "routable", True)
            and r.rid not in exclude]
        if not healthy:
            return None
        if self.policy == "hash":
            r = healthy[zlib.crc32(str(uid).encode()) % len(healthy)]
            return r if self._room(r) else None
        # least_loaded with room; ties broken by lowest rid (list order)
        candidates = [r for r in healthy if self._room(r)]
        if not candidates:
            return None
        return min(candidates, key=lambda r: (r.load(), r.rid))
