"""Fleet-level service metrics.

One ``FleetMetrics`` instance accumulates everything a dependable-serving
SLO needs: delivery counters (released / rejected / deadline misses),
dependability counters (scrubs, detections, recoveries, failovers), the
lost-work accounting the paper's bounded-recovery story requires, and
per-request latency in *ticks* (the fleet's deterministic clock) so the
numbers replay bit-exactly under campaign seeds.  ``to_json`` is the export
surface — the fleet CLI and campaign reports both serialize it verbatim.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import List

import numpy as np


@dataclasses.dataclass
class FleetMetrics:
    # configuration-derived bound: max tokens a replica can produce between
    # two clean scrubs (certification window × batch width)
    lost_work_bound_tokens: int = 0

    # service counters
    ticks: int = 0
    engine_steps: int = 0
    submitted: int = 0
    released: int = 0
    rejected: int = 0
    deadline_misses: int = 0
    failed: int = 0
    tokens_out: int = 0              # tokens of *released* (certified) requests

    # dependability counters
    scrubs: int = 0
    detections: int = 0              # scrub mismatches + DMR disagreements + state-scrub hits
    recoveries: int = 0              # quarantine→restore→re-verify→readmit cycles
    failovers: int = 0               # requests replayed on another replica
    replicas_lost: int = 0           # replicas that ended DEAD
    lost_tokens: int = 0             # tokens discarded and re-decoded (actual lost work)

    # recovery accounting (checkpoint/restart as a measured subsystem)
    incremental_restores: int = 0    # quarantine recoveries served by partial restore
    full_reloads: int = 0            # recoveries that needed the whole checkpoint
    leaves_restored: int = 0         # tensors re-read across incremental restores
    state_scrub_detections: int = 0  # decode-state checksum mismatches (transient SEUs)
    state_rollbacks: int = 0         # engine snapshot rollbacks (CKPT transient recovery)
    state_drains: int = 0            # drain+replay transient recoveries (ABFT detect mode)

    # latency, in fleet ticks (submit → release)
    latencies: List[int] = dataclasses.field(default_factory=list)
    # recovery latency, wall seconds (quarantine-restore + snapshot rollbacks)
    recovery_seconds: List[float] = dataclasses.field(default_factory=list)
    started_at: float = dataclasses.field(default_factory=time.time)

    # ------------------------------------------------------------- derived
    def observe_release(self, latency_ticks: int, n_tokens: int):
        self.released += 1
        self.tokens_out += n_tokens
        self.latencies.append(int(latency_ticks))

    def observe_recovery(self, seconds: float, *, leaves: int = 0,
                         incremental: bool = False, rollback: bool = False):
        """One measured recovery action: a quarantine restore (incremental
        or full-reload) or an engine decode-state snapshot rollback."""
        self.recovery_seconds.append(float(seconds))
        if rollback:
            self.state_rollbacks += 1
        elif incremental:
            self.incremental_restores += 1
            self.leaves_restored += leaves
        else:
            self.full_reloads += 1

    def recovery_mean_seconds(self) -> float:
        if not self.recovery_seconds:
            return 0.0
        return float(np.mean(self.recovery_seconds))

    def recovery_max_seconds(self) -> float:
        if not self.recovery_seconds:
            return 0.0
        return float(np.max(self.recovery_seconds))

    def latency_percentile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies), q))

    @property
    def p50_ticks(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99_ticks(self) -> float:
        return self.latency_percentile(99)

    def throughput_tokens_per_tick(self) -> float:
        return self.tokens_out / max(self.ticks, 1)

    # -------------------------------------------------------------- export
    def to_json(self) -> dict:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)
             if f.name not in ("latencies", "recovery_seconds", "started_at")}
        d.update(
            recovery_count=len(self.recovery_seconds),
            recovery_mean_seconds=round(self.recovery_mean_seconds(), 6),
            recovery_max_seconds=round(self.recovery_max_seconds(), 6),
            p50_latency_ticks=self.p50_ticks,
            p99_latency_ticks=self.p99_ticks,
            tokens_per_tick=self.throughput_tokens_per_tick(),
            wall_seconds=round(time.time() - self.started_at, 3),
            tokens_per_second=round(
                self.tokens_out / max(time.time() - self.started_at, 1e-9), 1),
        )
        return d

    def dump(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2))
        return path
