"""Fleet-level service metrics — built on the ``repro.obs`` registry.

One ``FleetMetrics`` instance accumulates everything a dependable-serving
SLO needs: delivery counters (released / rejected / deadline misses),
dependability counters (scrubs, detections, recoveries, failovers), the
lost-work accounting the paper's bounded-recovery story requires, and
per-request latency in *ticks* (the fleet's deterministic clock) so the
numbers replay bit-exactly under campaign seeds.

Counters live in an ``repro.obs.Registry`` (attribute access is preserved:
``metrics.released += 1`` still works, routed to the registry counter), and
the two distributions that used to be unbounded Python lists — release
latency and recovery wall time — are streaming ``Histogram``s: a fleet that
serves ten million requests holds the same few hundred bytes of metric
state as one that serves ten.  The registry doubles as the Prometheus /
JSON-snapshot export surface (``metrics.registry``).

``to_json`` is the export surface the fleet CLI and campaign reports
serialize verbatim.  Wall-clock-derived fields (``wall_seconds``,
``tokens_per_second``) are opt-in via ``to_json(wall=True)``: they change
run to run even under fixed seeds, so the deterministic default keeps
report diffs clean.
"""
from __future__ import annotations

import json
import pathlib
import time

from repro.obs import Histogram, Registry

# every integer counter the fleet maintains, in export order
_COUNTERS = (
    # configuration-derived bound: max tokens a replica can produce between
    # two clean scrubs (certification window × batch width)
    "lost_work_bound_tokens",
    # service counters
    "ticks", "engine_steps", "submitted", "released", "rejected",
    "deadline_misses", "failed",
    "tokens_out",                # tokens of *released* (certified) requests
    # dependability counters
    "scrubs",
    "detections",         # scrub mismatches + DMR disagreements + state hits
    "recoveries",         # quarantine→restore→re-verify→readmit cycles
    "failovers",          # requests replayed on another replica
    "replicas_lost",      # replicas that ended DEAD
    "lost_tokens",        # tokens discarded and re-decoded (actual lost work)
    # recovery accounting (checkpoint/restart as a measured subsystem)
    "incremental_restores",   # quarantine recoveries served by partial restore
    "full_reloads",           # recoveries that needed the whole checkpoint
    "leaves_restored",        # tensors re-read across incremental restores
    "state_scrub_detections",  # decode-state checksum mismatches (transients)
    "state_rollbacks",        # engine snapshot rollbacks (CKPT recovery)
    "state_drains",           # drain+replay transient recoveries (ABFT detect)
    # multi-host: speculative backups + rolling weight deploys
    "backup_dispatches",      # straggler requests re-issued to a warm spare
    "backups_won",            # releases where the backup copy finished first
    "deploys",                # rolling weight deploys started
    "replicas_swapped",       # replicas that swapped + re-verified clean
)

# latency in fleet ticks: power-of-two edges 1..8192
_TICK_BUCKETS = tuple(float(2 ** i) for i in range(14))
# recovery wall seconds: 100 µs .. ~26 s exponential
_SECONDS_BUCKETS = tuple(0.0001 * 4.0 ** i for i in range(10))


class FleetMetrics:
    """Registry-backed fleet metrics with the legacy attribute surface."""

    def __init__(self, lost_work_bound_tokens: int = 0,
                 registry: Registry = None):
        self.registry = registry if registry is not None else Registry()
        self._c = {name: self.registry.counter("fleet_" + name)
                   for name in _COUNTERS}
        # latency, in fleet ticks (submit → release)
        self.latencies: Histogram = self.registry.histogram(
            "fleet_release_latency_ticks",
            "submit-to-release latency in fleet ticks",
            buckets=_TICK_BUCKETS)
        # recovery latency, wall seconds (quarantine restores + rollbacks)
        self.recovery_seconds: Histogram = self.registry.histogram(
            "fleet_recovery_seconds",
            "wall time of measured recovery actions",
            buckets=_SECONDS_BUCKETS)
        self.started_at = time.time()
        self.lost_work_bound_tokens = lost_work_bound_tokens

    # counter attribute routing: ``metrics.released += 1`` reads and writes
    # the registry counter, so the monolith-era call sites stay unchanged
    def __getattr__(self, name):
        c = self.__dict__.get("_c")
        if c is not None and name in c:
            return c[name].value
        raise AttributeError(name)

    def __setattr__(self, name, value):
        c = self.__dict__.get("_c")
        if c is not None and name in c:
            c[name].value = int(value)
        else:
            object.__setattr__(self, name, value)

    # ------------------------------------------------------------- derived
    def observe_release(self, latency_ticks: int, n_tokens: int):
        self.released += 1
        self.tokens_out += n_tokens
        self.latencies.observe(int(latency_ticks))

    def observe_recovery(self, seconds: float, *, leaves: int = 0,
                         incremental: bool = False, rollback: bool = False):
        """One measured recovery action: a quarantine restore (incremental
        or full-reload) or an engine decode-state snapshot rollback."""
        self.recovery_seconds.observe(float(seconds))
        if rollback:
            self.state_rollbacks += 1
        elif incremental:
            self.incremental_restores += 1
            self.leaves_restored += leaves
        else:
            self.full_reloads += 1

    def recovery_mean_seconds(self) -> float:
        return self.recovery_seconds.mean()

    def recovery_max_seconds(self) -> float:
        h = self.recovery_seconds
        return float(h.max) if h.count else 0.0

    def latency_percentile(self, q: float) -> float:
        return self.latencies.percentile(q)

    @property
    def p50_ticks(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99_ticks(self) -> float:
        return self.latency_percentile(99)

    def throughput_tokens_per_tick(self) -> float:
        return self.tokens_out / max(self.ticks, 1)

    # -------------------------------------------------------------- export
    def to_json(self, wall: bool = False) -> dict:
        """JSON-ready metrics.  Deterministic by default; ``wall=True`` adds
        the wall-clock-derived rates (they vary run to run, so reports that
        want diffable output leave them off)."""
        d = {name: self._c[name].value for name in _COUNTERS}
        d.update(
            recovery_count=self.recovery_seconds.count,
            recovery_mean_seconds=round(self.recovery_mean_seconds(), 6),
            recovery_max_seconds=round(self.recovery_max_seconds(), 6),
            p50_latency_ticks=self.p50_ticks,
            p99_latency_ticks=self.p99_ticks,
            tokens_per_tick=self.throughput_tokens_per_tick(),
        )
        if wall:
            elapsed = time.time() - self.started_at
            d.update(
                wall_seconds=round(elapsed, 3),
                tokens_per_second=round(
                    self.tokens_out / max(elapsed, 1e-9), 1),
            )
        return d

    def dump(self, path, wall: bool = False) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(wall=wall), indent=2))
        return path
