"""Worker-process side of the fleet's process-isolation transport.

``worker_entry`` is the spawn target: it owns one real ``Replica`` (engine,
weights, golden checksums) and serves the parent's framed RPCs over a
``PipeChannel``.  The module top stays import-light — the heavy imports
(jax, the model stack) happen inside ``worker_entry`` *after* the spawn, so
the parent can stamp ``JAX_PLATFORMS`` into the child's environment first.

Certify-before-release crosses the boundary as an *upcall*: the worker
installs a certifier on its replica that sends the finished request to the
parent and blocks for the verdict frame.  While blocked it keeps serving
nested RPCs (``_serve_until``), because the parent's gate may re-enter this
worker — e.g. a DMR mismatch scrubs both replicas of the pair, including
the one whose certify stage is mid-upcall.
"""
from __future__ import annotations

import traceback
from typing import Any, Dict, List, Optional

import numpy as np


def _sync_blob(replica) -> dict:
    """Occupancy + stats snapshot attached to every ack, so the parent's
    cached view matches the live engine at each fleet decision point."""
    eng = replica.engine
    return {
        "pending": int(eng.executor.pending_count()),
        "queue": bool(eng.queue),
        "active": bool(eng.active),
        "steps": int(eng.stats.steps),
        "tokens_out": int(eng.stats.tokens_out),
        "replays": int(eng.stats.replays),
        "faults_detected": int(eng.stats.faults_detected),
    }


class _Server:
    def __init__(self, ch, rid: int):
        self.ch = ch
        self.rid = rid
        self.replica = None
        self._params_cache: Dict[int, Any] = {}   # ckpt step -> restored tree
        self._ckpt_dir: Optional[str] = None
        self.running = True

    # ------------------------------------------------------------ plumbing
    def _reply(self, op: str, payload: dict,
               arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
        self.ch.put((op, payload, arrays or {}))

    def _serve_until(self, want_op: str) -> dict:
        """Block for a ``want_op`` frame, dispatching any other ops that
        arrive first.  This re-entrancy is what lets the parent's certify
        gate issue nested RPCs against this same worker mid-upcall."""
        while True:
            op, payload, arrays = self.ch.get(None)
            if op == want_op:
                return payload
            self.dispatch(op, payload, arrays)

    def _restore(self, ckpt_dir: str, step: int):
        """crc32-verified checkpoint restore, cached per step — the fleet
        resets replicas to the same step across every campaign trial, and
        the store round-trip guarantees byte-identity with the parent."""
        from repro.train import checkpoint as ckpt_mod
        if step not in self._params_cache or ckpt_dir != self._ckpt_dir:
            if ckpt_dir != self._ckpt_dir:
                self._params_cache.clear()
                self._ckpt_dir = ckpt_dir
            _, params = ckpt_mod.restore(ckpt_dir, step)
            self._params_cache[step] = params
            # rolling deploys advance the step every time — keep the cache
            # bounded to the store's own retention window
            while len(self._params_cache) > 3:
                del self._params_cache[min(self._params_cache)]
        return self._params_cache[step]

    def _certify_upcall(self, req) -> bool:
        self._reply("certify", {"req": req.to_doc()})
        payload = self._serve_until("verdict")
        return bool(payload.get("release", True))

    # ------------------------------------------------------------ handlers
    def dispatch(self, op: str, payload: dict,
                 arrays: Dict[str, np.ndarray]) -> None:
        try:
            handler = getattr(self, "op_" + op, None)
            if handler is None:
                raise ValueError(f"unknown op {op!r}")
            handler(payload, arrays)
        except Exception:
            self._reply("error", {"op": op,
                                  "traceback": traceback.format_exc()})

    def op_init(self, payload: dict, arrays) -> None:
        from repro.fleet.replica import Replica
        from repro.fleet.transport import cfg_from_doc
        cfg = cfg_from_doc(payload["cfg"])
        params = self._restore(payload["ckpt_dir"], int(payload["step"]))
        self.replica = Replica(
            self.rid, cfg, params,
            capacity=int(payload["capacity"]),
            max_len=int(payload["max_len"]),
            prefill_pad=int(payload["prefill_pad"]),
            snapshot_every=int(payload["snapshot_every"]),
            eos_id=int(payload["eos_id"]),
            backend=payload.get("backend"),
            state_scrub=payload.get("state_scrub", "off"))
        self.replica.install_certifier(
            lambda _replica, req: self._certify_upcall(req))
        self._reply("ready", {"rid": self.rid,
                              "sync": _sync_blob(self.replica)})

    def op_submit(self, payload: dict, arrays) -> None:
        from repro.runtime.dataflow import Request
        self.replica.engine.submit(Request.from_doc(payload["req"]))
        self._reply("submit_ok", {"sync": _sync_blob(self.replica)})

    def op_cancel(self, payload: dict, arrays) -> None:
        found = self.replica.engine.cancel(int(payload["uid"]))
        self._reply("cancel_ok", {"found": bool(found),
                                  "sync": _sync_blob(self.replica)})

    def op_step(self, payload: dict, arrays) -> None:
        released = self.replica.engine.step()
        self._reply("step_done", {
            "released": [int(r.uid) for r in released],
            "state_events": self.replica.engine.drain_state_events(),
            "sync": _sync_blob(self.replica)})

    def op_in_flight(self, payload: dict, arrays) -> None:
        self._reply("in_flight_ok", {
            "reqs": [r.to_doc() for r in self.replica.in_flight()],
            "sync": _sync_blob(self.replica)})

    def op_scrub(self, payload: dict, arrays) -> None:
        bad = self.replica.scrub()
        self._reply("scrub_ok", {"bad": list(bad),
                                 "sync": _sync_blob(self.replica)})

    def op_reload_leaves(self, payload: dict, arrays) -> None:
        import jax.numpy as jnp
        leaves = {name: jnp.asarray(a) for name, a in arrays.items()}
        self.replica.reload_leaves(leaves)
        self._reply("reload_ok", {"sync": _sync_blob(self.replica)})

    def op_patch_leaves(self, payload: dict, arrays) -> None:
        import jax
        import jax.numpy as jnp
        from repro.train import checkpoint as ckpt_mod
        leaves = {name[len("leaf:"):]: jnp.asarray(a)
                  for name, a in arrays.items() if name.startswith("leaf:")}
        gold = {name[len("gold:"):]: jnp.asarray(a)
                for name, a in arrays.items() if name.startswith("gold:")}
        golden = None
        if gold:
            # the wire carries the golden checksums flattened; rebuild the
            # tree against the existing golden's structure (paths match —
            # checksum trees mirror the params tree)
            flat, treedef = jax.tree_util.tree_flatten_with_path(
                self.replica.golden)
            golden = jax.tree_util.tree_unflatten(
                treedef, [gold.get(ckpt_mod.path_str(p), leaf)
                          for p, leaf in flat])
        self.replica.patch_leaves(leaves, golden=golden)
        self._reply("patch_ok", {"sync": _sync_blob(self.replica)})

    def op_reset(self, payload: dict, arrays) -> None:
        from repro.fleet.replica import _checksums_jit
        params = self._restore(payload["ckpt_dir"], int(payload["step"]))
        self.replica.reset(params=params)
        # re-pin the scrub baseline to the restored step (mirrors the
        # parent-side Fleet.reset, which re-pins golden for inproc replicas)
        self.replica.golden = _checksums_jit(params)
        self._reply("reset_ok", {"sync": _sync_blob(self.replica)})

    def op_engine_reset(self, payload: dict, arrays) -> None:
        self.replica.engine.reset()
        self.replica.uncertified.clear()
        self._reply("reset_ok", {"sync": _sync_blob(self.replica)})

    def op_set_state_scrub(self, payload: dict, arrays) -> None:
        self.replica.engine.state_scrub = payload["mode"]
        self._reply("scrub_mode_ok", {"sync": _sync_blob(self.replica)})

    def op_strike(self, payload: dict, arrays) -> None:
        import jax
        from repro.fleet.transport import fault_from_name
        fault = fault_from_name(payload["fault"])
        key = jax.random.wrap_key_data(np.asarray(arrays["key"]))
        self.replica.engine.strike(payload["site"], fault, key)
        self._reply("strike_ok", {"sync": _sync_blob(self.replica)})

    def op_ping(self, payload: dict, arrays) -> None:
        self._reply("pong", {"rid": self.rid})

    def op_shutdown(self, payload: dict, arrays) -> None:
        self._reply("bye", {})
        self.running = False


def worker_entry(conn, rid: int) -> None:
    """Spawn target: build the transport channel, then serve until the
    parent says shutdown or the pipe dies (parent exit → EOF → clean
    process exit; the fleet treats the reverse direction the same way)."""
    from repro.fleet.transport import PipeChannel, TransportDead
    ch = PipeChannel(conn, f"worker{rid}:child")
    server = _Server(ch, rid)
    try:
        while server.running:
            try:
                op, payload, arrays = ch.get(None)
            except TransportDead:
                break
            server.dispatch(op, payload, arrays)
    finally:
        ch.close()
