"""Train / serve step builders — the functions the launcher jits and the
dry-run lowers.

``make_train_step`` returns a pure (state, batch) → (state, metrics) function
plus the sharding pytrees for its inputs/outputs, so launch/dryrun.py can do

    jax.jit(step, in_shardings=…, out_shardings=…).lower(...).compile()

with no further knowledge of the model family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import api as model_api
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.transformer import ShardCtx
from repro.parallel import sharding as shd
from repro.train import optim as optim_mod


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(cfg: ArchConfig, key: jax.Array,
                     optimizer: Optional[optim_mod.Optimizer] = None) -> TrainState:
    optimizer = optimizer or optim_mod.make_optimizer(cfg.optimizer)
    params = model_api.init_params(cfg, key)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Sharding derivation
# ---------------------------------------------------------------------------


def _opt_state_specs(pspecs, params, opt_name: str):
    """Optimizer state specs follow param specs (Adafactor drops one dim).

    The factored/unfactored split must mirror optim.adafactor exactly:
    it factors on ``param.ndim >= 2`` (stacked 1-D scales are 2-D ⇒
    factored), so we decide from the param leaf, padding short specs to
    the tensor rank first.
    """
    if opt_name == "adamw":
        return {"m": pspecs, "v": pspecs}
    if opt_name == "sgdm":
        return {"m": pspecs}

    # adafactor: vr drops the last dim's entry, vc drops the second-to-last
    def fac(spec: P, p):
        parts = tuple(spec)
        parts = parts + (None,) * (p.ndim - len(parts))
        if p.ndim >= 2:
            return {"vr": P(*parts[:-1]), "vc": P(*(parts[:-2] + parts[-1:]))}
        return {"v": P(*parts)}

    sflat = jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    pflat, pdef = jax.tree_util.tree_flatten(params)
    assert len(sflat) == len(pflat)
    return jax.tree_util.tree_unflatten(
        pdef, [fac(s, p) for s, p in zip(sflat, pflat)])


def train_state_specs(cfg: ArchConfig, params, dp, mdl, opt_name: str,
                      mesh=None):
    pspecs = shd.param_specs(cfg, params, dp, mdl, mesh=mesh)
    return TrainState(pspecs, _opt_state_specs(pspecs, params, opt_name), P())


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, ctx: Optional[ShardCtx] = None,
                    optimizer: Optional[optim_mod.Optimizer] = None,
                    grad_clip: float = 1.0):
    optimizer = optimizer or optim_mod.make_optimizer(cfg.optimizer)
    n_micro = max(cfg.grad_accum, 1)

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: model_api.loss_fn(cfg, p, batch, ctx), has_aux=True
        )(params)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            # microbatched gradient accumulation: activation memory scales
            # with B/n_micro while the optimizer still sees the full-batch
            # gradient — the capacity lever for 405B-class models at 4k seq.
            # Grads accumulate in f32 regardless of compute dtype.
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            # the f32 accumulator MUST inherit the parameter sharding — left
            # unconstrained, XLA SPMD replicates it and re-reduces every
            # microbatch (measured 10× collective blow-up on llama3-405b)
            def pin(tree):
                if ctx is None:
                    return tree
                from jax.sharding import NamedSharding
                from repro.parallel import sharding as shd
                specs = shd.param_specs(cfg, state.params, ctx.dp, ctx.model,
                                        mesh=ctx.mesh)
                sh = jax.tree_util.tree_map(
                    lambda s: NamedSharding(ctx.mesh, s), specs,
                    is_leaf=lambda x: isinstance(x, P))
                return jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, tree, sh)

            def acc_body(carry, microbatch):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(state.params, microbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (pin(g_acc), loss_acc + loss), metrics

            g0 = pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (grads, loss), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)

        grads, gnorm = optim_mod.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, state.step)
        params = jax.tree_util.tree_map(jnp.add, state.params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ArchConfig, ctx: Optional[ShardCtx] = None):
    def eval_step(params, batch):
        loss, metrics = model_api.loss_fn(cfg, params, batch, ctx)
        return metrics
    return eval_step


def make_prefill_step(cfg: ArchConfig, max_len: int,
                      ctx: Optional[ShardCtx] = None):
    def prefill_step(params, batch):
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        logits, cache = model_api.prefill(cfg, params, tokens, max_len, ctx,
                                          embeds=embeds)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill_step


def make_decode_step(cfg: ArchConfig, ctx: Optional[ShardCtx] = None):
    def decode_step(params, token, cache):
        logits, cache = model_api.decode_step(cfg, params, token, cache, ctx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return decode_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins for the dry-run; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for one (arch × shape) cell.

    train/prefill: token batch (+labels for train). [audio]/[vlm] archs get
    precomputed frame/patch embeddings instead of tokens (stub frontend).
    decode: one new token + the KV/recurrent cache at seq_len.
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.input_mode == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.input_mode == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
        return batch
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: model_api.init_cache(cfg, B, S))
        return {"token": jax.ShapeDtypeStruct((B,), jnp.int32), "cache": cache}
    raise ValueError(shape.kind)


def abstract_train_state(cfg: ArchConfig,
                         optimizer: Optional[optim_mod.Optimizer] = None) -> TrainState:
    """eval_shape'd TrainState (no device allocation — dry-run input)."""
    optimizer = optimizer or optim_mod.make_optimizer(cfg.optimizer)
    return jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.key(0), optimizer))
