"""Fault-tolerant sharded checkpointing.

Design (what a 1000-node fleet needs, realized with npz on local disk —
the I/O layer is pluggable, the *protocol* is the contribution):

  * **Atomic**: write to ``step_<n>.tmp/``, fsync, then ``rename`` — a crash
    mid-write never corrupts the latest valid checkpoint.
  * **Sharded**: each host writes only its own addressable shards
    (``process_index`` prefix); a manifest records the global pytree
    structure, shapes, dtypes and the mesh the state was saved under.
  * **Elastic restore**: ``restore`` reshards onto *any* target mesh — the
    manifest stores logical PartitionSpecs, not device ids, so a 512-chip
    checkpoint restores onto 256 chips after losing a pod (mesh-shrink
    restart path used by runtime/ft_loop.py).
  * **Integrity**: every array shard carries a crc32; restore verifies and
    refuses silently-corrupted data (the SEU threat model of the paper,
    applied to storage).
  * **Retention**: keep_n newest checkpoints are retained, old ones pruned
    only after the new write is durable.
  * **Incremental + async** (``IncrementalCheckpointer``): dirty-chunk
    tracking against mod-2^32 storage checksums — only chunks whose bits
    changed since the last durable checkpoint are rewritten; unchanged
    chunks are *referenced* from the step that last wrote them, so a
    checkpoint of a mostly-static serving fleet is a few KB of manifest.
    Writes run on a background thread with bounded staleness (the caller
    blocks once ``max_pending`` snapshots are in flight), and each manifest
    is published with the same tmp→fsync→rename barrier, so a crash at any
    byte leaves the previous chain intact.  ``restore`` reassembles a
    chained (format-2) checkpoint bit-identically to a full one;
    ``restore_leaves`` pulls single leaves for the fleet's incremental
    quarantine-recovery (see docs/recovery.md).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MANIFEST = "manifest.json"


def _flat_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts) or "root"


def save(ckpt_dir: str | Path, step: int, state: Any,
         specs: Any = None, keep_n: int = 3) -> Path:
    """Atomically persist ``state`` (a pytree of jax/np arrays)."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:010d}.tmp"
    final = ckpt_dir / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flat_with_paths(state)
    spec_leaves = None
    if specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))

    entries = []
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        name = f"a{i:05d}"
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        entries.append({
            "name": name,
            "path": _path_str(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
            "spec": list(spec_leaves[i]) if spec_leaves is not None else None,
        })

    np.savez(tmp / "shards.npz", **arrays)
    # treedef via pickle: proto serialization rejects registered nodes like
    # TrainState; pickle resolves them by import path at restore time.
    import pickle
    manifest = {
        "step": step,
        "format": 1,
        "treedef": pickle.dumps(jax.tree_util.tree_structure(state)).hex(),
        "entries": entries,
        "n_processes": jax.process_count(),
    }
    (tmp / MANIFEST).write_text(json.dumps(manifest))
    # durability barrier, then atomic publish
    with open(tmp / MANIFEST, "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    _prune(ckpt_dir, keep_n)
    return final


def _step_dir(ckpt_dir: Path, step: int) -> Path:
    return ckpt_dir / f"step_{step:010d}"


def _prune(ckpt_dir: Path, keep_n: int):
    steps = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    kept = steps[-keep_n:] if keep_n > 0 else steps
    # incremental (format-2) manifests reference chunks in earlier step
    # dirs — anything a kept manifest points at must survive the prune
    referenced = set()
    for d in kept:
        mf = d / MANIFEST
        if not mf.exists():
            continue
        manifest = json.loads(mf.read_text())
        if manifest.get("format", 1) >= 2:
            for leaf in manifest["leaves"]:
                for c in leaf["chunks"]:
                    referenced.add(_step_dir(ckpt_dir, c["step"]).name)
    for d in steps:
        if d not in kept and d.name not in referenced:
            shutil.rmtree(d)
    # clear any orphaned tmp dirs from crashed writers
    for d in ckpt_dir.glob("step_*.tmp"):
        shutil.rmtree(d)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
             if d.is_dir() and d.name.startswith("step_")
             and not d.name.endswith(".tmp") and (d / MANIFEST).exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: Optional[int] = None,
            mesh: Optional[Mesh] = None, specs: Any = None,
            verify: bool = True) -> Tuple[int, Any]:
    """Load a checkpoint; optionally place shards on ``mesh`` per ``specs``.

    ``mesh``/``specs`` may describe a *different* topology than the one the
    checkpoint was written under (elastic restart): arrays are loaded as host
    numpy then ``jax.device_put`` resharded.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    manifest = json.loads((d / MANIFEST).read_text())

    import pickle
    treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))
    if manifest.get("format", 1) >= 2:
        leaves = _assemble_incremental(ckpt_dir, manifest, verify=verify)
    else:
        data = np.load(d / "shards.npz")
        leaves = []
        for e in manifest["entries"]:
            arr = data[e["name"]]
            if verify and zlib.crc32(arr.tobytes()) != e["crc32"]:
                raise IOError(
                    f"checkpoint shard {e['path']} failed crc32 — corrupted "
                    f"data (SEU in storage path); refusing to restore")
            leaves.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, leaves)

    if mesh is not None and specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        state_leaves, sdef = jax.tree_util.tree_flatten(state)
        assert len(spec_leaves) == len(state_leaves), \
            f"spec/state leaf mismatch {len(spec_leaves)} vs {len(state_leaves)}"
        placed = [jax.device_put(x, NamedSharding(mesh, s))
                  for x, s in zip(state_leaves, spec_leaves)]
        state = jax.tree_util.tree_unflatten(sdef, placed)
    return step, state


# ---------------------------------------------------------------------------
# Incremental + async checkpointing (format 2)
#
# Layout: every save publishes one step_<n>/ dir holding
#   chunks.npz       only the chunks whose mod-2^32 checksum changed
#   manifest.json    format=2: full tree structure + per-leaf chunk table,
#                    each chunk tagged with the step whose chunks.npz holds
#                    its bytes (== this step for dirty chunks, an earlier
#                    step for clean ones)
# so any manifest alone reconstructs the whole state, and the tmp→fsync→
# rename barrier makes each manifest all-or-nothing.
# ---------------------------------------------------------------------------


def u32_checksum(arr: np.ndarray) -> int:
    """Mod-2^32 sum over the array's raw bits — the storage-scrub identity
    (core/abft.storage_checksums) computed host-side: a flipped bit b
    changes the sum by ±2^b ≠ 0 (mod 2^32), dtype-uniform via the byte
    view (any single-bit SEU still perturbs exactly one byte term)."""
    b = np.frombuffer(np.ascontiguousarray(arr).tobytes(), np.uint8)
    return int(b.sum(dtype=np.uint64) & 0xFFFFFFFF)


def path_str(path) -> str:
    """Public name for the manifest's pytree-path encoding (fleet recovery
    maps scrub verdicts onto manifest entries through this)."""
    return _path_str(path)


def _chunk_slices(n_elems: int, chunk_elems: int) -> List[Tuple[int, int]]:
    if n_elems == 0:
        return [(0, 0)]
    return [(i, min(i + chunk_elems, n_elems))
            for i in range(0, n_elems, chunk_elems)]


def _assemble_leaf(ckpt_dir: Path, leaf: dict, npz_cache: Dict[int, Any],
                   verify: bool = True) -> np.ndarray:
    """Reassemble one leaf from its (possibly cross-step) chunk table."""
    parts = []
    for c in leaf["chunks"]:
        src = c["step"]
        if src not in npz_cache:
            npz_cache[src] = np.load(_step_dir(ckpt_dir, src) / "chunks.npz")
        arr = npz_cache[src][c["key"]]
        if verify and zlib.crc32(arr.tobytes()) != c["crc32"]:
            raise IOError(
                f"incremental chunk {leaf['path']}[{c['key']}] failed crc32 "
                f"(stored in step {src}) — refusing to restore")
        parts.append(arr)
    flat = parts[0] if len(parts) == 1 else np.concatenate(parts)
    return flat.reshape(leaf["shape"]).astype(np.dtype(leaf["dtype"]), copy=False)


def _assemble_incremental(ckpt_dir: Path, manifest: dict,
                          verify: bool = True) -> List[np.ndarray]:
    npz_cache: Dict[int, Any] = {}
    return [_assemble_leaf(ckpt_dir, leaf, npz_cache, verify=verify)
            for leaf in manifest["leaves"]]


def restore_leaves(ckpt_dir: str | Path, paths: Sequence[str],
                   step: Optional[int] = None,
                   verify: bool = True) -> Dict[str, np.ndarray]:
    """Partial restore: load only the named leaves (manifest ``path`` keys,
    e.g. ``"params/w"``) from the newest (or given) checkpoint.

    This is the fleet supervisor's incremental quarantine-recovery read —
    a replica with two corrupted tensors re-reads two tensors, not the
    whole model.  Works on both full (format-1) and incremental (format-2)
    checkpoints; every byte read is crc32-verified.  Unknown paths are
    simply absent from the result (caller decides whether to fall back to
    a full reload).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    manifest = json.loads((d / MANIFEST).read_text())
    want = set(paths)
    out: Dict[str, np.ndarray] = {}
    if manifest.get("format", 1) >= 2:
        npz_cache: Dict[int, Any] = {}
        for leaf in manifest["leaves"]:
            if leaf["path"] in want:
                out[leaf["path"]] = _assemble_leaf(ckpt_dir, leaf, npz_cache,
                                                   verify=verify)
    else:
        data = np.load(d / "shards.npz")
        for e in manifest["entries"]:
            if e["path"] in want:
                arr = data[e["name"]]
                if verify and zlib.crc32(arr.tobytes()) != e["crc32"]:
                    raise IOError(f"checkpoint shard {e['path']} failed "
                                  f"crc32 — refusing partial restore")
                out[e["path"]] = arr
    return out


def manifest_paths(ckpt_dir: str | Path,
                   step: Optional[int] = None) -> List[str]:
    """Every leaf path addressable in the newest (or given) checkpoint, in
    manifest order.  This is the manifest-addressed fetch surface the
    fleet's rolling deploys diff against: the deploy walks these paths,
    compares storage checksums old-vs-new, and feeds exactly the changed
    subset to ``restore_leaves`` — no tree flattening, no weight reads."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    manifest = json.loads((_step_dir(ckpt_dir, step) / MANIFEST).read_text())
    key = "leaves" if manifest.get("format", 1) >= 2 else "entries"
    return [e["path"] for e in manifest[key]]


class IncrementalCheckpointer:
    """Async, incremental, crash-consistent checkpointer.

    ``save(step, state)`` snapshots the state to host memory immediately
    (so the caller may keep mutating device state) and returns; a background
    thread diffs per-chunk mod-2^32 checksums against the last durable
    checkpoint and writes only dirty chunks.  Staleness is bounded: at most
    ``max_pending`` snapshots may be in flight before ``save`` blocks, so
    the durable state on disk never trails the train/serve loop by more
    than ``max_pending`` save intervals.

    ``full_every=k`` forces every k-th save to rewrite all chunks (a
    rebase), bounding chain length and letting retention reclaim old dirs.
    Writer-thread errors are re-raised on the next ``save``/``wait``/
    ``close`` — a checkpointer that cannot persist must not fail silently.
    """

    def __init__(self, ckpt_dir: str | Path, *, keep_n: int = 3,
                 chunk_bytes: int = 1 << 20, async_write: bool = True,
                 max_pending: int = 2, full_every: int = 0):
        self.ckpt_dir = Path(ckpt_dir)
        self.ckpt_dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.chunk_bytes = int(chunk_bytes)
        self.full_every = int(full_every)
        self.async_write = async_write
        # path -> list of (checksum, crc32, key, step) per chunk, for the
        # last durable checkpoint — the dirty-diff baseline
        self._baseline: Dict[str, List[dict]] = {}
        self.stats = {"saves": 0, "chunks_total": 0, "chunks_written": 0,
                      "bytes_written": 0}
        self._err: Optional[BaseException] = None
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(max_pending)))
        self._thread: Optional[threading.Thread] = None
        if async_write:
            self._thread = threading.Thread(
                target=self._writer_loop, name="ckpt-writer", daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- frontend
    def save(self, step: int, state: Any) -> None:
        """Snapshot ``state`` to host and schedule (or perform) the write."""
        self._raise_pending()
        leaves, _ = _flat_with_paths(state)
        # np.array(copy=True): a numpy leaf would otherwise alias the
        # caller's buffer and the async writer would persist whatever the
        # caller mutated it to *after* this call, not the snapshot
        snap = [(path, np.array(jax.device_get(leaf)))
                for path, leaf in leaves]
        treedef = jax.tree_util.tree_structure(state)
        if self._thread is not None:
            self._q.put((step, snap, treedef))       # blocks at max_pending
        else:
            self._write(step, snap, treedef)

    def wait(self) -> None:
        """Block until every scheduled write is durable; re-raise errors."""
        if self._thread is not None:
            self._q.join()
        self._raise_pending()

    def close(self) -> None:
        self.wait()
        if self._thread is not None:
            self._q.put(None)
            self._thread.join()
            self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    # -------------------------------------------------------------- backend
    def _writer_loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                self._write(*item)
            except BaseException as e:               # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, snap, treedef):
        # rebase cadence counts *durable* saves, so a torn write retried
        # later lands the rebase on the same durable save it would have
        rebase = self.full_every > 0 and (
            (self.stats["saves"] + 1) % self.full_every == 0)
        tmp = self.ckpt_dir / f"step_{step:010d}.tmp"
        final = _step_dir(self.ckpt_dir, step)
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()

        leaves_meta, arrays = [], {}
        new_baseline: Dict[str, List[dict]] = {}
        n_chunks = n_written = bytes_written = 0
        for i, (path, arr) in enumerate(snap):
            pstr = _path_str(path)
            flat = np.ascontiguousarray(arr).reshape(-1)
            chunk_elems = max(1, self.chunk_bytes // max(arr.dtype.itemsize, 1))
            old = self._baseline.get(pstr)
            chunks = []
            for ci, (lo, hi) in enumerate(_chunk_slices(flat.size, chunk_elems)):
                piece = flat[lo:hi]
                csum = u32_checksum(piece)
                key = f"a{i:05d}_c{ci:04d}"
                prev = old[ci] if old is not None and ci < len(old) else None
                n_chunks += 1
                if (not rebase and prev is not None
                        and prev["checksum"] == csum
                        and prev["shape"] == [int(hi - lo)]):
                    # clean chunk: reference the step that last wrote it
                    chunks.append({**prev, "key": prev["key"]})
                else:
                    crc = zlib.crc32(piece.tobytes())
                    arrays[key] = piece
                    chunks.append({"key": key, "step": step, "crc32": crc,
                                   "checksum": csum, "shape": [int(hi - lo)]})
                    n_written += 1
                    bytes_written += int(piece.nbytes)
            leaves_meta.append({
                "path": pstr, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "chunk_elems": int(chunk_elems),
                "chunks": chunks,
            })
            new_baseline[pstr] = chunks

        np.savez(tmp / "chunks.npz", **arrays)
        import pickle
        manifest = {
            "step": step, "format": 2,
            "rebase": bool(rebase),
            "treedef": pickle.dumps(treedef).hex(),
            "leaves": leaves_meta,
            "n_processes": jax.process_count(),
        }
        (tmp / MANIFEST).write_text(json.dumps(manifest))
        with open(tmp / MANIFEST, "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        # only now — after the rename barrier — do the baseline and the
        # accounting reflect this save; a crash before this point leaves the
        # previous chain, stats, and rebase cadence fully intact
        self._baseline = new_baseline
        self.stats["saves"] += 1
        self.stats["chunks_total"] += n_chunks
        self.stats["chunks_written"] += n_written
        self.stats["bytes_written"] += bytes_written
        _prune(self.ckpt_dir, self.keep_n)

    # ------------------------------------------------------------- utility
    def dirty_fraction(self) -> float:
        """Fraction of chunks actually rewritten over the checkpointer's
        lifetime — the incremental win (1.0 == every save was a full write)."""
        return self.stats["chunks_written"] / max(self.stats["chunks_total"], 1)
