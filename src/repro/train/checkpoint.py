"""Fault-tolerant sharded checkpointing.

Design (what a 1000-node fleet needs, realized with npz on local disk —
the I/O layer is pluggable, the *protocol* is the contribution):

  * **Atomic**: write to ``step_<n>.tmp/``, fsync, then ``rename`` — a crash
    mid-write never corrupts the latest valid checkpoint.
  * **Sharded**: each host writes only its own addressable shards
    (``process_index`` prefix); a manifest records the global pytree
    structure, shapes, dtypes and the mesh the state was saved under.
  * **Elastic restore**: ``restore`` reshards onto *any* target mesh — the
    manifest stores logical PartitionSpecs, not device ids, so a 512-chip
    checkpoint restores onto 256 chips after losing a pod (mesh-shrink
    restart path used by runtime/ft_loop.py).
  * **Integrity**: every array shard carries a crc32; restore verifies and
    refuses silently-corrupted data (the SEU threat model of the paper,
    applied to storage).
  * **Retention**: keep_n newest checkpoints are retained, old ones pruned
    only after the new write is durable.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MANIFEST = "manifest.json"


def _flat_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts) or "root"


def save(ckpt_dir: str | Path, step: int, state: Any,
         specs: Any = None, keep_n: int = 3) -> Path:
    """Atomically persist ``state`` (a pytree of jax/np arrays)."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:010d}.tmp"
    final = ckpt_dir / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flat_with_paths(state)
    spec_leaves = None
    if specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))

    entries = []
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        name = f"a{i:05d}"
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        entries.append({
            "name": name,
            "path": _path_str(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
            "spec": list(spec_leaves[i]) if spec_leaves is not None else None,
        })

    np.savez(tmp / "shards.npz", **arrays)
    # treedef via pickle: proto serialization rejects registered nodes like
    # TrainState; pickle resolves them by import path at restore time.
    import pickle
    manifest = {
        "step": step,
        "format": 1,
        "treedef": pickle.dumps(jax.tree_util.tree_structure(state)).hex(),
        "entries": entries,
        "n_processes": jax.process_count(),
    }
    (tmp / MANIFEST).write_text(json.dumps(manifest))
    # durability barrier, then atomic publish
    with open(tmp / MANIFEST, "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    _prune(ckpt_dir, keep_n)
    return final


def _prune(ckpt_dir: Path, keep_n: int):
    steps = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    for d in steps[:-keep_n] if keep_n > 0 else []:
        shutil.rmtree(d)
    # clear any orphaned tmp dirs from crashed writers
    for d in ckpt_dir.glob("step_*.tmp"):
        shutil.rmtree(d)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
             if d.is_dir() and d.name.startswith("step_")
             and not d.name.endswith(".tmp") and (d / MANIFEST).exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: Optional[int] = None,
            mesh: Optional[Mesh] = None, specs: Any = None,
            verify: bool = True) -> Tuple[int, Any]:
    """Load a checkpoint; optionally place shards on ``mesh`` per ``specs``.

    ``mesh``/``specs`` may describe a *different* topology than the one the
    checkpoint was written under (elastic restart): arrays are loaded as host
    numpy then ``jax.device_put`` resharded.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((d / MANIFEST).read_text())
    data = np.load(d / "shards.npz")

    leaves = []
    for e in manifest["entries"]:
        arr = data[e["name"]]
        if verify and zlib.crc32(arr.tobytes()) != e["crc32"]:
            raise IOError(
                f"checkpoint shard {e['path']} failed crc32 — corrupted data "
                f"(SEU in storage path); refusing to restore")
        leaves.append(arr)

    import pickle
    treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))
    state = jax.tree_util.tree_unflatten(treedef, leaves)

    if mesh is not None and specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        state_leaves, sdef = jax.tree_util.tree_flatten(state)
        assert len(spec_leaves) == len(state_leaves), \
            f"spec/state leaf mismatch {len(spec_leaves)} vs {len(state_leaves)}"
        placed = [jax.device_put(x, NamedSharding(mesh, s))
                  for x, s in zip(state_leaves, spec_leaves)]
        state = jax.tree_util.tree_unflatten(sdef, placed)
    return step, state
