"""Optimizers: AdamW and Adafactor (factored second moment, for ≥100B params).

Self-contained (no optax dependency).  Both are (init_fn, update_fn) pairs
operating on pytrees; state shardings derive from the param shardings
(train/steps.py), which is what lets kimi-k2-1t fit: Adafactor's factored
state is O(m+n) per (m, n) matrix instead of Adam's O(2·m·n).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    name: str


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m, v

        flat, treedef = jax.tree_util.tree_flatten(params)
        gflat = treedef.flatten_up_to(grads)
        mflat = treedef.flatten_up_to(state["m"])
        vflat = treedef.flatten_up_to(state["v"])
        ups, ms, vs = [], [], []
        for g, m, v, p in zip(gflat, mflat, vflat, flat):
            u, m2, v2 = upd(g, m, v, p)
            ups.append(u)
            ms.append(m2)
            vs.append(v2)
        unf = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
        return unf(ups), {"m": unf(ms), "v": unf(vs)}

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — factored second moment
# ---------------------------------------------------------------------------


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return jax.tree_util.tree_map(st, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rms = jnp.sqrt(
                    vr[..., :, None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps))
                u = g / jnp.maximum(rms, eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS of update ≤ clip_threshold)
            urms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, urms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), new_s

        flat, treedef = jax.tree_util.tree_flatten(params)
        gflat = treedef.flatten_up_to(grads)
        sflat = treedef.flatten_up_to(state)
        ups, ns = [], []
        for g, s, p in zip(gflat, sflat, flat):
            u, s2 = upd(g, s, p)
            ups.append(u)
            ns.append(s2)
        return (jax.tree_util.tree_unflatten(treedef, ups),
                jax.tree_util.tree_unflatten(treedef, ns))

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, lr: float = 3e-4) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    if name == "sgdm":
        return sgdm(lr=lr)
    raise ValueError(name)


def sgdm(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, step):
        def upd(g, m):
            m = momentum * m + g.astype(jnp.float32)
            return m
        m = jax.tree_util.tree_map(upd, grads, state["m"])
        updates = jax.tree_util.tree_map(
            lambda mm, p: (-lr * mm).astype(p.dtype), m, params)
        return updates, {"m": m}

    return Optimizer(init, update, "sgdm")
