"""jax version compatibility shims.

``shard_map`` drifted twice across the jax versions this repo must span:
the import moved (``jax.experimental.shard_map`` → ``jax.shard_map``) and
the replication-check kwarg was renamed (``check_rep`` → ``check_vma``).
Everything in-repo imports it from here and always uses the ``check_vma``
spelling; the shim maps onto whatever the installed jax accepts.
"""
from __future__ import annotations

import inspect

try:                                      # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                       # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
    kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def axis_size(axis_name) -> int:
    """``lax.axis_size`` appeared after jax 0.4.x; the classic static-size
    idiom is ``psum(1, axis)``, which constant-folds at trace time."""
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
