"""Deterministic, sharded, prefetching data pipeline.

The paper's system streams sensor frames into the co-processor; a training
fleet streams token batches into the mesh.  Properties a 1000-node run needs,
all implemented here:

  * **Determinism under restart**: batch ``i`` is a pure function of
    (seed, i) — ``batch_at(step)`` regenerates any step's batch exactly, so a
    restore-from-checkpoint continues on *bit-identical* data with no
    dataloader state to persist.
  * **Host sharding**: each process materializes only its slice of the
    global batch (``process_index``-strided rows), matching how
    multi-host pjit expects per-host addressable shards.
  * **Prefetch**: a double-buffered iterator overlaps host batch synthesis
    with device compute — the Klepsydra "streaming, lock-free" idea at the
    host boundary, built on the same ``Channel``/``Stage`` primitives as the
    serving pipeline (``runtime/dataflow.py``), just under the threaded
    driver instead of the deterministic cooperative one.
  * Sources: synthetic LM stream (zipf-ish token marginals so losses are
    non-degenerate), or a memory-mapped corpus of token ids.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, ShapeConfig
from repro.runtime.dataflow import Channel, Closed, SourceStage, ThreadedSource


class TokenStream:
    """Deterministic synthetic LM token stream."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, seed: int = 0,
                 n_hosts: Optional[int] = None, host_id: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.n_hosts = n_hosts if n_hosts is not None else jax.process_count()
        self.host_id = host_id if host_id is not None else jax.process_index()
        if shape.global_batch % self.n_hosts:
            raise ValueError(
                f"global_batch {shape.global_batch} not divisible by "
                f"{self.n_hosts} hosts")
        self.host_batch = shape.global_batch // self.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) — the restart-determinism core."""
        B, S, V = self.host_batch, self.shape.seq_len, self.cfg.vocab_size
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, step, self.host_id]))
        # zipf-flavored marginals (clipped) => realistic non-uniform targets
        z = rng.zipf(1.3, size=(B, S + 1))
        tokens = np.minimum(z - 1, V - 1).astype(np.int32)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.cfg.input_mode == "embeddings":
            batch["embeds"] = rng.standard_normal(
                (B, S, self.cfg.d_model), dtype=np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MmapCorpus:
    """Token-id corpus on disk (np.memmap), deterministic strided reads."""

    def __init__(self, path: str, cfg: ArchConfig, shape: ShapeConfig,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.n_hosts, self.host_id = n_hosts, host_id
        self.host_batch = shape.global_batch // n_hosts
        self.n_windows = (len(self.data) - 1) // shape.seq_len
        if self.n_windows < 1:
            raise ValueError("corpus shorter than one sequence")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.host_batch, self.shape.seq_len
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 1, step, self.host_id]))
        idx = rng.integers(0, self.n_windows, size=B)
        rows = np.stack([self.data[i * S:i * S + S + 1] for i in idx])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


def prefetch(source, start_step: int = 0, depth: int = 2):
    """Double-buffered prefetch: synthesize batch i+1 while i is on device.

    One ``SourceStage`` (producing ``(step, source.batch_at(step))``) runs
    under the threaded driver, blocking on a bounded ``Channel`` of depth
    ``depth`` — the host-boundary instance of the staged-streaming pipeline
    the serving executor is built from.  The consumer side is an iterator;
    ``close()`` closes the channel, which unblocks and joins the producer.
    """
    ch = Channel(depth, name="prefetch")
    stage = SourceStage(lambda step: (step, source.batch_at(step)),
                        ch, start=start_step)
    driver = ThreadedSource(stage).start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            try:
                return ch.get()
            except Closed:
                raise StopIteration from None

        def close(self):
            driver.close()

    return _Iter()


def shard_batch(batch: Dict[str, np.ndarray], mesh, dp_axes) -> Dict[str, jax.Array]:
    """Place a host batch onto the mesh, batch dim over the dp axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(dp_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
