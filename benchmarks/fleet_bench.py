"""Fleet scaling + dependability-policy overhead benchmark.

Measures released-token throughput of the serving fleet as replicas and
policies vary — the serving-side companion of benchmarks/campaign_bench.py
(which prices the op-level policies).  The interesting ratios:

  * none → abft: the cost of certify-before-release (periodic pytree
    checksums + release latency, no extra decode), and
  * none → dmr: the cost of pair-serving (2× decode of every request).

``--transport proc`` benches the process-isolation transport instead; each
proc row also replays the same request stream through an in-process fleet
and asserts the released token streams are byte-identical
(``bit_identical_to_inproc`` in the row) — throughput with a built-in
correctness gate.

    PYTHONPATH=src python -m benchmarks.fleet_bench --fast
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.dependability import Policy
from repro.fleet import Fleet
from repro.runtime.serving import Request


def _released_streams(fleet, n_requests):
    return tuple(tuple(fleet.released[uid].output)
                 if uid in fleet.released else None
                 for uid in range(n_requests))


def bench(arch: str, n_replicas: int, policy: Policy, n_requests: int,
          max_new: int, seed: int = 0, transport: str = "inproc"):
    from repro.configs import registry
    from repro.models import api as model_api
    from repro.models.config import reduced

    cfg = reduced(registry.get(arch))
    params = model_api.init_params(cfg, jax.random.key(seed))
    fleet = Fleet(cfg, params, n_replicas=n_replicas, policy=policy,
                  capacity=4, max_len=96, prefill_pad=8, scrub_every=4,
                  transport=transport)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=4).tolist()
               for _ in range(n_requests)]

    def run_once(fl):
        fl.reset(policy=policy)
        for i, p in enumerate(prompts):
            fl.submit(Request(uid=i, prompt=list(p), max_new_tokens=max_new))
        fl.run()
        return fl.metrics

    run_once(fleet)                              # warmup / compile
    t0 = time.perf_counter()
    m = run_once(fleet)
    dt = time.perf_counter() - t0
    row = {
        "arch": cfg.name, "replicas": n_replicas, "policy": policy.value,
        "transport": transport,
        "released": m.released, "tokens": m.tokens_out, "ticks": m.ticks,
        "tok_per_s": m.tokens_out / dt,
        "p50_ticks": m.p50_ticks, "p99_ticks": m.p99_ticks,
        "metrics": m.to_json(),
    }
    if transport != "inproc":
        # correctness gate: the same stream through an in-process fleet
        # must release byte-identical tokens (docs/multihost.md)
        proc_out = _released_streams(fleet, n_requests)
        ref = Fleet(cfg, params, n_replicas=n_replicas, policy=policy,
                    capacity=4, max_len=96, prefill_pad=8, scrub_every=4)
        run_once(ref)
        ref_out = _released_streams(ref, n_requests)
        ref.close()
        row["bit_identical_to_inproc"] = proc_out == ref_out
        if not row["bit_identical_to_inproc"]:
            raise AssertionError(
                f"{transport} released stream diverged from inproc: "
                f"{proc_out} != {ref_out}")
    fleet.close()
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.fleet_bench")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--replicas", default="1,2,4")
    ap.add_argument("--policies", default="none,abft,dmr")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--fast", action="store_true",
                    help="2 replicas only, 6 requests")
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "proc"],
                    help="proc: one worker process per replica; every row "
                         "is also checked bit-identical against inproc")
    ap.add_argument("--metrics-out", default=None,
                    help="write every row's full FleetMetrics snapshot "
                         "(registry counters + latency histograms) as JSON")
    args = ap.parse_args(argv)

    replica_counts = [2] if args.fast else [
        int(x) for x in args.replicas.split(",")]
    n_requests = 6 if args.fast else args.requests
    policies = [Policy(p) for p in args.policies.split(",")]

    rows = []
    for n in replica_counts:
        for pol in policies:
            if pol == Policy.DMR and n < 2:
                continue                          # pair-serving needs 2
            r = bench(args.arch, n, pol, n_requests, args.max_new_tokens,
                      transport=args.transport)
            rows.append(r)
            ident = ("  bit-identical=yes"
                     if r.get("bit_identical_to_inproc") else "")
            print(f"{r['arch']}  replicas={r['replicas']}  "
                  f"policy={r['policy']:>4}  {r['tok_per_s']:8.1f} tok/s  "
                  f"p50={r['p50_ticks']:.0f}t p99={r['p99_ticks']:.0f}t  "
                  f"({r['released']} released){ident}", flush=True)

    base = {r["replicas"]: r["tok_per_s"] for r in rows
            if r["policy"] == "none"}
    for r in rows:
        if r["policy"] != "none" and r["replicas"] in base:
            print(f"  overhead {r['policy']} @ {r['replicas']} replicas: "
                  f"{base[r['replicas']] / max(r['tok_per_s'], 1e-9):.2f}×")
    if args.metrics_out:
        import json
        import pathlib
        mpath = pathlib.Path(args.metrics_out)
        mpath.parent.mkdir(parents=True, exist_ok=True)
        mpath.write_text(json.dumps({"rows": rows}, indent=2,
                                    sort_keys=True) + "\n")
        print(f"wrote {mpath}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
