"""Paper Table 1 reproduction: quantized conv+requant single-layer latency.

The paper benchmarks 4 Ship-Detection conv layers on the HPDP (rad-hard
dataflow co-processor, 250 MHz) vs the GR740 (rad-hard LEON4, 250 MHz) and
reports HPDP 112×–660× faster.  We reproduce the comparison three ways:

  1. **Paper's own numbers** (measured, Table 1) — the claims we validate.
  2. **Analytic device models** from first principles — a dataflow model of
     the HPDP (40 ALU-PAEs, one MAC/PAE/cycle, stream-limited) and a scalar
     model of the GR740 (LEON4 in-order, ~1 MAC / 8 cycles effective) — to
     confirm the *magnitudes* of the paper's measurements are consistent
     with the architectures (validation per §EXPERIMENTS).
  3. **Our TPU backend** — the same layers through the qconv2d Pallas kernel
     design: modeled v5e latency (int8 roofline: max(MACs·2/394T, bytes/819G))
     plus measured-for-correctness execution (interpret mode vs the oracle,
     which proves the kernel computes the right thing; wall time on the CPU
     interpreter is NOT a latency claim).

``--bit-sweep`` runs the campaign engine's per-bit accumulator sweep at
(reduced) Table-1 layer geometry: every int32 accumulator bit position is
flipped ``--bit-trials`` times under none and abft, classifying which bits
the requantization rescale masks and which the ABFT checksum catches.  The
report lands under ``reports/table1_bitsweep/``.

Usage: PYTHONPATH=src python -m benchmarks.table1_conv [--check] [--bit-sweep]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from repro.models.shipdet import TABLE1_LAYERS, ConvSpec

# Paper Table 1 (ms)
PAPER_HPDP_MS = {"conv_24x3x3x24": 121.27, "conv_48x3x3x48": 110.94,
                 "conv_96x3x3x96": 104.84, "conv_96x1x1x96": 47.44}
PAPER_GR740_MS = {"conv_24x3x3x24": 23894.08, "conv_48x3x3x48": 23731.64,
                  "conv_96x3x3x96": 11765.59, "conv_96x1x1x96": 31320.04}

# --- analytic device models ---------------------------------------------
HPDP_CLOCK = 250e6
HPDP_MACS_PER_CYCLE = 40 * 0.35     # 40 ALU-PAEs, ~35% stream efficiency
                                    # (fitted once on layer 1, applied to all)
GR740_CLOCK = 250e6
GR740_CYCLES_PER_MAC = 14           # in-order SPARC V8: ld/ld/mul/add/st + loop
                                    # overhead on int8→int32 MAC (fitted layer 1)

TPU_INT8_FLOPS = 394e12
TPU_HBM_BW = 819e9


def hpdp_model_ms(s: ConvSpec) -> float:
    return s.macs / (HPDP_CLOCK * HPDP_MACS_PER_CYCLE) * 1e3


def gr740_model_ms(s: ConvSpec) -> float:
    return s.macs * GR740_CYCLES_PER_MAC / GR740_CLOCK * 1e3


def tpu_model_ms(s: ConvSpec) -> float:
    flops = 2 * s.macs
    bytes_ = (s.h * s.w * s.cin            # int8 activations in
              + s.kh * s.kw * s.cin * s.cout
              + s.h * s.w * s.cout // (s.stride ** 2)
              + 4 * s.cout * 3)            # bias/scale/colsum
    return max(flops / TPU_INT8_FLOPS, bytes_ / TPU_HBM_BW) * 1e3


def correctness_check() -> bool:
    """Kernel-under-interpreter vs oracle on (reduced) Table-1 geometry."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.kernels.qconv2d import ops, ref

    rng = np.random.default_rng(0)
    ok = True
    for s in TABLE1_LAYERS:
        r = dataclasses.replace(s, h=max(s.h // 8, 8), w=max(s.w // 8, 8))
        x_q = jnp.asarray(rng.integers(-128, 128, (1, r.h, r.w, r.cin)), jnp.int8)
        w_q = jnp.asarray(rng.integers(-127, 128, (r.kh, r.kw, r.cin, r.cout)), jnp.int8)
        colsum = jnp.sum(w_q.astype(jnp.int32), axis=(0, 1, 2))
        bias = jnp.asarray(rng.integers(-500, 500, (r.cout,)), jnp.int32)
        scale = jnp.asarray(rng.uniform(1e-4, 1e-2, (r.cout,)).astype(np.float32))
        x_zp = jnp.int32(3)
        out_zp = jnp.int32(-2)
        got = ops.qconv2d_op(x_q, x_zp, w_q, colsum, bias, scale, out_zp,
                             use_kernel=True, interpret=True)
        want = ref.qconv2d_ref(x_q, x_zp, w_q, bias, scale, out_zp)
        same = np.array_equal(np.asarray(got), np.asarray(want))
        print(f"  {s.name:<18} reduced {r.h}x{r.w}: kernel==oracle: {same}")
        ok &= same
    return ok


# (layer, reduced geometry) pairs for the --bit-sweep mode: the first and
# last Table-1 layers, spatially shrunk so the vmapped sweep compiles fast
# while keeping the layer's channel/kernel shape (what the checksum sees)
BIT_SWEEP_GEOMETRIES = [
    ("qconv2d_t1_conv1", dict(h=24, w=24, cin=24, cout=24, kh=3, kw=3)),
    ("qconv2d_t1_conv4", dict(h=12, w=12, cin=96, cout=96, kh=1, kw=1)),
]


def bit_sweep(trials_per_bit: int, seed: int, out_dir: str) -> int:
    """Per-bit accumulator fault sweep at Table-1 conv geometry."""
    import jax
    from repro.campaign import stats as stats_mod
    from repro.campaign.report import write_report
    from repro.campaign.runner import QConv2dCase, run_bit_sweep
    from repro.core.dependability import Policy

    plan = stats_mod.SamplingPlan(ci_halfwidth=0.05, min_trials=4, chunk=4)
    rows = []
    for label, geom in BIT_SWEEP_GEOMETRIES:
        case = QConv2dCase(jax.random.key(seed), **geom)
        rows += run_bit_sweep(label, [Policy.NONE, Policy.ABFT],
                              trials_per_bit=trials_per_bit, seed=seed,
                              case=case, plan=plan)
        print(f"{label}: swept 32 bits × ≤{trials_per_bit} trials "
              f"× 2 policies", flush=True)
    meta = {
        "bench": "table1_bitsweep",
        "seed": seed,
        "trials_per_bit": trials_per_bit,
        "geometries": {label: geom for label, geom in BIT_SWEEP_GEOMETRIES},
        "plan": {"ci_halfwidth": plan.ci_halfwidth,
                 "min_trials": plan.min_trials, "chunk": plan.chunk},
    }
    jpath, mpath = write_report([], out_dir, meta, basename="table1_bitsweep",
                                bit_coverage=rows)
    sdc_bits = {}
    for r in rows:
        if r.sdc > 0:
            sdc_bits.setdefault((r.workload, r.policy), []).append(r.bit)
    for (wl, pol), bits in sorted(sdc_bits.items()):
        print(f"  {wl}/{pol}: SDC at bits {bits}")
    abft_sdc = sum(r.sdc for r in rows if r.policy == "abft")
    print(f"abft residual SDC across all bits: {abft_sdc}")
    print(f"wrote {jpath} and {mpath}")
    return 1 if abft_sdc else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="also run kernel-vs-oracle correctness on each layer")
    ap.add_argument("--bit-sweep", action="store_true",
                    help="per-bit accumulator SEU sweep at Table-1 geometry "
                         "(writes reports/table1_bitsweep/)")
    ap.add_argument("--bit-trials", type=int, default=8,
                    help="fault injections per bit position per policy")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="reports/table1_bitsweep",
                    help="output directory for the --bit-sweep report")
    args = ap.parse_args()

    if args.bit_sweep:
        raise SystemExit(bit_sweep(args.bit_trials, args.seed, args.out))

    hdr = (f"{'layer':<18} {'MACs':>9} | {'HPDP ms':>9} {'model':>8} "
           f"{'GR740 ms':>10} {'model':>9} | {'speedup':>7} {'model':>6} "
           f"| {'TPU-v5e ms':>10} {'vs HPDP':>8}")
    print(hdr)
    print("-" * len(hdr))
    rows = []
    for s in TABLE1_LAYERS:
        hp, gp = PAPER_HPDP_MS[s.name], PAPER_GR740_MS[s.name]
        hm, gm = hpdp_model_ms(s), gr740_model_ms(s)
        tm = tpu_model_ms(s)
        rows.append((s.name, s.macs, hp, hm, gp, gm, gp / hp, gm / hm, tm, hp / tm))
        print(f"{s.name:<18} {s.macs/1e6:8.1f}M | {hp:9.2f} {hm:8.2f} "
              f"{gp:10.2f} {gm:9.2f} | {gp/hp:6.0f}× {gm/hm:5.0f}× "
              f"| {tm:10.4f} {hp/tm:7.0f}×")

    # paper-claim validation (the EXPERIMENTS.md §Paper-validation numbers)
    speedups = [r[6] for r in rows]
    print(f"\npaper claim: HPDP beats GR740 on every layer "
          f"({min(speedups):.0f}×–{max(speedups):.0f}×): "
          f"{'CONFIRMED' if min(speedups) > 1 else 'FAILED'}")
    mods = [abs(np.log10(r[3] / r[2])) for r in rows] + \
           [abs(np.log10(r[5] / r[4])) for r in rows]
    print(f"analytic models within {10**max(mods):.1f}× of all paper "
          f"measurements (order-of-magnitude consistency)")

    if args.check:
        print("\ncorrectness (kernel interpret vs jnp oracle, reduced geometry):")
        ok = correctness_check()
        print(f"  all layers exact: {ok}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
