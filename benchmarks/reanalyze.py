"""Re-derive hlo_analysis for every artifact from its saved .hlo.gz.

Lets the analyzer evolve (e.g. new HBM-traffic model) without recompiling
66 dry-run cells:

    PYTHONPATH=src python -m benchmarks.reanalyze
"""
from __future__ import annotations

import gzip
import json
from pathlib import Path

from repro.launch import hlo_analysis

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def main():
    n = 0
    for hp in sorted(ARTIFACTS.glob("*.hlo.gz")):
        jp = hp.with_name(hp.name.replace(".hlo.gz", ".json"))
        if not jp.exists():
            continue
        rec = json.loads(jp.read_text())
        with gzip.open(hp, "rt") as f:
            hlo = f.read()
        rec["hlo_analysis"] = hlo_analysis.analyze(hlo)
        jp.write_text(json.dumps(rec, indent=1))
        n += 1
        print(f"reanalyzed {jp.name}")
    print(f"{n} artifacts updated")


if __name__ == "__main__":
    main()
