"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch × shape × mesh) cell, derive the three roofline terms from the
compiled artifact recorded by launch/dryrun.py:

    compute    = HLO_FLOPs_per_dev / peak_FLOPs      (197e12 bf16/chip)
    memory     = HLO_bytes_per_dev / HBM_bw          (819e9 B/s/chip)
    collective = collective_bytes_per_dev / link_bw  (50e9 B/s/link)

All three in seconds; the max is the bottleneck.  MODEL_FLOPS = 6·N·D for
training (2·N·D forward-only for prefill/decode), N = active params —
the ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/redundancy waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md out.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class target)
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def model_flops(rec: Dict) -> float:
    """Useful FLOPs for the whole cell (all chips)."""
    n = rec["active_param_count"]
    kind = rec["kind"]
    # tokens processed by one step
    import re
    m = re.match(r".*", rec["shape"])
    shape_tokens = {
        "train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
        "decode_32k": 128, "long_500k": 1,
    }[rec["shape"]]
    per_tok = 6 * n if kind == "train" else 2 * n
    return per_tok * shape_tokens


def analyze_record(rec: Dict) -> Dict:
    ha = rec["hlo_analysis"]
    chips = rec["n_devices"]
    flops_dev = ha["flops"]
    # hbm_bytes: traffic at materialization boundaries (dot/conv/fusion/
    # collective), i.e. assuming TPU-grade elementwise fusion.  The raw
    # bytes_accessed of the barely-fused CPU HLO overestimates wildly.
    bytes_dev = ha.get("hbm_bytes", ha["bytes_accessed"])
    coll_dev = ha["total_collective_bytes"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())

    mf = model_flops(rec)
    useful_ratio = mf / max(flops_dev * chips, 1.0)
    # roofline fraction: useful model FLOPs per chip over what the chip could
    # do in the bound time (how close the *useful* work runs to peak)
    frac = (mf / chips / PEAK_FLOPS) / t_bound if t_bound > 0 else 0.0

    return {
        "cell": rec["cell"], "arch": rec["arch"], "shape": rec["shape"],
        "mesh": rec["mesh"], "kind": rec["kind"], "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "t_bound_s": t_bound,
        "model_flops": mf, "hlo_flops_dev": flops_dev,
        "useful_ratio": useful_ratio, "roofline_fraction": frac,
        "coll_counts": ha["collective_counts"],
    }


def what_would_help(r: Dict) -> str:
    b = r["bottleneck"]
    if b == "compute":
        if r["useful_ratio"] < 0.25:
            return ("compute-bound but mostly non-useful FLOPs — relax remat "
                    "policy / remove redundant recompute")
        return "compute-bound near useful peak — int8 (2× MXU) or more chips"
    if b == "memory":
        return ("memory-bound — fuse epilogues, cast params/activations to "
                "bf16, larger per-op tiles (fewer HBM round-trips)")
    return ("collective-bound — reshard to cut all-gather volume, overlap "
            "collectives with compute, bf16/int8 gradient compression")


def load_all(mesh: Optional[str] = None) -> List[Dict]:
    out = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if "hlo_analysis" not in rec:
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        out.append(analyze_record(rec))
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.2f}us"


def to_markdown(rows: List[Dict]) -> str:
    lines = [
        "| cell | chips | compute | memory | collective | bound | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']}×{r['shape']}@{r['mesh']} | {r['chips']} "
            f"| {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
            f"| {fmt_s(r['t_collective_s'])} | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--md", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()

    rows = load_all(args.mesh)
    if not rows:
        print("no artifacts found — run: python -m repro.launch.dryrun --all")
        return

    if args.csv:
        print("cell,chips,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
              "useful_ratio,roofline_fraction")
        for r in rows:
            print(f"{r['cell']},{r['chips']},{r['t_compute_s']:.6g},"
                  f"{r['t_memory_s']:.6g},{r['t_collective_s']:.6g},"
                  f"{r['bottleneck']},{r['useful_ratio']:.4f},"
                  f"{r['roofline_fraction']:.4f}")
    else:
        for r in rows:
            print(f"{r['cell']:<55} {r['bottleneck']:<10} "
                  f"c={fmt_s(r['t_compute_s'])} m={fmt_s(r['t_memory_s'])} "
                  f"x={fmt_s(r['t_collective_s'])} useful={r['useful_ratio']:.3f} "
                  f"frac={r['roofline_fraction']:.3f}")
            print(f"{'':<55} ↳ {what_would_help(r)}")

    if args.md:
        Path(args.md).write_text(to_markdown(rows))
        print(f"\nwrote {args.md}")


if __name__ == "__main__":
    main()
