"""Continuous batching vs pad-and-step: the streaming-executor benchmark.

Serves one mixed-length request trace two ways and compares:

  * **streamed** — the staged dataflow engine (runtime/dataflow.py):
    requests join and leave the slotted decode batch mid-flight, so a slot
    freed by a short request is refilled while its neighbors keep decoding.
  * **padded** — the monolith-equivalent pad-and-step baseline: the same
    engine with ``drain_barrier=True``, so a group of ``capacity`` requests
    is admitted, decoded until *every* member has its full token budget,
    and only then is the next group admitted.  Short requests idle their
    slot for the group's max — exactly the barrier the staged pipeline
    removes.

Both paths run the identical jitted per-step decode, prefill machinery, and
host loop over the same fixed batch width — the only difference is the
admission policy — so the tokens/s ratio prices continuous batching itself
(batch occupancy), which is the paper's streaming-throughput claim at
serving granularity.  ``--check-bit-identity`` additionally verifies the
streamed outputs against the plain greedy reference — continuous batching
must never change tokens.

    PYTHONPATH=src python -m benchmarks.serving_bench --requests 24 \
        --out BENCH_serving.json

Writes tokens/s, mean batch occupancy, and p50/p99 release latency for both
paths plus the speedup ratio to ``--out`` (default: BENCH_serving.json at
the repo root).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request


def make_trace(cfg, n_requests: int, seed: int):
    """Mixed-length trace: short prompts, heavy-tailed token budgets (the
    serving-realistic shape that punishes a drain barrier most — every
    static group inherits its longest member's budget while the short
    majority idles)."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        prompt = rng.integers(1, cfg.vocab_size, size=int(
            rng.integers(3, 8))).tolist()
        max_new = int(rng.choice([4, 6, 8, 64]))
        trace.append((prompt, max_new))
    return trace


def greedy_reference(cfg, params, prompt, n_new, max_len):
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model_api.prefill(cfg, params, toks, max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def _latency_stats(latencies):
    if not latencies:
        return {"p50_latency_s": 0.0, "p99_latency_s": 0.0}
    arr = np.asarray(latencies)
    return {"p50_latency_s": round(float(np.percentile(arr, 50)), 4),
            "p99_latency_s": round(float(np.percentile(arr, 99)), 4)}


def run_engine(cfg, params, trace, capacity, max_len, prefill_pad,
               drain_barrier=False, compiled=None, multi_step=1,
               tracer=None, metrics=None, policy_map=None):
    """Serve the trace through the staged engine (continuous batching, or
    the pad-and-step baseline under ``drain_barrier``); returns
    (report, reqs, compiled-pair).  ``policy_map`` engages the per-site
    dependability policies (in-graph FFN hardening + the engine's derived
    scrub schedules) — mapped engines compile their own decode graphs, so
    never share ``compiled`` across different maps."""
    eng = Engine(cfg, params, capacity=capacity, max_len=max_len,
                 prefill_pad=prefill_pad, drain_barrier=drain_barrier,
                 compiled=compiled, multi_step=multi_step,
                 tracer=tracer, metrics=metrics, policy_map=policy_map)

    def serve():
        eng.reset()
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
                for i, (p, n) in enumerate(trace)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return reqs

    serve()                                         # warmup / compile
    dt = float("inf")
    for _ in range(3):                              # best-of-3: shed noise
        t0 = time.perf_counter()
        reqs = serve()
        dt = min(dt, time.perf_counter() - t0)
    tokens = sum(len(r.output) for r in reqs)
    report = {
        "tokens": tokens,
        "decode_steps": eng.stats.steps,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(tokens / dt, 1),
        "occupancy": round(eng.stats.tokens_per_step() / capacity, 4),
        **_latency_stats([r.finished_at - r.submitted_at for r in reqs]),
    }
    return report, reqs, eng.compiled


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.serving_bench")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-pad", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--multi-step", type=int, default=4,
                    help="decode-dispatch window: steps decoded on device "
                         "per host readback (1 = per-step dispatch)")
    ap.add_argument("--quant-kv", action="store_true",
                    help="serve with the int8-quantized KV cache "
                         "(ArchConfig.quant_kv)")
    ap.add_argument("--check-bit-identity", action="store_true",
                    help="also verify streamed outputs == greedy reference "
                         "(slow: one reference decode per request)")
    ap.add_argument("--policy-map", default=None, metavar="JSON",
                    help="selective-hardening comparison: serve the trace "
                         "on the W8A8 FFN path under this per-site policy "
                         "map (path or inline JSON, e.g. "
                         "reports/dse/best_map.json), against the "
                         "uniform-ABFT and unprotected corners — reports "
                         "the mapped-vs-uniform speedup and asserts all "
                         "three decode streams are bit-identical "
                         "(docs/dse.md)")
    ap.add_argument("--trace-out", default=None,
                    help="re-serve the streamed trace with span tracing on "
                         "and write the Chrome trace_event JSON; also "
                         "reports trace_overhead_frac vs the untraced run")
    ap.add_argument("--metrics-out", default=None,
                    help="write the traced run's metrics registry snapshot "
                         "(.prom extension → Prometheus text format)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    cfg = reduced(registry.get(args.arch))
    if args.quant_kv:
        import dataclasses
        cfg = dataclasses.replace(cfg, quant_kv=True)
    params = model_api.init_params(cfg, jax.random.key(args.seed))
    trace = make_trace(cfg, args.requests, args.seed)

    streamed, reqs, compiled = run_engine(
        cfg, params, trace, args.capacity, args.max_len, args.prefill_pad,
        multi_step=args.multi_step)
    # same compiled (decode, prefill) pair: the baseline pays no extra
    # compiles, so the ratio isolates the admission policy
    padded, _, _ = run_engine(
        cfg, params, trace, args.capacity, args.max_len, args.prefill_pad,
        drain_barrier=True, compiled=compiled)

    multi_step_bit_identical = None
    per_step = None
    if args.multi_step > 1:
        # the multi-step window must be a pure dispatch optimization: the
        # per-step schedule (N=1) serves the same trace and every token
        # stream must match bit-for-bit
        per_step, reqs_1, _ = run_engine(
            cfg, params, trace, args.capacity, args.max_len,
            args.prefill_pad, compiled=compiled, multi_step=1)
        multi_step_bit_identical = all(
            a.output == b.output for a, b in zip(reqs, reqs_1))
        assert multi_step_bit_identical, \
            "multi-step decode changed tokens vs per-step dispatch"

    bit_identical = None
    if args.check_bit_identity:
        bit_identical = all(
            r.output == greedy_reference(cfg, params, p, n, args.max_len)
            for r, (p, n) in zip(reqs, trace))

    traced = None
    trace_overhead_frac = None
    if args.trace_out or args.metrics_out:
        # observability cost: same trace, same compiled functions, tracing
        # and metrics on — tokens/s delta vs the untraced streamed run is
        # the overhead the < 3 % budget (docs/observability.md) bounds
        from repro.obs import Registry, SpanTracer
        tracer = SpanTracer(name="serving_bench") if args.trace_out else None
        reg = Registry() if args.metrics_out else None
        traced, traced_reqs, _ = run_engine(
            cfg, params, trace, args.capacity, args.max_len,
            args.prefill_pad, compiled=compiled, multi_step=args.multi_step,
            tracer=tracer, metrics=reg)
        assert all(a.output == b.output
                   for a, b in zip(reqs, traced_reqs)), \
            "tracing changed tokens — observer effect"
        trace_overhead_frac = round(
            1.0 - traced["tokens_per_s"] / streamed["tokens_per_s"], 4)
        if tracer is not None:
            tracer.dump(args.trace_out)
        if reg is not None:
            reg.dump(args.metrics_out)

    policy_map_section = None
    policy_map_speedup = None
    if args.policy_map:
        import dataclasses
        from repro.core.dependability import Policy
        from repro.core.policy_map import PolicyMap, as_policy_map
        pm = as_policy_map(args.policy_map)
        # all three corners serve the same quantized path (the mapped ffn.*
        # sites only exist there), so the ratio prices the policies alone
        qcfg = dataclasses.replace(cfg, quant="w8a8_ffn")
        qparams = model_api.init_params(qcfg, jax.random.key(args.seed))
        runs = {}
        reqs_by = {}
        for label, this_map in (
                ("none", None),
                ("mapped", pm),
                ("uniform_abft", PolicyMap.uniform(Policy.ABFT))):
            runs[label], reqs_by[label], _ = run_engine(
                qcfg, qparams, trace, args.capacity, args.max_len,
                args.prefill_pad, multi_step=args.multi_step,
                policy_map=this_map)
        # the dependability contract: policies never change clean tokens —
        # mapped and uniform streams must equal the unprotected stream
        map_bit_identical = all(
            all(a.output == b.output
                for a, b in zip(reqs_by["none"], reqs_by[label]))
            for label in ("mapped", "uniform_abft"))
        assert map_bit_identical, \
            "policy map changed clean decode tokens vs uniform/unprotected"
        policy_map_speedup = round(
            runs["mapped"]["tokens_per_s"]
            / max(runs["uniform_abft"]["tokens_per_s"], 1e-9), 3)
        none_tps = max(runs["none"]["tokens_per_s"], 1e-9)
        policy_map_section = {
            "map": pm.to_doc(),
            "quant": "w8a8_ffn",
            "runs": runs,
            "overhead_vs_none": {
                label: round(none_tps / max(r["tokens_per_s"], 1e-9), 3)
                for label, r in runs.items()},
            "bit_identical": map_bit_identical,
        }

    speedup = streamed["tokens_per_s"] / max(padded["tokens_per_s"], 1e-9)
    result = {
        "arch": cfg.name,
        "capacity": args.capacity,
        "requests": args.requests,
        "seed": args.seed,
        "multi_step": args.multi_step,
        "quant_kv": bool(args.quant_kv),
        "trace_max_new": [n for _, n in trace],
        "streamed": streamed,
        "per_step": per_step,
        "padded": padded,
        "speedup_tokens_per_s": round(speedup, 3),
        "multi_step_speedup": (round(streamed["tokens_per_s"]
                                     / max(per_step["tokens_per_s"], 1e-9), 3)
                               if per_step else None),
        "multi_step_bit_identical": multi_step_bit_identical,
        "decode_bit_identical": bit_identical,
        "traced": traced,
        "trace_overhead_frac": trace_overhead_frac,
        "policy_map": policy_map_section,
        "policy_map_speedup": policy_map_speedup,
    }
    out = Path(args.out)
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"streamed: {streamed['tokens_per_s']:8.1f} tok/s  "
          f"occupancy {streamed['occupancy']:.2f}  "
          f"p99 {streamed['p99_latency_s']:.2f}s  "
          f"(multi_step={args.multi_step})")
    if per_step is not None:
        print(f"per-step: {per_step['tokens_per_s']:8.1f} tok/s  "
              f"(bit-identical to multi-step: {multi_step_bit_identical})")
    print(f"padded:   {padded['tokens_per_s']:8.1f} tok/s  "
          f"occupancy {padded['occupancy']:.2f}  "
          f"p99 {padded['p99_latency_s']:.2f}s")
    print(f"continuous batching speedup: {speedup:.2f}×"
          + (f"  (bit-identical to reference: {bit_identical})"
             if bit_identical is not None else ""))
    if traced is not None:
        print(f"traced:   {traced['tokens_per_s']:8.1f} tok/s  "
              f"(overhead {trace_overhead_frac * 100:.1f}%)")
    if policy_map_section is not None:
        r = policy_map_section["runs"]
        print(f"policy map (w8a8): none {r['none']['tokens_per_s']:.1f} | "
              f"mapped {r['mapped']['tokens_per_s']:.1f} | "
              f"uniform-abft {r['uniform_abft']['tokens_per_s']:.1f} tok/s"
              f"  -> mapped vs uniform {policy_map_speedup:.2f}x "
              f"(bit-identical: {policy_map_section['bit_identical']})")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
