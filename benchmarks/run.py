"""Benchmark entrypoint: one function per paper table/figure + framework
benches.  Prints ``name,value,derived`` CSV lines and human-readable tables.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Sections:
  table1   — paper Table 1 / Figure 5 reproduction (+ kernel correctness)
  roofline — three-term roofline per dry-run artifact (§Roofline)
  kernels  — CPU wall-clock of the jnp oracles + interpret-mode kernels
             (correctness-bearing; CPU wall time is not a TPU latency claim)
  serving  — continuous-batching engine throughput on a reduced config
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def bench_table1(check: bool = True):
    print("\n=== Table 1 / Figure 5: conv+requant latency (paper repro) ===")
    import benchmarks.table1_conv as t1
    rows = []
    for s in t1.TABLE1_LAYERS:
        hp, gp = t1.PAPER_HPDP_MS[s.name], t1.PAPER_GR740_MS[s.name]
        tm = t1.tpu_model_ms(s)
        rows.append((s.name, hp, gp, gp / hp, tm))
        print(f"table1,{s.name},paper_hpdp_ms={hp},paper_gr740_ms={gp},"
              f"speedup={gp/hp:.0f}x,tpu_model_ms={tm:.4f}")
    if check:
        ok = t1.correctness_check()
        print(f"table1,correctness,{ok}")
        assert ok
    return rows


def bench_roofline():
    print("\n=== Roofline (from dry-run artifacts) ===")
    from benchmarks import roofline as rl
    rows = rl.load_all()
    if not rows:
        print("roofline,SKIPPED,no artifacts (run repro.launch.dryrun --all)")
        return []
    for r in rows:
        print(f"roofline,{r['cell']},bottleneck={r['bottleneck']},"
              f"t_bound_s={r['t_bound_s']:.4g},useful={r['useful_ratio']:.3f},"
              f"frac={r['roofline_fraction']:.4f}")
    return rows


def _time(f, *args, reps=3):
    f(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    try:
        out.block_until_ready()
    except AttributeError:
        pass
    return (time.perf_counter() - t0) / reps * 1e6   # us


def bench_kernels(fast: bool = False):
    print("\n=== Kernel microbenches (CPU oracle wall time; correctness-bearing) ===")
    import jax
    import jax.numpy as jnp
    from repro.kernels.qmatmul.kernel import qmatmul
    from repro.kernels.qmatmul.ref import qmatmul_ref
    from repro.kernels.flashattn.kernel import flash_attention
    from repro.kernels.flashattn.ref import attention_ref

    rng = np.random.default_rng(0)
    m = 64 if fast else 256
    x = jnp.asarray(rng.integers(-128, 128, (m, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (256, 128)), jnp.int8)
    colsum = jnp.sum(w.astype(jnp.int32), axis=0)
    bias = jnp.zeros((128,), jnp.int32)
    scale = jnp.full((128,), 1e-3, jnp.float32)
    zps = jnp.asarray([0, 0], jnp.int32)

    t_ref = _time(jax.jit(lambda: qmatmul_ref(x, jnp.int32(0), w, bias,
                                              scale, jnp.int32(0))))
    print(f"kernels,qmatmul_ref_{m}x256x128,us_per_call={t_ref:.0f}")
    t_int = _time(lambda: qmatmul(x, w, colsum, bias, scale, zps,
                                  interpret=True))
    print(f"kernels,qmatmul_interpret_{m}x256x128,us_per_call={t_int:.0f},"
          f"derived=interpreter_overhead_{t_int/max(t_ref,1):.0f}x")

    S = 128 if fast else 256
    q = jnp.asarray(rng.standard_normal((1, 4, S, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, S, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, S, 32)), jnp.float32)
    t_ref = _time(jax.jit(lambda: attention_ref(q, k, v)))
    print(f"kernels,flashattn_ref_S{S},us_per_call={t_ref:.0f}")
    t_int = _time(lambda: flash_attention(q, k, v, interpret=True,
                                          block_q=64, block_k=64))
    print(f"kernels,flashattn_interpret_S{S},us_per_call={t_int:.0f}")


def bench_serving(fast: bool = False):
    print("\n=== Serving engine throughput (reduced config, CPU) ===")
    import jax
    from repro.configs import registry
    from repro.models import api as model_api
    from repro.models.config import reduced
    from repro.runtime.serving import Engine, Request

    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    n_req = 4 if fast else 8
    eng = Engine(cfg, params, capacity=4, max_len=128, prefill_pad=16)
    rng = np.random.default_rng(0)
    for i in range(n_req):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(1, 200, size=5).tolist(),
                           max_new_tokens=8))
    t0 = time.perf_counter()
    stats = eng.run()
    dt = time.perf_counter() - t0
    print(f"serving,reduced_smollm,tokens={stats.tokens_out},"
          f"tok_per_s={stats.tokens_out/dt:.1f},"
          f"tokens_per_step={stats.tokens_per_step():.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    bench_table1(check=True)
    bench_roofline()
    bench_kernels(fast=args.fast)
    bench_serving(fast=args.fast)
    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
