"""Dependability-policy overhead bench: NONE vs ABFT vs TMR throughput.

Measures the steady-state cost of each policy on the quantized matmul and
conv primitives (the Safe-NEureka-style hybrid-redundancy comparison: how
much throughput does each protection level buy its coverage with), plus the
campaign engine's own trial rate, across the execution backends
(``--backends jnp,pallas`` benchmarks the FPGA/VPU-style same-workload
cross-backend comparison; the pallas numbers are interpreter wall-clock off
TPU, so only the jnp rows are throughput claims there).

    PYTHONPATH=src python -m benchmarks.campaign_bench [--fast]

Prints ``campaign_bench,<name>,<key>=<val>,...`` CSV-ish lines like the
other benches.  CPU wall-clock: relative overhead is the signal, absolute
latency is not a TPU claim.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dependability import Policy, dependable_qconv2d, dependable_qmatmul


def _time(f, *args, reps: int = 20):
    out = f(*args)                      # compile
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def bench_policy_overhead(m=256, k=512, n=256, reps=20, backends=("jnp",)):
    print(f"\n=== policy overhead: qmatmul ({m}x{k}x{n} int8) ===")
    rng = np.random.default_rng(0)
    x_q = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (n,), dtype=np.int32))
    scale = jnp.full((n,), 1e-3, jnp.float32)
    zp = jnp.int32(0)

    rows = []
    for backend in backends:
        base = None
        for policy in (Policy.NONE, Policy.ABFT, Policy.TMR):
            f = jax.jit(lambda xq, wq, p=policy, be=backend: dependable_qmatmul(
                p, xq, zp, wq, bias, scale, zp, backend=be)[0])
            t = _time(f, x_q, w_q, reps=reps)
            base = base or t
            gmacs = m * k * n / t / 1e9
            rows.append((backend, policy.value, t, t / base, gmacs))
            print(f"campaign_bench,qmatmul_policy={policy.value},"
                  f"backend={backend},ms={t * 1e3:.3f},"
                  f"overhead_x={t / base:.2f},gmacs={gmacs:.2f}")
    return rows


def bench_conv_policy_overhead(h=32, w=32, cin=32, cout=32, reps=10,
                               backends=("jnp",)):
    print(f"\n=== policy overhead: qconv2d ({h}x{w}x{cin}->{cout} 3x3) ===")
    rng = np.random.default_rng(1)
    x_q = jnp.asarray(rng.integers(-128, 128, (1, h, w, cin), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (3, 3, cin, cout), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-100, 100, (cout,), dtype=np.int32))
    scale = jnp.full((cout,), 1e-3, jnp.float32)
    zp = jnp.int32(0)

    rows = []
    for backend in backends:
        base = None
        for policy in (Policy.NONE, Policy.ABFT, Policy.TMR):
            f = jax.jit(lambda xq, wq, p=policy, be=backend: dependable_qconv2d(
                p, xq, zp, wq, bias, scale, zp, backend=be)[0])
            t = _time(f, x_q, w_q, reps=reps)
            base = base or t
            rows.append((backend, policy.value, t, t / base))
            print(f"campaign_bench,qconv2d_policy={policy.value},"
                  f"backend={backend},ms={t * 1e3:.3f},"
                  f"overhead_x={t / base:.2f}")
    return rows


def bench_trial_rate(trials=200):
    print(f"\n=== campaign engine trial rate ({trials} trials/config) ===")
    from repro.campaign import CampaignSpec, run_campaign
    specs = [CampaignSpec("qmatmul", p, "accumulator", "single_bitflip",
                          trials, seed=0)
             for p in (Policy.NONE, Policy.ABFT, Policy.TMR)]
    t0 = time.perf_counter()
    results = run_campaign(specs)
    dt = time.perf_counter() - t0
    total = sum(r.trials for r in results)
    print(f"campaign_bench,trial_rate,trials={total},seconds={dt:.2f},"
          f"trials_per_s={total / dt:.1f}")
    return total / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backends", default="jnp",
                    help="comma list of execution backends to compare "
                         "(jnp, ref, pallas)")
    args = ap.parse_args(argv)
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    reps = 5 if args.fast else 20
    bench_policy_overhead(reps=reps, backends=backends)
    bench_conv_policy_overhead(reps=max(reps // 2, 3), backends=backends)
    bench_trial_rate(trials=50 if args.fast else 200)


if __name__ == "__main__":
    main()
