"""Dependability-policy overhead + adaptive-campaign bench.

Measures the steady-state cost of each policy on the quantized matmul and
conv primitives (the Safe-NEureka-style hybrid-redundancy comparison: how
much throughput does each protection level buy its coverage with), the
campaign engine's trial rate per workload, and the headline speedup of the
adaptive engine: a sequential-sampling campaign reaching the same verdicts
as a fixed-budget one at equal CI precision, in a fraction of the trials.
``--backends jnp,pallas`` benchmarks the FPGA/VPU-style same-workload
cross-backend comparison; the pallas numbers are interpreter wall-clock off
TPU, so only the jnp rows are throughput claims there.

    PYTHONPATH=src python -m benchmarks.campaign_bench [--fast] \
        [--out BENCH_campaign.json]

Prints ``campaign_bench,<name>,<key>=<val>,...`` CSV-ish lines like the
other benches and writes the committed summary JSON to ``--out``.  CPU
wall-clock: relative overhead / trial-count ratios are the signal,
absolute latency is not a TPU claim.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dependability import Policy, dependable_qconv2d, dependable_qmatmul


def _time(f, *args, reps: int = 20):
    out = f(*args)                      # compile
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


# every policy, so selective-hardening consumers (the DSE cost oracle) and
# the printed table read one number set — not the markdown-era NONE/ABFT/TMR
# subset
BENCH_POLICIES = (Policy.NONE, Policy.ABFT, Policy.DMR, Policy.TMR,
                  Policy.CKPT)


def bench_policy_overhead(m=256, k=512, n=256, reps=20, backends=("jnp",)):
    """Per-policy qmatmul cost; returns machine-readable rows (one dict per
    backend × policy) that main() embeds verbatim in the summary JSON."""
    print(f"\n=== policy overhead: qmatmul ({m}x{k}x{n} int8) ===")
    rng = np.random.default_rng(0)
    x_q = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (n,), dtype=np.int32))
    scale = jnp.full((n,), 1e-3, jnp.float32)
    zp = jnp.int32(0)

    rows = []
    for backend in backends:
        base = None
        for policy in BENCH_POLICIES:
            f = jax.jit(lambda xq, wq, p=policy, be=backend: dependable_qmatmul(
                p, xq, zp, wq, bias, scale, zp, backend=be)[0])
            t = _time(f, x_q, w_q, reps=reps)
            base = base or t
            gmacs = m * k * n / t / 1e9
            rows.append({"backend": backend, "policy": policy.value,
                         "ms": round(t * 1e3, 4),
                         "overhead_x": round(t / base, 3),
                         "gmacs": round(gmacs, 2)})
            print(f"campaign_bench,qmatmul_policy={policy.value},"
                  f"backend={backend},ms={t * 1e3:.3f},"
                  f"overhead_x={t / base:.2f},gmacs={gmacs:.2f}")
    return rows


def bench_conv_policy_overhead(h=32, w=32, cin=32, cout=32, reps=10,
                               backends=("jnp",)):
    print(f"\n=== policy overhead: qconv2d ({h}x{w}x{cin}->{cout} 3x3) ===")
    rng = np.random.default_rng(1)
    x_q = jnp.asarray(rng.integers(-128, 128, (1, h, w, cin), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (3, 3, cin, cout), dtype=np.int32), jnp.int8)
    bias = jnp.asarray(rng.integers(-100, 100, (cout,), dtype=np.int32))
    scale = jnp.full((cout,), 1e-3, jnp.float32)
    zp = jnp.int32(0)

    rows = []
    for backend in backends:
        base = None
        for policy in BENCH_POLICIES:
            f = jax.jit(lambda xq, wq, p=policy, be=backend: dependable_qconv2d(
                p, xq, zp, wq, bias, scale, zp, backend=be)[0])
            t = _time(f, x_q, w_q, reps=reps)
            base = base or t
            rows.append({"backend": backend, "policy": policy.value,
                         "ms": round(t * 1e3, 4),
                         "overhead_x": round(t / base, 3)})
            print(f"campaign_bench,qconv2d_policy={policy.value},"
                  f"backend={backend},ms={t * 1e3:.3f},"
                  f"overhead_x={t / base:.2f}")
    return rows


def bench_trial_rate(trials=200, workloads=("qmatmul", "serving"), cache=None):
    """Trials/s per workload: the kernel path amortizes across one vmapped
    XLA call; the host-side serving path is one engine run per trial."""
    from repro.campaign import CampaignSpec, run_campaign
    out = {}
    cache = {} if cache is None else cache
    for workload in workloads:
        site = "accumulator" if workload == "qmatmul" else "kv_cache"
        n = trials if workload == "qmatmul" else max(trials // 4, 10)
        print(f"\n=== campaign trial rate: {workload} ({n} trials/config) ===")
        specs = [CampaignSpec(workload, p, site, "single_bitflip", n, seed=0)
                 for p in (Policy.NONE, Policy.ABFT)]
        run_campaign(specs[:1], cache=cache)      # warm build + compile
        t0 = time.perf_counter()
        results = run_campaign(specs, cache=cache)
        dt = time.perf_counter() - t0
        total = sum(r.trials for r in results)
        rate = total / dt
        print(f"campaign_bench,trial_rate,workload={workload},trials={total},"
              f"seconds={dt:.2f},trials_per_s={rate:.1f}")
        out[workload] = {"trials": total, "seconds": round(dt, 3),
                         "trials_per_s": round(rate, 1)}
    return out


def bench_adaptive_vs_fixed(trials=100, ci_halfwidth=0.1, cache=None):
    """The adaptive engine's headline: equal-precision verdicts, fewer
    trials.  Both runs execute prefixes of the same key stream, so the
    adaptive run's verdict is a true early decision, not a reseed."""
    from repro.campaign import CampaignSpec, SamplingPlan, run_campaign
    print(f"\n=== adaptive vs fixed: serving/abft/kv_cache "
          f"(cap {trials}, target halfwidth {ci_halfwidth:g}) ===")
    spec = CampaignSpec("serving", Policy.ABFT, "kv_cache",
                        "single_bitflip", trials, seed=0)
    cache = {} if cache is None else cache
    run_campaign([CampaignSpec("serving", Policy.ABFT, "kv_cache",
                               "single_bitflip", 2, seed=0)], cache=cache)

    t0 = time.perf_counter()
    fixed = run_campaign([spec], cache=cache)[0]
    fixed_s = time.perf_counter() - t0

    plan = SamplingPlan(ci_halfwidth=ci_halfwidth, chunk=25, min_trials=25)
    t0 = time.perf_counter()
    adaptive = run_campaign([spec], plan=plan, cache=cache)[0]
    adaptive_s = time.perf_counter() - t0

    trial_speedup = fixed.trials / max(adaptive.trials, 1)
    wall_speedup = fixed_s / max(adaptive_s, 1e-9)
    verdict_match = (adaptive.sdc_rate == fixed.sdc_rate == 0.0
                     and adaptive.detection_rate == fixed.detection_rate)
    print(f"campaign_bench,adaptive_vs_fixed,fixed_trials={fixed.trials},"
          f"adaptive_trials={adaptive.trials},"
          f"trial_speedup={trial_speedup:.2f},wall_speedup={wall_speedup:.2f},"
          f"verdict_match={verdict_match},"
          f"adaptive_sdc_ci_hi={adaptive.sdc_ci_hi:.4f}")
    return {
        "workload": spec.workload, "policy": spec.policy.value,
        "site": spec.site, "fault_model": spec.fault_model,
        "ci_halfwidth": ci_halfwidth, "confidence": plan.confidence,
        "ci_method": plan.ci_method,
        "fixed": {"trials": fixed.trials, "seconds": round(fixed_s, 3),
                  "sdc_rate": fixed.sdc_rate,
                  "detection_rate": fixed.detection_rate},
        "adaptive": {"trials": adaptive.trials,
                     "seconds": round(adaptive_s, 3),
                     "sdc_rate": adaptive.sdc_rate,
                     "detection_rate": adaptive.detection_rate,
                     "sdc_ci_hi": round(adaptive.sdc_ci_hi, 6),
                     "early_stopped": adaptive.early_stopped},
        "trial_speedup": round(trial_speedup, 2),
        "wall_speedup": round(wall_speedup, 2),
        "verdict_match": verdict_match,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backends", default="jnp",
                    help="comma list of execution backends to compare "
                         "(jnp, ref, pallas)")
    ap.add_argument("--out", default="BENCH_campaign.json",
                    help="summary JSON path ('' skips writing)")
    args = ap.parse_args(argv)
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    reps = 5 if args.fast else 20
    qm_shape = (256, 512, 256)
    conv_shape = (32, 32, 32, 32)
    qm_rows = bench_policy_overhead(*qm_shape, reps=reps, backends=backends)
    conv_rows = bench_conv_policy_overhead(
        *conv_shape, reps=max(reps // 2, 3), backends=backends)
    cache = {}
    rates = bench_trial_rate(trials=50 if args.fast else 200, cache=cache)
    adaptive = bench_adaptive_vs_fixed(trials=50 if args.fast else 100,
                                       cache=cache)
    if args.out:
        doc = {
            "bench": "campaign",
            "fast": bool(args.fast),
            # the per-policy overhead tables the printed CSV shows, as JSON
            # — the DSE cost oracle (repro/dse/cost.py) and humans read the
            # same numbers
            "policy_overhead": {
                "qmatmul": {"shape_mkn": list(qm_shape), "rows": qm_rows},
                "qconv2d": {"shape_hwcc": list(conv_shape),
                            "rows": conv_rows},
            },
            "trial_rate": rates,
            "adaptive_vs_fixed": adaptive,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
