"""Observability layer: metrics registry semantics, byte-identical
same-seed span traces (transformer + rwkv, multi-step, under rollback),
the structured dependability event log, FleetMetrics export stability, and
the campaign report's timeline columns."""
from __future__ import annotations

import json

import jax
import pytest

from repro.configs import registry
from repro.core import fault_injection as fi
from repro.core.dependability import Policy
from repro.models import api as model_api
from repro.models.config import reduced
from repro.obs import (EventLog, Histogram, Registry, SpanTracer,
                       exp_buckets, merge_traces)
from repro.runtime.serving import Engine, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_fns():
    # This module compiles engine variants for two model families (traced,
    # untraced, multi-step, rollback); holding those executables for the rest
    # of the suite pushes the process's accumulated XLA compile state past
    # what later large compiles (transformer w8a8) survive.  Drop them when
    # the module is done — later tests recompile what they need.
    yield
    jax.clear_caches()


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_semantics():
    reg = Registry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(4)
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.dec(2)
    assert c.value == 5 and g.value == 5
    # get-or-create: same name returns the same instrument…
    assert reg.counter("reqs_total") is c
    # …and a kind clash is an error, not a silent shadow
    with pytest.raises(TypeError):
        reg.gauge("reqs_total")


def test_histogram_exact_stats_and_bounded_memory():
    h = Histogram("lat", buckets=exp_buckets(1.0, 2.0, 8))
    n_buckets = len(h.to_dict()["buckets"])
    for i in range(10_000):
        h.observe(float(i % 250))
    assert h.count == 10_000
    assert h.min == 0.0 and h.max == 249.0
    assert h.mean() == pytest.approx(124.5)
    p50 = h.percentile(0.5)
    assert h.min <= p50 <= h.max
    # streaming: absorbing 10k samples must not grow the representation
    assert len(h.to_dict()["buckets"]) == n_buckets


def test_histogram_percentile_clamped_to_observed_range():
    h = Histogram("x", buckets=(1.0, 10.0, 100.0))
    for v in (3.0, 4.0, 5.0):
        h.observe(v)
    assert h.percentile(0.0) >= h.min
    assert h.percentile(1.0) <= h.max


def test_registry_snapshot_and_prometheus_render():
    reg = Registry()
    reg.counter("a_total", "a").inc(3)
    reg.histogram("h", "h", buckets=(1.0, 2.0)).observe(1.5)
    snap = reg.snapshot()
    assert list(snap) == ["a_total", "h"]      # registration order
    text = reg.render_prometheus()
    assert "a_total 3" in text
    assert 'h_bucket{le="2"' in text or 'h_bucket{le="2.0"}' in text
    assert "h_sum" in text and "h_count 1" in text


def test_registry_dump_json_and_prom(tmp_path):
    reg = Registry()
    reg.counter("c_total").inc()
    jpath = reg.dump(tmp_path / "m.json")
    assert json.loads(jpath.read_text())["c_total"]["value"] == 1
    ppath = reg.dump(tmp_path / "m.prom")
    assert "c_total 1" in ppath.read_text()


# ---------------------------------------------------------------------------
# Span tracer primitives
# ---------------------------------------------------------------------------


def test_tracer_span_lifecycle_and_canonical_bytes():
    def build():
        tr = SpanTracer()
        tr.tick_to(1)
        tr.open_span(0, "admit", prompt_len=3)
        tr.tick_to(2)
        tr.close_span(0, "admit")
        tr.instant("strike", site="kv_cache")
        tr.counter("queue_depth", submit=2)
        tr.open_span(1, "decode")          # left open: flushed as unfinished
        return tr

    a, b = build(), build()
    assert a.to_bytes() == b.to_bytes()
    doc = a.to_chrome_trace()
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {"admit", "decode"} == {e["name"] for e in spans}
    admit = next(e for e in spans if e["name"] == "admit")
    assert admit["ts"] == 1 and admit["dur"] == 1
    assert admit["args"]["uid"] == 0 and admit["args"]["prompt_len"] == 3
    open_flush = next(e for e in spans if e["name"] == "decode")
    assert open_flush["args"]["unfinished"] is True
    assert doc["metadata"]["clock"] == "ticks"


def test_tracer_cancel_drops_span_silently():
    tr = SpanTracer()
    tr.open_span(7, "prefill")
    tr.cancel_span(7, "prefill")
    tr.close_span(7, "prefill")            # not open: silent no-op
    assert not [e for e in tr.events if e["ph"] == "X"]


def test_merge_traces_keeps_pids_distinct():
    a, b = SpanTracer(name="replica0", pid=0), SpanTracer(name="replica1",
                                                          pid=1)
    for tr in (a, b):
        tr.open_span(0, "decode")
        tr.tick_to(3)
        tr.close_span(0, "decode")
    doc = merge_traces([a, b])
    assert {e["pid"] for e in doc["traceEvents"]} == {0, 1}
    assert doc["metadata"]["tracer"] == "replica0+replica1"


# ---------------------------------------------------------------------------
# Engine trace determinism (the byte-identity acceptance criterion)
# ---------------------------------------------------------------------------

TRACE_ARCHS = ["smollm-135m", "rwkv6-1.6b"]


@pytest.fixture(scope="module", params=TRACE_ARCHS)
def traced_family(request):
    cfg = reduced(registry.get(request.param))
    params = model_api.init_params(cfg, jax.random.key(0))
    return cfg, params


def _traced_serve(cfg, params, *, multi_step=1, rollback=False):
    tracer = SpanTracer()
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 multi_step=multi_step,
                 snapshot_every=2 if rollback else 32,
                 state_scrub="rollback" if rollback else "off",
                 tracer=tracer)
    prompts = [[5, 9, 2], [3, 1, 4, 1], [2, 7]]
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    if rollback:
        for _ in range(3):
            eng.step()
        eng.strike("decode_state", fi.flip_one_bit, jax.random.key(3))
    eng.run()
    return tracer, [list(r.output) for r in reqs]


def test_same_seed_traces_are_byte_identical(traced_family):
    cfg, params = traced_family
    tr_a, out_a = _traced_serve(cfg, params)
    tr_b, out_b = _traced_serve(cfg, params)
    assert out_a == out_b
    assert tr_a.to_bytes() == tr_b.to_bytes()
    spans = [e for e in tr_a.to_chrome_trace()["traceEvents"]
             if e["ph"] == "X"]
    assert {e["name"] for e in spans} == {"admit", "prefill", "decode",
                                          "certify"}
    # every request leaves a full certified span chain
    certified = [e for e in spans if e["name"] == "certify"]
    assert len(certified) == 3
    assert all(e["args"]["certified"] for e in certified)


def test_multi_step_traces_are_byte_identical(traced_family):
    cfg, params = traced_family
    tr_a, out_a = _traced_serve(cfg, params, multi_step=4)
    tr_b, out_b = _traced_serve(cfg, params, multi_step=4)
    assert out_a == out_b
    assert tr_a.to_bytes() == tr_b.to_bytes()


def test_rollback_traces_are_byte_identical(traced_family):
    """Snapshot rollback repairs the span state deterministically: the
    same strike at the same tick replays to the same byte stream."""
    cfg, params = traced_family
    tr_a, out_a = _traced_serve(cfg, params, multi_step=2, rollback=True)
    tr_b, out_b = _traced_serve(cfg, params, multi_step=2, rollback=True)
    assert out_a == out_b
    assert tr_a.to_bytes() == tr_b.to_bytes()
    names = [e["name"] for e in tr_a.events if e["ph"] == "i"]
    assert "strike" in names and "rollback" in names


def test_tracing_is_a_pure_observer(traced_family):
    """Token streams with tracing on must equal the untraced streams."""
    cfg, params = traced_family
    _, traced = _traced_serve(cfg, params, multi_step=2, rollback=True)
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 multi_step=2, snapshot_every=2, state_scrub="rollback")
    assert eng.tracer is None            # disabled by default: None hooks
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=4)
            for i, p in enumerate([[5, 9, 2], [3, 1, 4, 1], [2, 7]])]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.strike("decode_state", fi.flip_one_bit, jax.random.key(3))
    eng.run()
    assert [list(r.output) for r in reqs] == traced


def test_engine_metrics_counters_match_stats(traced_family):
    cfg, params = traced_family
    reg = Registry()
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 metrics=reg)
    reqs = [Request(uid=i, prompt=[5, 2, 9], max_new_tokens=3)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    snap = reg.snapshot()
    assert snap["engine_requests_submitted_total"]["value"] == 3
    assert snap["engine_requests_released_total"]["value"] == 3
    # mirrors stats.tokens_out: decode-step tokens (each request's first
    # token comes from prefill, not a decode step)
    assert snap["engine_tokens_out_total"]["value"] == eng.stats.tokens_out
    assert snap["engine_release_latency_ticks"]["count"] == 3


# ---------------------------------------------------------------------------
# Event log
# ---------------------------------------------------------------------------


def test_event_log_validates_kind_and_merges_ctx():
    log = EventLog(policy="ckpt", replica=2)
    ev = log.emit("strike", tick=4, site="kv_cache", fault="single_bitflip")
    assert ev.policy == "ckpt" and ev.replica == 2 and ev.site == "kv_cache"
    with pytest.raises(ValueError):
        log.emit("meteor", tick=5)


def test_event_log_timeline_reconstruction():
    log = EventLog(policy="ckpt")
    log.emit("strike", tick=10, site="kv_cache")
    log.emit("detection", tick=12, site="decode_state")
    log.emit("rollback", tick=13, seconds=0.5)
    log.emit("strike", tick=20, site="weights")      # undetected chain
    tls = log.timelines()
    assert len(tls) == 2
    first, second = tls
    assert first["detected"] and first["detection_latency_ticks"] == 2
    assert first["recovered"] and first["recovery_latency_ticks"] == 3
    assert first["recovery_seconds"] == 0.5
    assert not second["detected"] and not second["recovered"]
    summary = log.latency_summary()["ckpt"]
    assert summary["strikes"] == 2 and summary["detected"] == 1
    assert summary["detection_ticks_mean"] == 2.0


def test_event_log_wall_flag_strips_seconds():
    log = EventLog()
    log.emit("strike", tick=1)
    log.emit("recovery", tick=2, seconds=1.25)
    with_wall = log.to_json(wall=True)
    without = log.to_json(wall=False)
    assert with_wall["events"][1]["seconds"] == 1.25
    assert all("seconds" not in e for e in without["events"])
    assert all("recovery_seconds" not in t for t in without["timelines"])


def test_engine_emits_provenance_stamped_events(traced_family):
    cfg, params = traced_family
    log = EventLog(policy="ckpt")
    eng = Engine(cfg, params, capacity=2, max_len=96, prefill_pad=8,
                 snapshot_every=2, state_scrub="rollback", event_log=log)
    reqs = [Request(uid=i, prompt=[5, 2, 9], max_new_tokens=6)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    eng.strike("decode_state", fi.flip_one_bit, jax.random.key(3))
    eng.run()
    kinds = [e.kind for e in log]
    assert kinds.count("strike") == 1
    assert "detection" in kinds and "rollback" in kinds
    strike = log.of_kind("strike")[0]
    assert strike.site == "decode_state" and strike.fault == "flip_one_bit"
    assert strike.policy == "ckpt"
    (tl,) = log.timelines()
    assert tl["detected"] and tl["recovered"]
    assert tl["detection_latency_ticks"] >= 0


# ---------------------------------------------------------------------------
# Campaign integration: one strike per trial, policy-resolved chains
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving_campaign():
    from repro.campaign import faultload as fl
    from repro.campaign.runner import run_campaign
    specs = fl.expand_grid(
        ["serving"], [Policy.NONE, Policy.ABFT, Policy.CKPT],
        ["kv_cache", "weights"], ["single_bitflip"], 2, 0)
    sink = []
    results = run_campaign(specs, event_sink=sink)
    return {(r.policy, r.site): r for r in results}, \
        {e["config"]: e["timelines"] for e in sink}


def test_campaign_logs_exactly_one_strike_per_trial(serving_campaign):
    results, _ = serving_campaign
    for r in results.values():
        assert r.strikes_logged == r.trials, (r.policy, r.site)


def test_campaign_detection_recovery_under_policies(serving_campaign):
    results, timelines = serving_campaign
    for site in ("kv_cache", "weights"):
        for policy in ("abft", "ckpt"):
            r = results[(policy, site)]
            assert r.detections_logged == r.trials, (policy, site)
            tls = timelines[f"serving/{policy}/{site}/single_bitflip"]
            assert all(t["detected"] for t in tls)
            assert all(t["detection_latency_ticks"] >= 0 for t in tls)
            if policy == "ckpt":
                assert all(t["recovered"] for t in tls), site
                r_lat = [t["recovery_latency_ticks"] for t in tls]
                assert all(lat >= 0 for lat in r_lat)


def test_campaign_none_policy_detects_nothing(serving_campaign):
    results, timelines = serving_campaign
    for site in ("kv_cache", "weights"):
        r = results[("none", site)]
        assert r.detections_logged == 0, site
        tls = timelines[f"serving/none/{site}/single_bitflip"]
        assert all(not t["detected"] and not t["recovered"] for t in tls)


def test_campaign_accumulator_site_synthesized_timelines():
    """Kernel (in-graph) workloads cannot emit host events mid-vmap; the
    runner synthesizes the chains from trial verdicts — ABFT detects every
    accumulator strike, NONE never does."""
    from repro.campaign import faultload as fl
    from repro.campaign.runner import run_campaign
    specs = fl.expand_grid(["qmatmul"], [Policy.NONE, Policy.ABFT,
                                         Policy.CKPT],
                           ["accumulator"], ["single_bitflip"], 8, 0)
    sink = []
    results = {r.policy: r for r in run_campaign(specs, event_sink=sink)}
    assert results["abft"].strikes_logged == 8
    assert results["abft"].detections_logged == 8
    assert results["none"].detections_logged == 0
    ck = results["ckpt"]
    assert ck.detections_logged == 8 and ck.faults_recovered == 8
    # in-op detection is same-tick: zero-latency chains
    assert ck.detection_ticks_max == 0 and ck.recovery_ticks_max == 0


def test_config_result_timeline_columns_round_trip():
    from repro.campaign.report import ConfigResult, to_markdown
    r = ConfigResult(workload="serving", policy="ckpt", site="kv_cache",
                     fault_model="single_bitflip", trials=4, masked=0,
                     detected_corrected=4, detected_uncorrected=0, sdc=0,
                     faults_recovered=4, strikes_logged=4,
                     detections_logged=4, detection_ticks_mean=1.5,
                     detection_ticks_max=3, recovery_ticks_mean=2.0,
                     recovery_ticks_max=4)
    again = ConfigResult.from_dict(r.to_dict())
    assert again == r
    # reports written before the timeline columns still load
    legacy = {k: v for k, v in r.to_dict().items()
              if not k.startswith(("strikes_", "detections_",
                                   "detection_", "recovery_ticks"))}
    old = ConfigResult.from_dict(legacy)
    assert old.strikes_logged == 0 and old.detection_ticks_mean == 0.0
    md = to_markdown([r])
    assert "det. lat ticks (mean/max)" in md
    assert "| 1.5/3 |" in md and "| 2.0/4 |" in md


# ---------------------------------------------------------------------------
# FleetMetrics export stability
# ---------------------------------------------------------------------------


def test_fleet_metrics_attribute_routing_and_json_keys():
    from repro.fleet.metrics import FleetMetrics
    m = FleetMetrics(lost_work_bound_tokens=12)
    m.detections += 1
    m.observe_release(4, 2)
    m.observe_release(8, 3)
    m.released += 1
    m.observe_recovery(0.5, leaves=2, incremental=True)
    assert m.released == 3 and m.detections == 1 and m.tokens_out == 5
    doc = m.to_json()
    for key in ("released", "detections", "recoveries", "failovers",
                "scrubs", "lost_work_bound_tokens", "p50_latency_ticks",
                "p99_latency_ticks", "tokens_per_tick", "recovery_count",
                "recovery_mean_seconds", "recovery_max_seconds"):
        assert key in doc, key
    assert doc["lost_work_bound_tokens"] == 12
    assert doc["recovery_count"] == 1
    assert doc["recovery_mean_seconds"] == pytest.approx(0.5)
    assert m.incremental_restores == 1
    # wall-clock numbers are opt-in so default reports diff cleanly
    assert "tokens_per_second" not in doc and "wall_seconds" not in doc
    wall = m.to_json(wall=True)
    assert "tokens_per_second" in wall and "wall_seconds" in wall


def test_fleet_metrics_histograms_are_streaming():
    from repro.fleet.metrics import FleetMetrics
    m = FleetMetrics()
    for i in range(50_000):
        m.observe_release(i % 128, 1)
    assert m.latencies.count == 50_000
    assert m.p50_ticks <= m.p99_ticks <= m.latencies.max
