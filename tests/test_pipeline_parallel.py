"""Pipeline parallelism (HPDP→HPDP chaining analogue): correctness vs
sequential execution, differentiability, bubble accounting."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import pipeline as pp

jax.config.update("jax_platform_name", "cpu")

N_DEV = jax.device_count()


def make_stage_params(key, n_stages, d):
    ks = jax.random.split(key, n_stages)
    return [{"w": jax.random.normal(k, (d, d)) * 0.3,
             "b": jnp.zeros((d,))} for k in ks]


def stage_fn(params, x):
    return jnp.tanh(x @ params["w"] + params["b"])


def sequential(param_list, mb):
    out = mb
    for p in param_list:
        out = jax.vmap(lambda m: stage_fn(p, m))(out)
    return out


@pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices (set XLA flag)")
def test_pipeline_matches_sequential():
    mesh = jax.make_mesh((N_DEV,), ("stage",))
    n_stages, n_micro, mb, d = N_DEV, 6, 2, 8
    plist = make_stage_params(jax.random.key(0), n_stages, d)
    stacked = pp.stack_stage_params(plist)
    x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
    got = pp.pipeline_apply(stage_fn, stacked, x, mesh)
    want = sequential(plist, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices")
def test_pipeline_grads_flow():
    """Autodiff through ppermute: every stage's params get nonzero grads."""
    mesh = jax.make_mesh((N_DEV,), ("stage",))
    n_stages, n_micro, mb, d = N_DEV, 4, 2, 8
    plist = make_stage_params(jax.random.key(0), n_stages, d)
    stacked = pp.stack_stage_params(plist)
    x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))

    def loss(params):
        out = pp.pipeline_apply(stage_fn, params, x, mesh)
        return jnp.mean(out ** 2)

    g = jax.grad(loss)(stacked)
    for leaf in jax.tree_util.tree_leaves(g):
        per_stage = np.asarray(jnp.sum(jnp.abs(leaf), axis=tuple(
            range(1, leaf.ndim))))
        assert (per_stage > 0).all(), "a stage got zero gradient"

    # gradient agrees with the sequential reference
    def seq_loss(plist_flat):
        out = sequential(plist_flat, x)
        return jnp.mean(out ** 2)

    g_seq = jax.grad(seq_loss)(plist)
    g_seq_stacked = pp.stack_stage_params(jax.tree_util.tree_map(
        lambda x: x, g_seq))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        g, g_seq_stacked)


def test_bubble_fraction():
    assert pp.bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert pp.bubble_fraction(2, 30) == pytest.approx(1 / 31)
    # more microbatches shrink the bubble monotonically
    fr = [pp.bubble_fraction(8, m) for m in (8, 16, 32, 64)]
    assert fr == sorted(fr, reverse=True)
