"""qconv2d Pallas kernel vs pure-jnp oracle — the paper's validation (Fig. 4).

Sweeps cover the exact Table-1 layer geometries from the paper plus stride,
padding, ragged channel counts, and hypothesis-driven random cases.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant
from repro.kernels.qconv2d import ops
from repro.kernels.qconv2d.ref import qconv2d_ref

jax.config.update("jax_platform_name", "cpu")

# The paper's Table-1 layers: (kernel: Cout x KH x KW x Cin, image: H x W x Cin)
# exercised at reduced spatial size for test speed; the benchmark harness runs
# the full sizes.
PAPER_LAYERS = [
    # (kh, kw, cin, cout, h, w)
    (3, 3, 24, 24, 48, 48),     # 24x3x3x24 @ 194x194x24 (reduced spatially)
    (3, 3, 48, 48, 24, 24),     # 48x3x3x48 @ 98x98x48
    (3, 3, 96, 96, 12, 12),     # 96x3x3x96 @ 50x50x96
    (1, 1, 96, 96, 24, 24),     # 96x1x1x96 @ 96x96x96
]


def _random_conv_case(rng, n, h, w, cin, kh, kw, cout):
    x_q = jnp.asarray(rng.integers(-128, 128, (n, h, w, cin), dtype=np.int32), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (kh, kw, cin, cout), dtype=np.int32), jnp.int8)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=(0, 1, 2))
    bias = jnp.asarray(rng.integers(-1000, 1000, (cout,), dtype=np.int32))
    scale = jnp.asarray(rng.uniform(1e-4, 5e-3, (cout,)).astype(np.float32))
    x_zp = jnp.int32(int(rng.integers(-10, 10)))
    out_zp = jnp.int32(int(rng.integers(-10, 10)))
    return x_q, w_q, colsum, bias, scale, x_zp, out_zp


@pytest.mark.parametrize("kh,kw,cin,cout,h,w", PAPER_LAYERS)
def test_paper_table1_layers(kh, kw, cin, cout, h, w):
    rng = np.random.default_rng(kh * 100 + cin)
    x_q, w_q, colsum, bias, scale, x_zp, out_zp = _random_conv_case(
        rng, 1, h, w, cin, kh, kw, cout)
    got = ops.qconv2d_op(x_q, x_zp, w_q, colsum, bias, scale, out_zp,
                         stride=(1, 1), padding="SAME",
                         use_kernel=True, interpret=True)
    want = qconv2d_ref(x_q, x_zp, w_q, bias, scale, out_zp,
                       stride=(1, 1), padding="SAME")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("stride", [(1, 1), (2, 2), (2, 1)])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_stride_padding_sweep(stride, padding):
    rng = np.random.default_rng(7)
    x_q, w_q, colsum, bias, scale, x_zp, out_zp = _random_conv_case(
        rng, 2, 17, 19, 8, 3, 3, 16)
    got = ops.qconv2d_op(x_q, x_zp, w_q, colsum, bias, scale, out_zp,
                         stride=stride, padding=padding,
                         use_kernel=True, interpret=True)
    want = qconv2d_ref(x_q, x_zp, w_q, bias, scale, out_zp,
                       stride=stride, padding=padding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_qconv2d_random_cases(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 3))
    h = int(rng.integers(4, 20))
    w = int(rng.integers(4, 20))
    cin = int(rng.integers(1, 32))
    cout = int(rng.integers(1, 48))
    kh = int(rng.choice([1, 3, 5]))
    kw = int(rng.choice([1, 3]))
    if kh > h or kw > w:
        kh, kw = 1, 1
    x_q, w_q, colsum, bias, scale, x_zp, out_zp = _random_conv_case(
        rng, n, h, w, cin, kh, kw, cout)
    got = ops.qconv2d_op(x_q, x_zp, w_q, colsum, bias, scale, out_zp,
                         stride=(1, 1), padding="SAME",
                         use_kernel=True, interpret=True)
    want = qconv2d_ref(x_q, x_zp, w_q, bias, scale, out_zp,
                       stride=(1, 1), padding="SAME")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qconv_act_end_to_end_accuracy():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(1, 16, 16, 24)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 24, 24)).astype(np.float32) * 0.05)
    b = jnp.asarray(rng.normal(size=(24,)).astype(np.float32) * 0.1)
    params = ops.make_qconv_params(w, b)
    y_f = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    x_scale, x_zp = quant.affine_qparams(jnp.min(x), jnp.max(x))
    o_scale, o_zp = quant.affine_qparams(jnp.min(y_f), jnp.max(y_f))
    y_q = ops.qconv_act(x, params, x_scale, x_zp, o_scale, o_zp,
                        use_kernel=True, interpret=True)
    rel = np.linalg.norm(np.asarray(y_q - y_f)) / np.linalg.norm(np.asarray(y_f))
    assert rel < 0.02, rel
