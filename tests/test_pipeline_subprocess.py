"""Run the pipeline-parallel correctness check under 8 fake CPU devices.

The main pytest process must keep the default single-device view (smoke
tests and benches depend on it), so multi-device pipeline coverage runs in
a subprocess with XLA_FLAGS set — the same trick launch/dryrun.py uses.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import pipeline as pp

mesh = jax.make_mesh((4,), ("stage",))
d, n_micro, mb = 8, 6, 2
ks = jax.random.split(jax.random.key(0), 4)
plist = [{"w": jax.random.normal(k, (d, d)) * 0.3, "b": jnp.zeros((d,))}
         for k in ks]
stage_fn = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])
stacked = pp.stack_stage_params(plist)
x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))

got = pp.pipeline_apply(stage_fn, stacked, x, mesh)
want = x
for p in plist:
    want = jax.vmap(lambda m: stage_fn(p, m))(want)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-5, atol=1e-5)

# grads flow through ppermute and match sequential
def loss(params):
    return jnp.mean(pp.pipeline_apply(stage_fn, params, x, mesh) ** 2)
g = jax.grad(loss)(stacked)

def seq_loss(pl):
    out = x
    for p in pl:
        out = jax.vmap(lambda m: stage_fn(p, m))(out)
    return jnp.mean(out ** 2)
g_seq = pp.stack_stage_params(jax.grad(seq_loss)(plist))
jax.tree_util.tree_map(
    lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                            rtol=1e-4, atol=1e-5),
    g, g_seq)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_parallel_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout
