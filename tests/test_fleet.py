"""Dependable serving fleet: routing determinism, admission control,
bit-exact failover across model families, weight-SEU recovery
(quarantine → checkpoint reload → re-verify → readmit), DMR pair-serving,
deadlines, metrics export, and the fleet-level campaign certification.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import CampaignSpec, classify_counts, resolve_fault_model, trial_keys
from repro.configs import registry
from repro.core import fault_injection as fi
from repro.core.dependability import Policy
from repro.fleet import Fleet, ReplicaState, Router
from repro.models import api as model_api
from repro.models.config import reduced
from repro.runtime.serving import Engine, Request

jax.config.update("jax_platform_name", "cpu")

PROMPTS = [[5, 9, 2], [3, 1, 4, 1], [2, 7], [8, 8, 6], [1, 6, 1, 8]]
N_NEW = 5


def greedy_reference(cfg, params, prompt, n_new, max_len=96):
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model_api.prefill(cfg, params, toks, max_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = model_api.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


@pytest.fixture(scope="module", params=["smollm-135m", "rwkv6-1.6b"])
def family_fleet(request):
    """One 2-replica fleet per model family (compiled once, reset per test)."""
    cfg = reduced(registry.get(request.param))
    params = model_api.init_params(cfg, jax.random.key(0))
    fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.NONE,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=3)
    return cfg, params, fleet


@pytest.fixture(scope="module")
def smollm_fleet():
    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    fleet = Fleet(cfg, params, n_replicas=3, policy=Policy.NONE,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=3)
    return cfg, params, fleet


def _serve(fleet, prompts, policy, n_new=N_NEW, mid_run=None):
    """Reset + submit + (optional mid-run drill) + drain; returns requests."""
    fleet.reset(policy=policy)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        assert fleet.submit(r)
    if mid_run is not None:
        fleet.tick()
        fleet.tick()
        mid_run(fleet)
    fleet.run()
    return reqs


# ---------------------------------------------------------------------------
# baseline correctness: a fleet serves exactly what one engine would
# ---------------------------------------------------------------------------


def test_fleet_matches_single_engine_reference(family_fleet):
    cfg, params, fleet = family_fleet
    reqs = _serve(fleet, PROMPTS, Policy.NONE)
    for r, p in zip(reqs, PROMPTS):
        assert r.uid in fleet.released
        assert r.output == greedy_reference(cfg, params, p, N_NEW), f"req {r.uid}"
    assert fleet.metrics.released == len(PROMPTS)


# ---------------------------------------------------------------------------
# router: determinism + admission control
# ---------------------------------------------------------------------------


def test_hash_router_is_deterministic_and_stable(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    router = Router("hash")
    picks = [router.pick(uid, fleet.replicas).rid for uid in range(20)]
    assert picks == [router.pick(uid, fleet.replicas).rid for uid in range(20)]
    assert len(set(picks)) > 1            # spreads over replicas


def test_least_loaded_router_prefers_idle_lowest_rid(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    router = Router("least_loaded")
    assert router.pick(0, fleet.replicas).rid == 0     # all idle → lowest rid
    fleet.replicas[0].engine.submit(Request(uid=90, prompt=[1], max_new_tokens=2))
    assert router.pick(1, fleet.replicas).rid == 1     # 0 now loaded


def test_admission_control_rejects_when_full(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    old = fleet.router
    try:
        fleet.router = Router("least_loaded", admit_limit=1)
        assert fleet.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
        assert fleet.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=2))
        assert fleet.submit(Request(uid=2, prompt=[1, 2], max_new_tokens=2))
        # all three replicas now hold one request each — fleet is full
        assert not fleet.submit(Request(uid=3, prompt=[1, 2], max_new_tokens=2))
        assert fleet.metrics.rejected == 1
        fleet.run()
        assert fleet.metrics.released == 3
    finally:
        fleet.router = old


def test_deadline_miss_expires_request(smollm_fleet):
    _, _, fleet = smollm_fleet
    fleet.reset()
    req = Request(uid=0, prompt=[5, 9, 2], max_new_tokens=30)
    assert fleet.submit(req, deadline_ticks=2)
    fleet.run()
    assert fleet.metrics.deadline_misses == 1
    assert req.uid not in fleet.released


# ---------------------------------------------------------------------------
# deterministic failover — same tokens with or without a mid-decode kill,
# across two model families (satellite requirement)
# ---------------------------------------------------------------------------


def test_failover_after_kill_is_bit_exact(family_fleet):
    cfg, params, fleet = family_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.NONE)]

    reqs = _serve(fleet, PROMPTS, Policy.NONE,
                  mid_run=lambda f: f.kill_replica(0))
    assert fleet.replicas[0].state is ReplicaState.DEAD
    assert fleet.metrics.failovers > 0
    assert [list(r.output) for r in reqs] == golden
    assert fleet.metrics.released == len(PROMPTS)


def test_heartbeat_timeout_declares_paused_replica_dead(smollm_fleet):
    _, _, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.NONE)]
    reqs = _serve(fleet, PROMPTS, Policy.NONE,
                  mid_run=lambda f: f.pause_replica(0))
    assert any("heartbeat timeout" in e for e in fleet.supervisor.events)
    assert [list(r.output) for r in reqs] == golden


# ---------------------------------------------------------------------------
# weight-SEU recovery: quarantine → checkpoint reload → re-verify → readmit
# ---------------------------------------------------------------------------


def _corrupt_weights(fleet, key=jax.random.key(11)):
    victim = fleet.replicas[0]
    victim.engine.params = fi.inject_pytree_with(
        victim.engine.params, key, fi.flip_one_bit)


def test_abft_scrub_recovers_weight_seu(smollm_fleet):
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.ABFT)]
    assert fleet.metrics.detections == 0          # clean pass: no false alarms

    reqs = _serve(fleet, PROMPTS, Policy.ABFT,
                  mid_run=lambda f: _corrupt_weights(f))
    assert fleet.metrics.detections >= 1
    assert fleet.metrics.recoveries == 1
    assert fleet.replicas[0].state is ReplicaState.HEALTHY   # readmitted
    assert fleet.replicas[0].scrub() == []                   # re-verified
    assert [list(r.output) for r in reqs] == golden          # zero SDC
    assert fleet.metrics.released == len(PROMPTS)


def test_dmr_detects_transient_decode_fault(smollm_fleet):
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.DMR)]
    assert fleet.metrics.detections == 0

    def strike(f):
        v = f.replicas[0]
        v.engine.tokens = v.engine.tokens ^ 1     # flip every active token

    reqs = _serve(fleet, PROMPTS, Policy.DMR, mid_run=strike)
    assert fleet.metrics.detections >= 1
    assert fleet.metrics.recoveries == 0          # transient: weights clean
    assert [list(r.output) for r in reqs] == golden
    assert fleet.metrics.released == len(PROMPTS)


# ---------------------------------------------------------------------------
# CKPT fleet policy: incremental restore + decode-state rollback
# ---------------------------------------------------------------------------


def test_ckpt_weight_seu_incremental_restore(smollm_fleet):
    """CKPT is scrub-gated like ABFT but recovers by restoring only the
    corrupted leaves from the golden checkpoint — measured, incremental."""
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.CKPT)]
    assert fleet.metrics.detections == 0          # clean pass: no false alarms

    reqs = _serve(fleet, PROMPTS, Policy.CKPT,
                  mid_run=lambda f: _corrupt_weights(f))
    m = fleet.metrics
    assert m.detections >= 1
    assert m.recoveries == 1
    assert m.incremental_restores == 1            # partial restore served it
    assert m.full_reloads == 0
    assert m.leaves_restored >= 1
    assert m.recovery_seconds.count == 1 and m.recovery_seconds.sum > 0
    assert m.to_json()["recovery_mean_seconds"] > 0
    assert fleet.replicas[0].state is ReplicaState.HEALTHY
    assert [list(r.output) for r in reqs] == golden
    assert m.released == len(PROMPTS)


def test_ckpt_decode_state_seu_rolls_back_in_place(smollm_fleet):
    """Transient SEU in the token buffer under CKPT: the engine's own
    snapshot rollback heals it — no failover, stream golden."""
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.CKPT)]

    def strike(f):
        v = f.replicas[0]
        v.engine.tokens = fi.flip_one_bit(v.engine.tokens, jax.random.key(5))

    reqs = _serve(fleet, PROMPTS, Policy.CKPT, mid_run=strike)
    m = fleet.metrics
    assert m.state_scrub_detections >= 1
    assert m.state_rollbacks >= 1                 # healed in place…
    assert m.recoveries == 0                      # …not via quarantine
    assert [list(r.output) for r in reqs] == golden
    assert m.released == len(PROMPTS)


def test_recovery_survives_crashed_checkpoint_writer(smollm_fleet):
    """Crash-consistency at fleet level: an orphaned step_N.tmp (writer
    killed mid-publish) in the golden checkpoint dir must be invisible —
    quarantine-recovery restores from the durable manifest and the engine
    state it rebuilds is bit-exact (same released stream)."""
    from pathlib import Path
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.CKPT)]

    orphan = Path(fleet.ckpt_dir) / "step_0000000099.tmp"
    orphan.mkdir()
    (orphan / "chunks.npz").write_bytes(b"torn write")
    try:
        reqs = _serve(fleet, PROMPTS, Policy.CKPT,
                      mid_run=lambda f: _corrupt_weights(f))
        assert fleet.metrics.recoveries == 1
        assert fleet.replicas[0].scrub() == []         # bit-exact params
        assert [list(r.output) for r in reqs] == golden
    finally:
        if orphan.exists():
            import shutil
            shutil.rmtree(orphan)


def test_abft_decode_state_seu_drains_and_replays(smollm_fleet):
    """The same strike under ABFT: detect-only scrub, fleet drains the
    replica and replays on verified replicas — stream still golden."""
    cfg, params, fleet = smollm_fleet
    golden = [list(r.output) for r in _serve(fleet, PROMPTS, Policy.ABFT)]

    def strike(f):
        v = f.replicas[0]
        v.engine.tokens = fi.flip_one_bit(v.engine.tokens, jax.random.key(5))

    reqs = _serve(fleet, PROMPTS, Policy.ABFT, mid_run=strike)
    m = fleet.metrics
    assert m.state_scrub_detections >= 1
    assert m.state_drains >= 1
    assert m.state_rollbacks == 0
    assert [list(r.output) for r in reqs] == golden
    assert m.released == len(PROMPTS)


# ---------------------------------------------------------------------------
# metrics export
# ---------------------------------------------------------------------------


def test_metrics_json_round_trip(smollm_fleet, tmp_path):
    _, _, fleet = smollm_fleet
    _serve(fleet, PROMPTS, Policy.ABFT)
    m = fleet.metrics.to_json()
    for k in ("released", "p50_latency_ticks", "p99_latency_ticks",
              "tokens_per_tick", "recoveries", "failovers",
              "lost_work_bound_tokens", "scrubs"):
        assert k in m, k
    assert m["released"] == len(PROMPTS)
    assert m["p50_latency_ticks"] <= m["p99_latency_ticks"]
    p = fleet.metrics.dump(tmp_path / "fleet.json")
    assert json.loads(p.read_text())["released"] == len(PROMPTS)
    report = fleet.report()
    assert len(report["replicas"]) == 3
    json.dumps(report)                            # fully serializable


# ---------------------------------------------------------------------------
# fleet campaign certification (acceptance criteria)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet_case():
    from repro.campaign.runner import build_case
    return build_case("fleet", 0)


def test_fleet_campaign_abft_zero_sdc_none_nonzero_100_trials(fleet_case):
    """≥100 seeded weight-SEU trials: ABFT scrub+failover ⇒ every trial
    detected_corrected and fleet SDC = 0; NONE ⇒ nonzero SDC."""
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")

    spec_a = CampaignSpec("fleet", Policy.ABFT, "weights",
                          "single_bitflip", trials=100, seed=0)
    det, mis = case.run_trials(Policy.ABFT, "weights", fault.apply,
                               trial_keys(spec_a))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_uncorrected"] == 0
    assert counts["detected_corrected"] == 100    # every flip caught + healed

    spec_n = CampaignSpec("fleet", Policy.NONE, "weights",
                          "single_bitflip", trials=100, seed=0)
    det, mis = case.run_trials(Policy.NONE, "weights", fault.apply,
                               trial_keys(spec_n))
    counts = classify_counts(det, mis)
    assert not det.any()
    assert counts["sdc"] > 0                      # undefended fleet corrupts


def test_fleet_campaign_dmr_covers_transient_site(fleet_case):
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")
    spec = CampaignSpec("fleet", Policy.DMR, "decode_state",
                        "single_bitflip", trials=40, seed=1)
    det, mis = case.run_trials(Policy.DMR, "decode_state", fault.apply,
                               trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_corrected"] > 0


@pytest.mark.parametrize("policy", [Policy.ABFT, Policy.CKPT])
@pytest.mark.parametrize("site", ["decode_state", "kv_cache"])
def test_fleet_scrub_policies_cover_transient_sites(fleet_case, policy, site):
    """The decode-state scrub closes the old ABFT blind spot: transient
    SEUs in the KV cache / token buffer are detected by checksum and healed
    — CKPT by in-place engine rollback, ABFT by drain + failover — with
    zero SDC on the released stream."""
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")
    spec = CampaignSpec("fleet", policy, site, "single_bitflip",
                        trials=20, seed=3)
    det, mis = case.run_trials(policy, site, fault.apply, trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_corrected"] == 20      # detected AND healed
    stats = case.drain_recovery_stats()
    assert stats["faults_recovered"] >= 20
    assert stats["recovery_ms_mean"] > 0.0


def test_fleet_ckpt_weight_seu_recovers_incrementally(fleet_case):
    """CKPT fleet trial: weight SEU → scrub detect → *incremental* restore
    of only the corrupted leaves → released stream golden, recovery timed."""
    case = fleet_case
    fault = resolve_fault_model("single_bitflip")
    spec = CampaignSpec("fleet", Policy.CKPT, "weights",
                        "single_bitflip", trials=20, seed=4)
    det, mis = case.run_trials(Policy.CKPT, "weights", fault.apply,
                               trial_keys(spec))
    counts = classify_counts(det, mis)
    assert counts["sdc"] == 0
    assert counts["detected_corrected"] == 20
    m = case.fleet.metrics
    assert m.incremental_restores >= 1             # partial restore, not reload
    assert m.full_reloads == 0
    assert m.leaves_restored >= 1


# ---------------------------------------------------------------------------
# Process transport: framing, wire round trips, cross-process bit identity
# ---------------------------------------------------------------------------


def test_transport_frame_round_trip():
    from repro.fleet import transport as tp
    arrays = {
        "i32": np.arange(6, dtype=np.int32).reshape(2, 3),
        "f32": np.asarray([[1.5, -2.25]], np.float32),
        "i8": np.asarray([-128, 127], np.int8),
        "scalar": np.asarray(3.0, np.float64),
    }
    payload = {"x": 1, "s": "y", "nested": {"a": [1, 2]}}
    buf = tp.encode_frame(7, "step", payload, arrays)
    seq, op, got_payload, got_arrays = tp.decode_frame(buf)
    assert (seq, op, got_payload) == (7, "step", payload)
    assert set(got_arrays) == set(arrays)
    for name, a in arrays.items():
        g = got_arrays[name]
        assert g.dtype == a.dtype and g.shape == a.shape
        assert np.array_equal(g, a)


def test_transport_frame_rejects_garbage():
    from repro.fleet import transport as tp
    good = tp.encode_frame(0, "ping", {}, {})
    with pytest.raises(tp.ProtocolError):
        tp.decode_frame(b"XXXX" + good[4:])        # bad magic
    with pytest.raises(tp.ProtocolError):
        tp.decode_frame(good[:-1])                 # truncated
    with pytest.raises(tp.ProtocolError):
        tp.decode_frame(good + b"\x00")            # trailing bytes


def test_pipe_channel_enforces_consecutive_seq():
    import multiprocessing as mp_proc
    from repro.fleet import transport as tp
    a, b = mp_proc.Pipe()
    ch = tp.PipeChannel(a, "seqtest")
    b.send_bytes(tp.encode_frame(1, "first", {}, {}))
    op, _, _ = ch.get(5)
    assert op == "first"
    b.send_bytes(tp.encode_frame(3, "gap", {}, {}))  # skipped seq 2
    with pytest.raises(tp.ProtocolError):
        ch.get(5)
    a.close()
    b.close()


def test_request_wire_doc_round_trip():
    req = Request(uid=3, prompt=[1, 2, 3], max_new_tokens=5)
    clone = Request.from_doc(req.to_doc())
    assert (clone.uid, clone.prompt, clone.max_new_tokens) == (3, [1, 2, 3], 5)
    finished = Request.from_doc(req.to_doc())
    finished.output = [9, 8]
    finished.finished_tick = 4
    req.sync_from_doc(finished.to_doc())
    assert req.output == [9, 8] and req.finished_tick == 4


@pytest.mark.parametrize("arch", [
    "smollm-135m",
    pytest.param("rwkv6-1.6b", marks=pytest.mark.slow),
])
def test_proc_fleet_bit_identical_with_failover(arch):
    """The transport acceptance gate: a 3-replica process fleet releases
    byte-identical token streams to the in-process fleet — including when
    one worker is SIGKILLed mid-run and its work fails over."""
    cfg = reduced(registry.get(arch))
    params = model_api.init_params(cfg, jax.random.key(0))
    prompts = PROMPTS[:3]

    def serve(fleet, kill=False):
        fleet.reset()
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            assert fleet.submit(r)
        if kill:
            fleet.tick()
            fleet.tick()
            fleet.replicas[0].handle.proc.kill()     # SIGKILL, no goodbye
        fleet.run()
        return [tuple(fleet.released[r.uid].output) for r in reqs]

    ref = Fleet(cfg, params, n_replicas=3, policy=Policy.NONE,
                capacity=2, max_len=96, prefill_pad=8)
    try:
        golden = serve(ref)
    finally:
        ref.close()

    fleet = Fleet(cfg, params, n_replicas=3, policy=Policy.NONE,
                  capacity=2, max_len=96, prefill_pad=8, transport="proc")
    try:
        assert serve(fleet) == golden                # clean cross-process pass
        assert serve(fleet, kill=True) == golden     # mid-run worker loss
        assert fleet.metrics.recoveries + fleet.metrics.failovers > 0
        assert all(r.state is ReplicaState.HEALTHY for r in fleet.replicas)
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# Zero-drain rolling weight deploys
# ---------------------------------------------------------------------------


def _deploy_fleet(policy=Policy.ABFT):
    cfg = reduced(registry.get("smollm-135m"))
    pa = model_api.init_params(cfg, jax.random.key(0))
    fleet = Fleet(cfg, pa, n_replicas=2, policy=policy,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=3)
    return cfg, pa, fleet


def _serve_with_deploy(fleet, prompts, n_new, deploy_to=None, mid_swap=None):
    fleet.reset()
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        assert fleet.submit(r)
    summary = None
    if deploy_to is not None:
        fleet.tick()
        fleet.tick()
        summary = fleet.deploy(params=deploy_to, mid_swap=mid_swap)
    fleet.run()
    outs = [tuple(fleet.released[r.uid].output) if r.uid in fleet.released
            else None for r in reqs]
    return outs, summary


def test_rolling_deploy_swaps_weights_without_draining():
    """Deploying genuinely different weights mid-serve: every in-flight
    request still releases (zero drain), both replicas re-verify against
    the *new* checksums, and the released tokens change — proof the swap
    reached the live engines."""
    cfg, pa, fleet = _deploy_fleet()
    pb = model_api.init_params(cfg, jax.random.key(1))
    try:
        golden_a, _ = _serve_with_deploy(fleet, PROMPTS[:3], 5)
        mixed, summary = _serve_with_deploy(fleet, PROMPTS[:3], 5,
                                            deploy_to=pb)
        assert summary["swapped"] == [0, 1] and not summary["failed"]
        assert summary["changed"] > 0                # a real weight diff
        assert None not in mixed                     # zero drain: all released
        assert mixed != golden_a                     # new weights are live
        assert fleet.metrics.deploys == 1
        assert fleet.metrics.replicas_swapped == 2
        # ABFT certify gating ran scrubs against the *new* golden the whole
        # time — any stale-checksum bug would have shown up as a detection
        assert fleet.metrics.detections == 0
        kinds = [e.kind for e in fleet.event_log]
        assert kinds.count("deploy_start") == 1
        assert kinds.count("replica_swapped") == 2
        # the fleet now serves the new weights steady-state
        post, _ = _serve_with_deploy(fleet, PROMPTS[:3], 5)
        assert post != golden_a
        assert all(r.routable and r.state is ReplicaState.HEALTHY
                   for r in fleet.replicas)
    finally:
        fleet.close()


def test_rolling_deploy_mid_swap_strike_detected_and_healed():
    """An SEU striking replica 0 while replica 1 is mid-swap — the hardest
    window — must be detected by the post-deploy certify gating and healed,
    with the released stream still byte-identical to the fault-free run."""
    cfg, pa, fleet = _deploy_fleet()
    try:
        golden, _ = _serve_with_deploy(fleet, PROMPTS[:3], 6, deploy_to=pa)

        def mid_swap(rid):
            if rid == 1:
                fleet.strike(0, "weights", fi.flip_one_bit,
                             jax.random.key(11))

        struck, summary = _serve_with_deploy(fleet, PROMPTS[:3], 6,
                                             deploy_to=pa, mid_swap=mid_swap)
        assert struck == golden
        assert fleet.metrics.detections >= 1
        assert fleet.metrics.recoveries >= 1
        assert all(r.state is ReplicaState.HEALTHY and r.routable
                   for r in fleet.replicas)
        kinds = [e.kind for e in fleet.event_log]
        assert "strike" in kinds and "recovery" in kinds
        # the strike landed inside the deploy window
        assert kinds.index("deploy_start") < kinds.index("strike")
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# Speculative backup dispatch
# ---------------------------------------------------------------------------


def test_speculative_backup_wins_when_primary_stalls(smollm_fleet):
    """A straggling primary gets its in-flight request re-issued to a warm
    spare; when the primary stalls outright, the backup's release wins and
    carries the exact bytes the primary would have produced."""
    cfg, params, fleet = smollm_fleet
    fleet.reset()
    prompt = [5, 9, 2]
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=5)
    assert fleet.submit(req)
    fleet.tick()                                     # request is in flight
    # synthetic step-time history: replica 0 is 900× slower than the median
    for i in range(5):
        for rid, dt in ((0, 9.0), (1, 0.01), (2, 0.01)):
            fleet.supervisor.heartbeat(rid, i + 1, dt, fleet.tick_no)
    assert fleet.supervisor.stragglers() == [0]
    fleet._dispatch_backups([0])
    rec = fleet.records[0]
    assert rec.backup is not None and rec.backup_rid != 0
    assert fleet.metrics.backup_dispatches == 1
    assert [e.kind for e in fleet.event_log].count("backup_dispatch") == 1
    fleet.replicas[0].paused = True                  # primary stalls outright
    fleet.run()
    fleet.replicas[0].paused = False
    assert fleet.metrics.backups_won == 1
    assert fleet.released[0] is rec.backup           # the backup's copy won
    assert list(fleet.released[0].output) == greedy_reference(
        cfg, params, prompt, 5)


# ---------------------------------------------------------------------------
# Chaos: SIGKILL under load and mid-deploy (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_proc_fleet_chaos_sigkill_mid_decode_and_mid_deploy():
    """Soak the worst windows: SIGKILL one worker mid-decode, then SIGKILL
    another *during its own weight swap*.  Both must come back through
    quarantine → restore → re-verify, every replayed token must match the
    fault-free run, and the event log must record the full chain."""
    cfg = reduced(registry.get("smollm-135m"))
    params = model_api.init_params(cfg, jax.random.key(0))
    fleet = Fleet(cfg, params, n_replicas=2, policy=Policy.ABFT,
                  capacity=2, max_len=96, prefill_pad=8, scrub_every=3,
                  transport="proc")
    try:
        golden, _ = _serve_with_deploy(fleet, PROMPTS[:3], 6, deploy_to=params)

        fleet.reset()
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=6)
                for i, p in enumerate(PROMPTS[:3])]
        for r in reqs:
            assert fleet.submit(r)
        fleet.tick()
        fleet.tick()
        fleet.replicas[0].handle.proc.kill()         # chaos 1: mid-decode
        fleet.tick()                                 # detect, respawn, replay

        def mid_swap(rid):
            if rid == 1:
                fleet.replicas[1].handle.proc.kill() # chaos 2: mid-own-swap
        summary = fleet.deploy(params=params, mid_swap=mid_swap)
        fleet.run()

        outs = [tuple(fleet.released[r.uid].output) for r in reqs]
        assert outs == golden                        # replay is bit-exact
        assert summary["step"] == 2
        assert fleet.metrics.recoveries >= 2
        assert all(r.state is ReplicaState.HEALTHY and r.routable and r.alive
                   for r in fleet.replicas)
        kinds = [e.kind for e in fleet.event_log]
        assert kinds.count("detection") >= 2         # both transport deaths
        assert kinds.count("quarantine") >= 2
        assert kinds.count("recovery") >= 2
        # the second death happened inside the deploy window and the swap
        # still completed (recovery respawned onto the *new* step)
        dep = kinds.index("deploy_start")
        assert "detection" in kinds[dep:] and "recovery" in kinds[dep:]
        assert kinds[dep:].count("replica_swapped") == 2
    finally:
        fleet.close()
